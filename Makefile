PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-cov test-fast lint bench bench-smoke chaos-smoke deps deps-dev

# fixed fault-injection seed: chaos runs must be reproducible fault-for-fault
REPRO_FAULT_SEED ?= 7
export REPRO_FAULT_SEED

# committed coverage floor over the serving + kernel layers (a ratchet:
# raise it as coverage grows, never lower it to make a PR pass)
COV_FLOOR := 60

lint:  ## ruff bug-tier rules (config in pyproject.toml); CI runs this
	ruff check src tests

test:  ## tier-1 verify (no plugins needed; works in minimal containers)
	python -m pytest -x -q

test-cov:  ## CI variant: parallel via pytest-xdist, coverage-gated on serving/ + kernels/ + obs/ + core.graph/
	python -m pytest -x -q -n auto \
	    --cov=repro.serving --cov=repro.kernels --cov=repro.obs \
	    --cov=repro.core.graph \
	    --cov-report=term --cov-fail-under=$(COV_FLOOR)

test-fast:  ## compiler + kernel subset (quick signal while iterating)
	python -m pytest -x -q tests/test_graph_compiler.py tests/test_execution_plan.py tests/test_kernels.py

bench:
	python -m benchmarks.run

bench-smoke:  ## tiny-shape benchmark pass (CI-sized, no TPU; writes results/BENCH_*_smoke.json)
	python -m benchmarks.kernel_bench --smoke
	python -m benchmarks.table1_apps --smoke
	python -m benchmarks.serving_bench --smoke
	python -m benchmarks.robustness_bench --smoke
	python -m benchmarks.obs_bench --smoke
	python -m benchmarks.decode_bench --smoke
	python -m benchmarks.trajectory --check

chaos-smoke:  ## seeded fault-injection pass: chaos test suite + robustness smoke bench
	python -m pytest -x -q tests/test_robustness.py tests/test_state_isolation.py
	python -m benchmarks.robustness_bench --smoke
	python -m benchmarks.trajectory --check

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
