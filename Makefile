PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast lint bench bench-smoke deps deps-dev

lint:  ## ruff bug-tier rules (config in pyproject.toml); CI runs this
	ruff check src tests

test:  ## tier-1 verify
	python -m pytest -x -q

test-fast:  ## compiler + kernel subset (quick signal while iterating)
	python -m pytest -x -q tests/test_graph_compiler.py tests/test_execution_plan.py tests/test_kernels.py

bench:
	python -m benchmarks.run

bench-smoke:  ## tiny-shape benchmark pass (CI-sized, no TPU; writes results/BENCH_fusion_smoke.json)
	python -m benchmarks.kernel_bench --smoke
	python -m benchmarks.table1_apps --smoke

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
