"""Paper application demo: prune + compile the style-transfer network and
compare the three Table-1 variants on this host.

Run:  PYTHONPATH=src:. python examples/prune_style_transfer.py
"""

import jax
import jax.numpy as jnp

from benchmarks.table1_apps import INPUT_SHAPES, app_masks, bench_app, count_graph_flops
from repro.core.graph import lower, optimize
from repro.models.cnn import build_style_transfer

r = bench_app("style_transfer", sparsity=0.5)
print("variant         ms/frame   (paper ms)")
for v in ("unpruned", "pruned", "pruned_compiler"):
    print(f"{v:15s} {r['ms'][v]:8.2f}   ({r['paper_ms'][v]})")
print(f"compiler FLOP cut: {r['flops']['unpruned'] / r['flops']['pruned_compiler']:.2f}x; "
      f"model bytes cut: {r['param_bytes']['unpruned'] / r['param_bytes']['pruned_compiler']:.2f}x; "
      f"output agreement vs masked-dense: {r['agreement_max_err']:.2e}")

# peek at the optimized graph
g = build_style_transfer(jax.random.PRNGKey(0), base=32)
masks, structures = app_masks(g, "style_transfer", 0.5)
go = optimize(g, masks, structures)
ops = {}
for n in go.nodes:
    ops[n.op] = ops.get(n.op, 0) + 1
print("optimized graph op histogram:", ops)
