"""Quickstart: the paper's pipeline end to end on one weight matrix.

    ADMM structured pruning -> compact storage -> matrix reorder ->
    block-sparse Pallas execution

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import (
    AdmmConfig, Block, PrunePlan, admm_init, admm_penalty, admm_update,
    convergence_metrics, hard_prune,
)
from repro.core.sparse import PBCSR, block_mask, plan_reorder, apply_column_perm, balance_stats
from repro.kernels import bsr_matmul, ref

# ---- 1. a toy task: recover a block-sparse teacher --------------------------
key = jax.random.PRNGKey(0)
D = 256
teacher, _ = __import__("repro.core.pruning", fromlist=["project"]).project(
    jax.random.normal(jax.random.PRNGKey(1), (D, D)), Block(0.5, bm=64, bn=64)
)
x = jax.random.normal(jax.random.PRNGKey(2), (1024, D))
y = x @ teacher


def task_loss(p):
    return jnp.mean((x @ p["w"] - y) ** 2)


# ---- 2. ADMM pruning (paper section 2) ---------------------------------------
plan = PrunePlan.from_rules([("*", Block(0.5, bm=64, bn=64))], min_size=16)
admm_cfg = AdmmConfig(rho=0.3, rho_ramp=1.1, rho_max=3.0, update_every=1)
params = {"w": jax.random.normal(key, (D, D)) * 0.1}
state = admm_init(params, plan, admm_cfg)

step = jax.jit(lambda p, s: jax.tree.map(
    lambda a, g: a - 2e-2 * g,
    p, jax.grad(lambda p_: task_loss(p_) + admm_penalty(p_, s))(p)))
for it in range(300):
    params = step(params, state)
    if it % 10 == 9:
        state = admm_update(params, state, admm_cfg)
print("primal residual:", float(convergence_metrics(params, state)["primal_residual"]))
pruned, masks = hard_prune(params, state)
print("task loss dense -> pruned:", float(task_loss(params)), "->", float(task_loss(pruned)))

# ---- 3. compiler: storage + reorder (paper section 3) --------------------------
w, mask = pruned["w"], masks["w"]
bmask = np.asarray(block_mask(mask, 64, 64))
print("balance before reorder:", balance_stats(bmask))
rplan = plan_reorder(bmask, max_bands=3, bm=64, bn=64)
w_perm = apply_column_perm(w, rplan.order, 64)
m_perm = apply_column_perm(mask, rplan.order, 64)
fmt = PBCSR.from_dense(w_perm, m_perm, 64, 64)
print(f"packed blocks: {fmt.n_blocks} (pad {fmt.padded_blocks}); "
      f"bytes {fmt.nbytes} vs dense {w.size * w.dtype.itemsize}")

# ---- 4. block-sparse execution (Pallas kernel, interpret mode on CPU) -------
bands = [(b.start, b.stop, b.count) for b in rplan.bands]
got = bsr_matmul(x[:128], fmt.values, fmt.block_rows, bands=bands)
want = ref.matmul_ref(x[:128], w_perm)
print("BSR kernel vs dense max err:", float(jnp.abs(got - want).max()))
print("OK")
