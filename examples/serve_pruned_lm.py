"""End-to-end serving driver: batched requests against a pruned LM.

Pipeline: init a small qwen-family model -> one-shot structured prune
(column on FFN) -> masked weights -> serve batched generations + a
continuous-batching queue.

    PYTHONPATH=src python examples/serve_pruned_lm.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pruning import Column, PrunePlan, project
from repro.launch.train import default_prune_plan
from repro.models import get_model
from repro.serving.engine import Engine, Request, RequestScheduler


def small_lm():
    base = get_config("qwen2.5-3b")
    return dataclasses.replace(
        base, name="qwen2.5-serve-demo", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=4096, dtype="float32",
    )


cfg = small_lm()
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# one-shot structured prune of the FFN (the serving-FLOP hotspot)
plan = default_prune_plan(0.5)
assigned = plan.assign(params)
n_pruned = 0
import jax.tree_util as jtu

flat, treedef = jtu.tree_flatten_with_path(params)
out = []
for path, w in flat:
    st = assigned.get(jtu.keystr(path))
    if st is not None:
        w = project(w, st)[0].astype(w.dtype)
        n_pruned += 1
    out.append(w)
params = jtu.tree_unflatten(treedef, out)
print(f"pruned {n_pruned} weight matrices (column/block @ 50%)")

engine = Engine(model, params, batch_size=4, max_len=96)
rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
t0 = time.time()
res = engine.generate(prompts, 24)
dt = time.time() - t0
print(f"batched generate: {res.tokens.shape} in {dt:.2f}s ({4 * 24 / dt:.1f} tok/s)")

sched = RequestScheduler(engine)
for rid in range(10):
    sched.submit(Request(rid=rid,
                         prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 16))).astype(np.int32),
                         max_new=int(rng.integers(4, 12))))
t0 = time.time()
sched.run()
served = [r for r in sched.slots if r is not None]
print(f"continuous batching: {sum(r.done for r in served)} finished in slots, "
      f"queue drained={not sched.queue}, {time.time()-t0:.2f}s")
print("OK")
