"""End-to-end driver: train a ~100M-param qwen2.5-family LM with the full
production stack -- ADMM pruning phases, checkpointing, preemption handling,
deterministic data -- on whatever devices exist.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 200
    PYTHONPATH=src python examples/train_lm_100m.py --tiny --steps 40   # CI

The config is the qwen2.5 family scaled to ~100M params (8 layers, d=512,
vocab 32k); on a pod the same script takes --arch qwen2.5-3b and the
launch/train.py mesh path.
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.pruning import AdmmConfig, hard_prune, tree_sparsity_report
from repro.data.pipeline import SyntheticPipeline
from repro.launch.train import default_prune_plan
from repro.models import get_model
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import PreemptionHandler, StragglerMonitor
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainState, init_train_state, make_train_step


def lm_100m():
    base = get_config("qwen2.5-3b")
    return dataclasses.replace(
        base, name="qwen2.5-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=2, d_ff=1536, vocab=32768, dtype="float32",
    )


def lm_tiny():
    base = get_config("qwen2.5-3b")
    return dataclasses.replace(
        base, name="qwen2.5-tiny", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--prune", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=3e-4 if not args.tiny else 2e-3,
                          total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    admm_cfg = AdmmConfig(rho=1e-2, rho_ramp=1.2, rho_max=1.0, update_every=20) if args.prune else None
    plan = default_prune_plan(0.5) if args.prune else None
    state = init_train_state(params, opt_cfg, admm_cfg=admm_cfg, prune_plan=plan)
    step = jax.jit(make_train_step(model.loss, opt_cfg, admm_cfg=admm_cfg))
    pipe = SyntheticPipeline(cfg, batch=args.batch, seq=args.seq + 1, seed=0)
    mgr = CheckpointManager(args.ckpt, save_every=50) if args.ckpt else None
    mon = StragglerMonitor()
    hard_at = int(args.steps * 0.6)

    with PreemptionHandler() as pre:
        t0 = time.time()
        for i in range(args.steps):
            mon.start_step()
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            state, m = step(state, batch)
            mon.end_step()
            if i % 20 == 0 or i == args.steps - 1:
                toks = args.batch * args.seq
                print(f"step {i:4d} ce={float(m['ce']):.4f} lr={float(m['lr']):.2e} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"({toks / max(mon.times[-1], 1e-9):.0f} tok/s)")
            if args.prune and i == hard_at:
                pruned, masks = hard_prune(state.params, state.admm)
                rep = tree_sparsity_report(pruned, masks)
                print(f"hard prune @ step {i}: sparsity={rep['pruned_global']:.2f}")
                state = TrainState(params=pruned, opt=state.opt, admm=None, masks=masks)
                step = jax.jit(make_train_step(model.loss, opt_cfg))
            if mgr:
                mgr.maybe_save(i + 1, (state, pipe.state.to_dict()), force=pre.should_stop)
            if pre.should_stop:
                print("preempted; clean exit")
                return
        print(f"trained {args.steps} steps in {time.time()-t0:.1f}s; "
              f"median step {mon.median:.2f}s")


if __name__ == "__main__":
    main()
