"""Performance hillclimbing (EXPERIMENTS.md section Perf).

Three cells (chosen per the assignment: worst roofline fraction, most
collective-bound, most representative of the paper's technique), each
iterated hypothesis -> change -> re-lower -> validate.  Every variant is a
full dry-run compile with probe-corrected costs; the deltas below are
therefore structural (HLO), not wall-clock noise.

  cell A  qwen3-14b        prefill_32k  (most collective-bound baseline)
  cell B  deepseek-v2-236b train_4k     (worst memory / compute inflation)
  cell C  qwen2.5-3b       train_4k     (paper technique: pruned execution)

Usage:  python -m benchmarks.perf_iterations [cellA|cellB|cellC ...]
Writes results/perf/<cell>__<variant>.json and prints the iteration log.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import sys

from jax.sharding import PartitionSpec as P

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "perf")


def _run(arch, shape, variant, overrides=None, cfg_override=None, **kw):
    from repro.launch.dryrun import run_cell
    from repro.launch.roofline import analyze_record

    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{arch}__{shape}__{variant}.json")
    if os.path.exists(path) and not kw.pop("force", False):
        with open(path) as f:
            rec = json.load(f)
    else:
        rec = run_cell(arch, shape, "single", overrides=overrides,
                       cfg_override=cfg_override, **kw)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    a = analyze_record(rec) if rec.get("ok") else None
    tag = (f"c={a['t_compute_s']:.3f}s m={a['t_memory_s']:.3f}s "
           f"x={a['t_collective_s']:.3f}s dom={a['dominant']} "
           f"frac={a['roofline_fraction']:.3f} live={a['live_gib']:.0f}GiB"
           if a else f"FAILED: {rec.get('error')}")
    print(f"  [{variant:24s}] {tag}", flush=True)
    return rec, a


def cell_a():
    """qwen3-14b prefill_32k: drive the collective term down."""
    print("=== cell A: qwen3-14b prefill_32k (collective-bound) ===")
    arch, shape = "qwen3-14b", "prefill_32k"
    print("H0 baseline: TP all-reduces of [B,32k,5120] activations dominate")
    _run(arch, shape, "baseline")
    print("H1: sequence-sharding the residual stream between blocks converts"
          " each AR(2N) into RS(N)+AG(N) at the block boundary and keeps all"
          " norms/elementwise S/16-sharded -> expect collective bytes ~0.5x,"
          " memory bytes ~ lower too")
    _run(arch, shape, "seqpar",
         overrides={"residual_spec": P("data", "model", None)})
    print("H2: on top of seqpar, raise the online-softmax KV chunk 1k->4k:"
          " 4x fewer renormalization rounds (m/l/acc rescales + mask temps)"
          " -> expect memory term down ~20-30%, compute ~flat")
    _run(arch, shape, "seqpar_chunk4k",
         overrides={"residual_spec": P("data", "model", None), "attn_chunk": 4096})
    print()


def cell_b():
    """deepseek-v2-236b train_4k: memory + compute inflation."""
    print("=== cell B: deepseek-v2-236b train_4k (worst memory) ===")
    arch, shape = "deepseek-v2-236b", "train_4k"
    print("H0 baseline(fsdp): involuntary full remat + expert all-gathers")
    _run(arch, shape, "baseline")
    print("H1: EP2D rules -- shard expert F-dim over data instead of D-dim:"
          " contraction stays local for gate/up, w_down contributes a"
          " reduce-scatter; no full expert-stack all-gather -> live GiB and"
          " collective bytes drop hard")
    from repro.models.sharding import FSDP_RULES
    from jax.sharding import PartitionSpec as P2

    EP2D = [
        (r"\['embed'\].*table", P2("model", "data")),
        (r"\['lm_head'\]\['w'\]", P2("data", "model")),
        (r"\['experts'\]\['w_gate'\]", P2("model", None, "data")),
        (r"\['experts'\]\['w_up'\]", P2("model", None, "data")),
        (r"\['experts'\]\['w_down'\]", P2("model", "data", None)),
        (r"\['router'\]", P2(None)),
        (r"\['(w_q|w_k|w_v|w_uq|w_uk|w_uv)'\]\['w'\]", P2("data", "model")),
        (r"\['(w_q|w_k|w_v|w_uq|w_uk|w_uv)'\]\['b'\]", P2("model")),
        (r"\['w_o'\]\['w'\]", P2("model", "data")),
        (r"\['(w_dq|w_dkv|w_kr)'\]\['w'\]", P2("data", None)),
        (r"\['(w_gate|w_up|in_proj|gate_proj|w_r|w_i)'\]\['w'\]", P2("data", "model")),
        (r"\['(w_down|out_proj)'\]\['w'\]", P2("model", "data")),
    ]
    _run(arch, shape, "ep2d", overrides={"rules": EP2D})
    print("H1 outcome: REFUTED -- F-sharded experts are propagation-hostile"
          " downstream of the dispatch einsum (memory term 5x worse)")
    print("H2: ep2d + seqpar residual (activation memory at S=4k is the"
          " second term)")
    _run(arch, shape, "ep2d_seqpar",
         overrides={"rules": EP2D, "residual_spec": P("data", "model", None)})
    print("H3: FSDP weight rules (GSPMD-friendly) + seqpar -- best of both")
    _run(arch, shape, "fsdp_seqpar",
         overrides={"rules": "fsdp", "residual_spec": P("data", "model", None)})
    print("H4: + dots-remat (save expert einsums; backward stops re-gathering"
          " FSDP shards)")
    _run(arch, shape, "fsdp_seqpar_dots",
         overrides={"rules": "fsdp", "residual_spec": P("data", "model", None),
                    "remat_policy": "dots"})
    print("H4 outcome: REFUTED (<1% bound, +65GiB live); stopped after two"
          " consecutive <5% changes per protocol")
    print()


def cell_c():
    """qwen2.5-3b train_4k: the paper's technique, faithful then beyond."""
    print("=== cell C: qwen2.5-3b train_4k (paper technique) ===")
    arch, shape = "qwen2.5-3b", "train_4k"
    from repro.configs import get_config
    from repro.configs.base import PruneConfig

    print("H0 dense baseline (paper's 'unpruned' row)")
    _run(arch, shape, "baseline")
    print("H1 paper-faithful: column-prune FFN + block-prune attn q/o @50%"
          " (packed execution) -> FFN+attn GEMM FLOPs halve; expect the"
          " compute term ~0.55x and memory term down (smaller weights)")
    cfg_pruned = dataclasses.replace(
        get_config(arch), prune=PruneConfig(enabled=True, exec_mode="bsr_xla", sparsity=0.5)
    )
    _run(arch, shape, "pruned50", cfg_override=cfg_pruned)
    print("H2 beyond-paper: + remat policy 'dots' (save matmul/TP-collective"
          " outputs; backward stops recomputing them) -> collective term"
          " ~0.6x, compute term down, memory term up slightly (saved dots)")
    _run(arch, shape, "pruned50_dotsremat", cfg_override=cfg_pruned,
         overrides={"remat_policy": "dots"})
    print("H3 beyond-paper: + sequence-parallel residual")
    _run(arch, shape, "pruned50_dots_seqpar", cfg_override=cfg_pruned,
         overrides={"remat_policy": "dots",
                    "residual_spec": P("data", "model", None)})
    print()


def main():
    which = sys.argv[1:] or ["cellA", "cellB", "cellC"]
    if "cellA" in which:
        cell_a()
    if "cellB" in which:
        cell_b()
    if "cellC" in which:
        cell_c()
    if "cellC" in which or "controls" in which:
        cell_c_controls()





def cell_c_controls():
    """Isolate the pruning contribution: the beyond-paper opts alone."""
    print("=== cell C controls ===")
    arch, shape = "qwen2.5-3b", "train_4k"
    print("H4 control: dense + dots-remat + seqpar (no pruning) -- isolates"
          " the paper technique's contribution inside the optimized stack")
    _run(arch, shape, "dense_dots_seqpar",
         overrides={"remat_policy": "dots",
                    "residual_spec": P("data", "model", None)})
    print("H5 control: pruned + FULL remat + seqpar (no dots policy)")
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.configs.base import PruneConfig

    cfg_pruned = _dc.replace(
        get_config(arch), prune=PruneConfig(enabled=True, exec_mode="bsr_xla", sparsity=0.5)
    )
    _run(arch, shape, "pruned50_seqpar", cfg_override=cfg_pruned,
         overrides={"residual_spec": P("data", "model", None)})
    print()


if __name__ == "__main__":
    main()
