"""Robustness benchmark: what guarded degradation costs and what chaos
cannot break.

What is recorded (``results/BENCH_robustness.json``, ``_smoke`` variant in
CI):

1. **degraded** -- per demo app, the eager reference plan vs the guarded
   plan forced into full degradation (a 100% injected kernel-failure rate,
   so every step demotes through the breaker machinery): the degraded-mode
   overhead ratio is the price of the guard rails when everything is on
   fire, and the outputs must be *bit-identical* to the reference plan
   (the fallback is the oracle).  The clean-mode ratio (guarded, no
   faults) is recorded alongside: the price of the rails when nothing is.
2. **chaos** -- the zero-request-loss gate: all three apps served by one
   ``AsyncPlanServer`` (background scheduler thread) under a seeded 5%
   kernel-failure rate, submissions through the jittered-backoff retry
   helper.  Every request must complete within 1e-4 of the reference
   plan, the scheduler thread must survive, and the injected faults must
   actually have fired (a chaos run with no chaos gates nothing).
3. **chaos_total** -- the same traffic under a 100% failure rate: every
   step demotes and every result must be bit-exact vs reference.
4. **recovery** -- breaker lifecycle on an injected clock: sustained
   failures trip every breaker open; with the faults gone and the cooldown
   elapsed, one probe pass must close them all again.

All fault decisions come from one seeded RNG (``--seed``, default from
``REPRO_FAULT_SEED``), so a run is reproducible fault-for-fault.
``--smoke`` shrinks shapes and traffic for CI (wired into
``make chaos-smoke`` / ``make bench-smoke``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import compile_plan
from repro.models.cnn import APPS
from repro.robustness import FaultPlan, FaultRule, GuardConfig
from repro.serving import AsyncPlanServer, submit_with_retry

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _median_ms(fn, reps: int) -> float:
    fn()  # warm: compile/caches outside the timed window
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e3


def _build(smoke: bool, guard: GuardConfig | None = None):
    """(guarded plan, reference plan, params, frame shape) per app."""
    base, size = (8, 12) if smoke else (16, 16)
    built = {}
    for app in APPS:
        g = APPS[app](jax.random.PRNGKey(0), base=base)
        built[app] = (
            compile_plan(g, backend="guarded", guard=guard),
            compile_plan(g, backend="reference"),
            g.params,
            (1 if app == "coloring" else 3, size, size),
        )
    return built


def bench_robustness(
    smoke: bool = False, seed: int = 7, out_path: str | None = None
) -> dict:
    record: dict = {
        "mode": "interpret",  # guarded plans are eager; wall-clock is Python
        "smoke": smoke,
        "seed": seed,
        "degraded": [],
        "chaos": {},
        "chaos_total": {},
        "recovery": {},
    }
    reps = 3 if smoke else 5
    frames_per_app = 4 if smoke else 8
    batch_size = 2 if smoke else 4
    rng = np.random.default_rng(0)

    # 1. degraded-mode overhead: guarded-under-total-failure vs reference.
    print("robustness_degraded,app,ref_ms,degraded_ms,overhead,bitexact")
    built = _build(smoke)
    for app, (plan, ref, params, shape) in built.items():
        x = jnp.asarray(rng.standard_normal((batch_size, *shape)), jnp.float32)
        y_ref = np.asarray(ref(params, x))
        ref_ms = _median_ms(lambda: ref(params, x), reps)
        clean_ms = _median_ms(lambda: plan(params, x), reps)
        with FaultPlan([FaultRule("*", "raise", rate=1.0)], seed=seed):
            y_deg = np.asarray(plan(params, x))
            deg_ms = _median_ms(lambda: plan(params, x), reps)
        bitexact = bool(np.array_equal(y_deg, y_ref))
        assert bitexact, app  # the fallback IS the reference: exact or bust
        row = {
            "app": app,
            "ref_ms": ref_ms,
            "clean_ms": clean_ms,
            "degraded_ms": deg_ms,
            "overhead": deg_ms / ref_ms,
            "clean_overhead": clean_ms / ref_ms,
            "max_err": 0.0,
            "bitexact": bitexact,
            "fallbacks": plan.guard_stats()["counters"]["fallbacks"],
        }
        record["degraded"].append(row)
        print(
            f"robustness_degraded,{app},{ref_ms:.2f},{deg_ms:.2f},"
            f"{row['overhead']:.2f}x,{bitexact}"
        )

    # 2 + 3. chaos scenarios through the async server (fresh plans so the
    # breaker/counter state starts clean; one server thread hosts all apps).
    def chaos_scenario(rate: float) -> dict:
        built = _build(smoke)
        server = AsyncPlanServer(flush_after=0.005, tick_interval=0.001)
        for app, (plan, _ref, params, shape) in built.items():
            server.add_plan(
                app, plan, params, batch_size,
                input_spec=[(shape, jnp.float32)],
            )
        frames = {
            app: [
                jnp.asarray(rng.standard_normal(built[app][3]), jnp.float32)
                for _ in range(frames_per_app)
            ]
            for app in built
        }
        with server:
            server.start()
            for app in built:  # warm each path outside the chaos window
                server.submit(app, frames[app][0]).result(120)
            t0 = time.perf_counter()
            with FaultPlan([FaultRule("*", "raise", rate=rate)], seed=seed) as fp:
                handles = [
                    (app, f, submit_with_retry(server, app, f, backoff=0.001))
                    for app in built
                    for f in frames[app]
                ]
                results = [(app, f, h, h.result(600)) for app, f, h in handles]
                injected = fp.injection_count()
            wall = time.perf_counter() - t0
            lost = sum(1 for _, _, h, _ in results if h.exception() is not None)
            max_err, exact = 0.0, True
            for app, f, _h, y in results:
                _plan, ref, params, _shape = built[app]
                y_ref = np.asarray(ref(params, f[None]))[0]
                max_err = max(max_err, float(np.max(np.abs(np.asarray(y) - y_ref))))
                exact = exact and bool(np.array_equal(np.asarray(y), y_ref))
            stats = server.stats
            health = server.health()
            out = {
                "rate": rate,
                "requests": len(handles),
                "lost_requests": lost,
                "injected_faults": injected,
                "fallbacks": sum(
                    p.get("guard", {}).get("counters", {}).get("fallbacks", 0)
                    for p in health["plans"].values()
                ),
                "breaker_trips": sum(
                    b["trips"]
                    for p in health["plans"].values()
                    for b in p.get("guard", {}).get("breakers", {}).values()
                ),
                "max_err": max_err,
                "bitexact": exact,
                "scheduler_survived": bool(
                    server.running and health["tick_errors"] == 0
                ),
                "watchdog_timeouts": stats["watchdog_timeouts"],
                "wall_s": wall,
            }
        # the chaos gate proper: zero loss, surviving scheduler, real chaos
        assert out["lost_requests"] == 0, out
        assert out["scheduler_survived"], out
        assert out["injected_faults"] >= 1, "chaos run injected nothing"
        assert out["max_err"] <= 1e-4, out
        return out

    record["chaos"] = chaos_scenario(0.05)
    c = record["chaos"]
    print(
        f"robustness_chaos,rate=0.05,requests={c['requests']},"
        f"lost={c['lost_requests']},injected={c['injected_faults']},"
        f"fallbacks={c['fallbacks']},max_err={c['max_err']:.2e},"
        f"survived={c['scheduler_survived']}"
    )
    record["chaos_total"] = chaos_scenario(1.0)
    ct = record["chaos_total"]
    assert ct["bitexact"], ct  # total demotion must reproduce the oracle
    print(
        f"robustness_chaos_total,rate=1.0,requests={ct['requests']},"
        f"lost={ct['lost_requests']},bitexact={ct['bitexact']},"
        f"trips={ct['breaker_trips']}"
    )

    # 4. breaker recovery on an injected clock: trip everything, lift the
    # faults, let the cooldown elapse, and one probe pass must close it all.
    clk = _Clock()
    cfg = GuardConfig(breaker_threshold=2, breaker_cooldown=5.0, clock=clk)
    built = _build(True, guard=cfg)  # tiny shapes: lifecycle, not perf
    app, (plan, ref, params, shape) = next(iter(built.items()))
    x = jnp.asarray(rng.standard_normal((2, *shape)), jnp.float32)
    with FaultPlan([FaultRule("*", "raise", rate=1.0)], seed=seed):
        for _ in range(3):  # enough passes to trip every per-op breaker
            plan(params, x)
    states = {b["state"] for b in plan.guard_stats()["breakers"].values()}
    trips = sum(b["trips"] for b in plan.guard_stats()["breakers"].values())
    assert "open" in states and trips >= 1, (states, trips)
    clk.advance(5.0)  # cooldown elapses; faults are gone
    y = plan(params, x)
    after = {b["state"] for b in plan.guard_stats()["breakers"].values()}
    recovered = after == {"closed"}
    assert recovered, after
    assert np.allclose(np.asarray(y), np.asarray(ref(params, x)), atol=1e-4)
    record["recovery"] = {
        "app": app,
        "breaker_trips": trips,
        "states_while_tripped": sorted(states),
        "states_after_cooldown": sorted(after),
        "recovered": recovered,
    }
    print(f"robustness_recovery,{app},trips={trips},recovered={recovered}")

    # smoke numbers are CI plumbing, not perf data: never clobber the
    # cross-PR trajectory artifact with them
    default_name = (
        "BENCH_robustness_smoke.json" if smoke else "BENCH_robustness.json"
    )
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"robustness,saved,{os.path.abspath(out_path)}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI, no TPU)")
    ap.add_argument(
        "--seed", type=int,
        default=int(os.environ.get("REPRO_FAULT_SEED", "7")),
        help="fault-injection seed (env REPRO_FAULT_SEED)",
    )
    args = ap.parse_args()
    bench_robustness(smoke=args.smoke, seed=args.seed)
