"""ADMM pruning benchmark: convergence + quality-vs-sparsity tradeoff
(the paper's section 2 as a table; their accuracy tables are qualitative
"satisfied output", our proxy is recoverable-regression loss).

Setup: block-sparse teacher, dense student; report the final primal residual
and the post-hard-prune loss ratio vs the dense-trained floor at each
sparsity -- ADMM should be near-loss-neutral up to the teacher's sparsity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import (
    AdmmConfig,
    Block,
    PrunePlan,
    admm_init,
    admm_penalty,
    admm_update,
    convergence_metrics,
    hard_prune,
)


def run_admm(sparsity: float, steps: int = 300, d: int = 64):
    key = jax.random.PRNGKey(0)
    wtrue, _ = (lambda w: (w, None))(jax.random.normal(jax.random.PRNGKey(2), (d, d)))
    from repro.core.pruning import project

    wtrue, _ = project(wtrue, Block(0.5, bm=8, bn=8))
    x = jax.random.normal(jax.random.PRNGKey(1), (512, d))
    y = x @ wtrue

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    plan = PrunePlan.from_rules([("*", Block(sparsity, bm=8, bn=8))], min_size=16)
    cfg = AdmmConfig(rho=0.3, rho_ramp=1.1, rho_max=3.0, update_every=1)
    params = {"w": jax.random.normal(key, (d, d)) * 0.1}
    state = admm_init(params, plan, cfg)

    def total(p, s):
        return loss_fn(p) + admm_penalty(p, s)

    step = jax.jit(
        lambda p, s: jax.tree.map(lambda a, g: a - 2e-2 * g, p, jax.grad(total)(p, s))
    )
    p = params
    for it in range(steps):
        p = step(p, state)
        if it % 10 == 9:
            state = admm_update(p, state, cfg)
    res = float(convergence_metrics(p, state)["primal_residual"])
    pruned, _ = hard_prune(p, state)
    # dense floor: same budget without ADMM
    pd = params
    stepd = jax.jit(lambda p: jax.tree.map(lambda a, g: a - 2e-2 * g, p, jax.grad(loss_fn)(p)))
    for _ in range(steps):
        pd = stepd(pd)
    return res, float(loss_fn(pruned)), float(loss_fn(pd))


def main():
    print("admm,sparsity,primal_residual,pruned_loss,dense_loss,ratio")
    for sp in (0.25, 0.5, 0.75):
        res, lp, ld = run_admm(sp)
        print(f"admm,{sp},{res:.4f},{lp:.5f},{ld:.5f},{lp / max(ld, 1e-9):.2f}")


if __name__ == "__main__":
    main()
