"""Serving-layer benchmark: the async continuous-batching engine
(`repro/serving/scheduler.py`) hosting all three demo apps in one process.

What is recorded (``results/BENCH_serving.json``, ``_smoke`` variant in CI):

1. **parity** -- the async path must be bit-close to direct
   ``ExecutionPlan`` execution for every app (padding, batching and the
   scheduler must be invisible in the outputs); gated in EVERY mode.
2. **sustained throughput** -- mixed traffic over the three apps through
   the background scheduler thread: requests/s, p50/p95/p99 request
   latency, padding overhead (padded frames per executed slot) and the
   deadline-miss rate.  The speedup vs serial single-frame execution is
   asserted on real hardware only (interpret/CPU wall-clock measures
   Python, not the schedule).
3. **backpressure** -- bounded admission queues under flood: the reject
   policy's rejection count and the shed policy's evictions, both of which
   must actually trigger (the queue bound is load-bearing).
4. **fairness** -- 10:1 skewed traffic over two plans: the minority plan's
   requests must complete in the first scheduler rotations, not behind the
   majority's backlog.
5. **multi_tenant** -- sustained overload at 2x capacity with a 10:1
   hot/light tenant skew on the injected clock: per-tenant p50/p95/p99,
   throttle/shed counts and ladder-transition counts.  Gated: the in-quota
   light tenant loses zero requests and stays within its deadline SLO
   while the hot tenant's excess is absorbed by its quota + degradation
   ladder -- the armed watchdog must never fire.

``--smoke`` shrinks shapes and traffic so CI exercises the full path
without a TPU (wired into ``make bench-smoke``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import compile_plan, optimize
from repro.kernels import ops as kops
from repro.models.cnn import APPS, app_masks
from repro.serving import AsyncPlanServer, QueueFullError

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

APP_FRAME_SHAPES = {
    "style_transfer": (3, 16, 16),
    "coloring": (1, 16, 16),
    "super_resolution": (3, 8, 8),
}


def _build_plans(smoke: bool, backend: str):
    plans = {}
    for app in APPS:
        g = APPS[app](jax.random.PRNGKey(0), base=8 if smoke else 16)
        masks, structures = app_masks(g, app, sparsity=0.5)
        go = optimize(g, masks, structures)
        plans[app] = (compile_plan(go, backend=backend), go.params)
    return plans


def _frame(rng, app):
    return jnp.asarray(rng.standard_normal(APP_FRAME_SHAPES[app]), jnp.float32)


def _latency_pcts(lats) -> dict:
    arr = np.asarray([v for v in lats if v is not None])
    if not arr.size:
        return {"count": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    return {
        "count": int(arr.size),
        "p50": float(np.percentile(arr, 50)),
        "p95": float(np.percentile(arr, 95)),
        "p99": float(np.percentile(arr, 99)),
        "mean": float(arr.mean()),
    }


def bench_serving(smoke: bool = False, out_path: str | None = None) -> dict:
    interpret = kops.interpret_default()
    backend = "reference" if interpret else "kernel"
    record: dict = {
        "mode": "interpret" if interpret else "hw",
        "smoke": smoke,
        "backend": backend,
        "parity": [],
        "throughput": {},
        "backpressure": {},
        "fairness": {},
        "multi_tenant": {},
    }
    plans = _build_plans(smoke, backend)
    rng = np.random.default_rng(0)
    batch_size = 4

    # 1. parity: deterministic (step-driven) async serving vs direct plan
    # execution -- gates the bench in every mode.
    print("serving_parity,app,requests,max_err")
    now = [0.0]
    server = AsyncPlanServer(flush_after=1.0, clock=lambda: now[0])
    for app, (plan, params) in plans.items():
        server.add_plan(app, plan, params, batch_size)
    probes = {
        app: [(_frame(rng, app), None) for _ in range(batch_size + 1)]
        for app in plans
    }
    for app, frames in probes.items():
        probes[app] = [(x, server.submit(app, x)) for x, _ in frames]
    while server.step(force=True):
        pass
    for app, frames in probes.items():
        plan, params = plans[app]
        want = plan(params, jnp.stack([x for x, _ in frames]))
        err = float(
            max(
                jnp.max(jnp.abs(jnp.asarray(h.result(0)) - jnp.asarray(want)[i]))
                for i, (_, h) in enumerate(frames)
            )
        )
        assert err <= 1e-5, (app, err)  # parity gates the bench in every mode
        record["parity"].append({"app": app, "requests": len(frames), "max_err": err})
        print(f"serving_parity,{app},{len(frames)},{err:.2e}")
    server.close()

    # 2. sustained throughput through the scheduler thread: mixed traffic,
    # per-request deadlines, latency percentiles, padding overhead.
    n_requests = 24 if smoke else 240
    deadline = 5.0 if smoke else 1.0
    apps = list(plans)
    server = AsyncPlanServer(flush_after=0.005, tick_interval=0.001)
    for app, (plan, params) in plans.items():
        server.add_plan(app, plan, params, batch_size)
    with server:
        server.start()
        for app in apps:  # warm chunk compilation out of the timed window
            server.submit(app, jnp.zeros(APP_FRAME_SHAPES[app], jnp.float32)).result()
        warm_stats = server.stats
        t0 = time.perf_counter()
        handles = []
        for i in range(n_requests):
            app = apps[i % len(apps)]
            handles.append(
                server.submit(app, _frame(rng, app), priority=i % 2, deadline=deadline)
            )
        for h in handles:
            h.result()
        dt = time.perf_counter() - t0
        s = server.stats
        # percentiles over the traffic handles only: the server's reservoirs
        # also hold the warmup requests, whose latency is jit compile time
        lat = _latency_pcts([h.latency for h in handles])
        batches = s["batches"] - warm_stats["batches"]
        padded = s["padded_frames"] - warm_stats["padded_frames"]
        misses = s["deadline_misses"] - warm_stats["deadline_misses"]
        record["throughput"] = {
            "requests": n_requests,
            "wall_s": dt,
            "req_per_s": n_requests / dt,
            "batches": batches,
            "padded_frames": padded,
            "padding_overhead": padded / max(batches * batch_size, 1),
            "deadline_misses": misses,
            "deadline_miss_rate": misses / n_requests,
            "deadline_flushes": s["deadline_flushes"] - warm_stats["deadline_flushes"],
            "latency_s": lat,
            "per_plan_latency_s": {
                a: _latency_pcts([h.latency for h in handles if h.plan == a])
                for a in apps
            },
        }

    # serial single-frame baseline over the same traffic volume: the
    # throughput the batching schedule must beat on real hardware
    serial_fns = {
        app: jax.jit(lambda p, x, _plan=plan: _plan(p, x))
        for app, (plan, params) in plans.items()
    }
    for app, (plan, params) in plans.items():  # compile outside the window
        jax.block_until_ready(serial_fns[app](params, jnp.zeros((1, *APP_FRAME_SHAPES[app]))))
    t0 = time.perf_counter()
    for i in range(n_requests):
        app = apps[i % len(apps)]
        jax.block_until_ready(serial_fns[app](plans[app][1], _frame(rng, app)[None]))
    serial_dt = time.perf_counter() - t0
    record["throughput"]["serial_req_per_s"] = n_requests / serial_dt
    speedup = serial_dt / record["throughput"]["wall_s"]
    record["throughput"]["speedup_vs_serial"] = speedup
    if not interpret:  # interpret/CPU wall-clock measures Python, not silicon
        assert speedup > 1.0, speedup
    t = record["throughput"]
    print(
        f"serving_throughput,{n_requests},{t['req_per_s']:.1f}req/s,"
        f"p50={t['latency_s']['p50'] * 1e3:.2f}ms,"
        f"p95={t['latency_s']['p95'] * 1e3:.2f}ms,"
        f"p99={t['latency_s']['p99'] * 1e3:.2f}ms,"
        f"pad={t['padding_overhead']:.3f},miss={t['deadline_miss_rate']:.3f},"
        f"vs_serial={speedup:.2f}x"
    )

    # 3. backpressure: both overload policies must actually trigger.
    app = apps[0]
    plan, params = plans[app]
    for policy in ("reject", "shed"):
        server = AsyncPlanServer(max_queue=4, overload=policy, clock=lambda: 0.0)
        server.add_plan(app, plan, params, batch_size)
        rejected = 0
        handles = []
        # 3 over the bound; the overflow submits carry a higher priority so
        # the shed policy actually evicts queued work (an equal-priority
        # newcomer is itself the victim and raises, like reject)
        for i in range(7):
            try:
                handles.append(
                    server.submit(app, _frame(rng, app), priority=int(i >= 4))
                )
            except QueueFullError:
                rejected += 1
        failed = sum(1 for h in handles if h.done() and h.exception() is not None)
        server.close()
        s = server.stats
        row = {"policy": policy, "submitted": 7, "max_queue": 4,
               "rejected": s["rejected"], "shed": s["shed"]}
        record["backpressure"][policy] = row
        assert (s["rejected"] if policy == "reject" else s["shed"]) == 3, row
        assert (rejected if policy == "reject" else failed) == 3, row
        print(f"serving_backpressure,{policy},rejected={s['rejected']},shed={s['shed']}")

    # 4. fairness under 10:1 skew: the minority plan's batch must execute in
    # the first scheduler rotations, not after the majority's backlog.
    heavy, light = apps[0], apps[1]
    server = AsyncPlanServer(clock=lambda: 0.0)
    for a in (heavy, light):
        server.add_plan(a, *plans[a], batch_size=batch_size)
    heavy_handles = [server.submit(heavy, _frame(rng, heavy)) for _ in range(10 * batch_size)]
    light_handles = [server.submit(light, _frame(rng, light)) for _ in range(batch_size)]
    ticks_to_light = 0
    while not all(h.done() for h in light_handles):
        server.step()
        ticks_to_light += 1
    heavy_done = sum(h.done() for h in heavy_handles)
    server.close()
    record["fairness"] = {
        "heavy_requests": len(heavy_handles), "light_requests": len(light_handles),
        "ticks_until_light_done": ticks_to_light,
        "heavy_done_at_that_point": heavy_done,
    }
    assert ticks_to_light <= 2, ticks_to_light  # round-robin, not FIFO-global
    print(f"serving_fairness,ticks_until_light_done={ticks_to_light},"
          f"heavy_done={heavy_done}/{len(heavy_handles)}")

    # 5. multi-tenant overload: 2x sustained capacity with a 10:1 hot/light
    # skew, driven tick-by-tick on the injected clock (deterministic).  The
    # in-quota light tenant must ride out the storm -- zero lost requests,
    # deadline misses within its SLO -- while the hot tenant's excess is
    # absorbed by its token bucket and the degradation ladder (throttle ->
    # shrink_flush -> demote -> shed).  The watchdog is armed and must never
    # fire: overload is a policy decision here, not a hang.
    from repro.serving import LadderConfig, QuotaExceededError, TenantSLO

    app = apps[0]
    plan, params = plans[app]
    now = [0.0]
    dt = 0.01  # one scheduler tick = one batch of service capacity
    ticks = 60 if smoke else 240
    deadline_s = 10 * dt
    server = AsyncPlanServer(
        clock=lambda: now[0], overload="shed", max_queue=512,
        deadline_margin=2 * dt, watchdog=30.0,
    )
    server.add_plan(app, plan, params, batch_size)
    server.register_variant(app, "cheap", plan, params)
    server.add_tenant(
        "hot", weight=1.0, rate=6.0 / dt, burst=2.0 * batch_size,
        slo=TenantSLO(p99_latency=5 * dt, min_samples=4),
        ladder=LadderConfig(interval=5 * dt, breach_evals=1,
                            recover_evals=4, shed_below_priority=1),
    )
    server.add_tenant("light", weight=1.0)
    handles = {"hot": [], "light": []}
    turned_away = {"hot": 0, "light": 0}
    throttled_at_submit = 0
    arrival = 0
    for _ in range(ticks):
        for _ in range(2 * batch_size):  # 2x capacity offered per tick
            tenant = "light" if arrival % 11 == 0 else "hot"  # 10:1 skew
            arrival += 1
            try:
                handles[tenant].append(server.submit(
                    app, _frame(rng, app),
                    priority=1 if tenant == "light" else 0,
                    deadline=deadline_s, tenant=tenant,
                ))
            except QuotaExceededError:
                turned_away[tenant] += 1
                throttled_at_submit += 1
            except QueueFullError:  # ladder shed or queue shed
                turned_away[tenant] += 1
        now[0] += dt
        server.step()
    while server.pending():  # drain the residual backlog on the same clock
        now[0] += dt
        server.step(force=True)
    per_tenant = server.stats["per_tenant"]
    plan_stats = server.stats["per_plan"][app]
    tenant_health = server.health()["tenants"]
    server.close()

    def tenant_row(name):
        hs = handles[name]
        ok = [h for h in hs if h.exception() is None]
        misses = sum(h.deadline_missed for h in ok)
        ts = per_tenant[name]
        return {
            "offered": len(hs) + turned_away[name],
            "admitted": len(hs),
            "lost": len(hs) - len(ok),  # admitted but never completed
            "turned_away": turned_away[name],
            "throttled": ts["throttled"],
            "ladder_shed": ts["ladder_shed"],
            "demoted_admissions": ts["demoted_admissions"],
            "ladder_up": ts["ladder_up"],
            "ladder_down": ts["ladder_down"],
            "ladder_level": tenant_health[name]["level_name"],
            "deadline_misses": misses,
            "deadline_miss_rate": misses / max(len(ok), 1),
            "latency_s": _latency_pcts([h.latency for h in ok]),
        }

    hot, light = tenant_row("hot"), tenant_row("light")
    record["multi_tenant"] = {
        "ticks": ticks, "capacity_per_tick": batch_size,
        "offered_per_tick": 2 * batch_size, "skew": "10:1",
        "deadline_s": deadline_s, "hot": hot, "light": light,
        "queue_shed": plan_stats["shed"],
        "watchdog_timeouts": plan_stats["watchdog_timeouts"],
    }
    # the overload gate: in-SLO tenant unharmed, ladder (not watchdog)
    # absorbed the excess, and every transition is registry-visible
    assert light["lost"] == 0 and light["turned_away"] == 0, light
    assert light["deadline_miss_rate"] <= 0.1, light
    assert hot["ladder_up"] >= 1, hot  # the ladder actually engaged
    assert hot["ladder_shed"] + hot["throttled"] >= 1, hot
    assert plan_stats["watchdog_timeouts"] == 0
    from repro.obs import metrics as _metrics

    transitions = _metrics.registry().label_counts(
        "serving_ladder_transitions_total", "tenant", "direction"
    )
    assert sum(transitions.values()) >= hot["ladder_up"], transitions
    print(
        f"serving_multi_tenant,hot,p99={hot['latency_s']['p99'] * 1e3:.1f}ms,"
        f"throttled={hot['throttled']},ladder_shed={hot['ladder_shed']},"
        f"ladder_up={hot['ladder_up']},level={hot['ladder_level']}"
    )
    print(
        f"serving_multi_tenant,light,p99={light['latency_s']['p99'] * 1e3:.1f}ms,"
        f"miss_rate={light['deadline_miss_rate']:.3f},lost={light['lost']},"
        f"watchdog_timeouts={plan_stats['watchdog_timeouts']}"
    )

    # smoke numbers are CI plumbing, not perf data: never clobber the
    # cross-PR trajectory artifact with them
    default_name = "BENCH_serving_smoke.json" if smoke else "BENCH_serving.json"
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"serving,saved,{os.path.abspath(out_path)}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI, no TPU)")
    bench_serving(smoke=ap.parse_args().smoke)
