"""Autoregressive-decode benchmark: the decoder lowering
(`repro/models/transformer_graph.py`), the paged KV-cache
(`repro/serving/kvcache.py`) and token-level continuous batching
(`AsyncPlanServer.submit_llm`).

What is recorded (``results/BENCH_decode.json``, ``_smoke`` variant in CI):

1. **parity** -- prefill-plan logits vs the plain jnp ``forward`` on the
   same params (the whole lowering + PassManager pipeline must be invisible
   in the outputs); gated at 1e-4 in every mode.
2. **greedy** -- full autoregressive greedy decode through the paged
   pipeline (prefill plan -> per-token decode plan over ``gather``-ed cache
   spans) vs a naive jnp forward loop: exact token match, gated.
3. **plans** -- plan-step counts for both phase graphs, unfused vs through
   ``fuse_epilogue`` (rope folds into the q/k projections, residual adds
   into w_o/w_down, the final rmsnorm into the last w_down): the step
   reduction is gated (fused < unfused).
4. **serve** -- mixed-length prompts through ``AsyncPlanServer.submit_llm``
   continuous batching: decode tok/s, prefill/decode batch counts, and the
   zero-loss / zero-page-leak gates.  Wall-clock is recorded, never
   asserted, in interpret mode (it measures Python, not the schedule).

``--smoke`` shrinks traffic so CI exercises the full path without a TPU
(wired into ``make bench-smoke``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.core.graph import compile_plan
from repro.core.graph.passes import optimize
from repro.kernels import ops as kops
from repro.models.transformer import forward, init_lm
from repro.models.transformer_graph import build_decoder_graph, decoder_cache_spec
from repro.serving import AsyncPlanServer, PagedKVCache

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

ARCH = "qwen2.5-3b"


def _greedy_naive(params, cfg, prompt, steps):
    seq = [int(t) for t in prompt]
    for _ in range(steps):
        logits, _ = forward(params, cfg, jnp.asarray([seq], jnp.int32))
        seq.append(int(jnp.argmax(logits[0, -1])))
    return seq[len(prompt):]


def bench_decode(smoke: bool = False, out_path: str | None = None) -> dict:
    interpret = kops.interpret_default()
    backend = "reference" if interpret else "kernel"
    record: dict = {
        "mode": "interpret" if interpret else "hw",
        "smoke": smoke,
        "backend": backend,
        "arch": ARCH,
        "parity": [],
        "greedy": {},
        "plans": [],
        "serve": {},
    }
    cfg = smoke_config(ARCH)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # 3. plan-step reduction through the epilogue-fusion pipeline
    graphs, plans = {}, {}
    for phase in ("prefill", "decode"):
        g = build_decoder_graph(params, cfg, phase=phase)
        go = optimize(g)
        graphs[phase] = go
        plans[phase] = compile_plan(go, backend=backend, interpret=interpret)
        row = {
            "phase": phase,
            "steps_unfused": len(compile_plan(g, backend=backend,
                                              interpret=interpret).steps),
            "steps_fused": len(plans[phase].steps),
        }
        record["plans"].append(row)
        assert row["steps_fused"] < row["steps_unfused"], row
        print(f"decode_plan,{phase},steps={row['steps_fused']}"
              f"(unfused={row['steps_unfused']})")

    # 1. prefill parity vs the plain jnp forward -- gates in every mode
    b, s = (2, 12) if smoke else (4, 24)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    lens = jnp.full((b,), s, jnp.int32)
    want, _ = forward(params, cfg, tok)
    go = graphs["prefill"]
    outs = plans["prefill"](go.params, tok, pos, lens)
    err = float(jnp.max(jnp.abs(
        outs[0][..., : cfg.vocab] - want[..., : cfg.vocab]
    )))
    assert err <= 1e-4, err
    record["parity"].append(
        {"case": f"prefill:{backend}", "max_err": err, "tokens": b * s}
    )
    print(f"decode_parity,prefill:{backend},{err:.2e}")

    # 2. greedy decode through the paged pipeline vs the naive jnp loop
    spec = decoder_cache_spec(cfg)
    g_, dh = spec["n_kv_heads"], spec["head_dim"]
    n_new = 4 if smoke else 8
    prompt = [int(t) for t in rng.integers(0, cfg.vocab, size=5)]
    want_toks = _greedy_naive(params, cfg, prompt, n_new)
    cache = PagedKVCache(num_pages=16, page_size=4, **spec)
    cache.allocate(0)
    tok1 = jnp.asarray([prompt], jnp.int32)
    pos1 = jnp.asarray([list(range(len(prompt)))], jnp.int32)
    len1 = jnp.asarray([len(prompt)], jnp.int32)
    outs = plans["prefill"](graphs["prefill"].params, tok1, pos1, len1)
    kvs = [np.asarray(o[0]).reshape(len(prompt), g_, dh) for o in outs[1:]]
    cache.append(0, np.stack(kvs[0::2], 1), np.stack(kvs[1::2], 1))
    got = [int(np.argmax(np.asarray(outs[0])[0, -1]))]
    for _ in range(n_new - 1):
        n = cache.length(0)
        cache.ensure_capacity(0, n + 1)
        k_ctx, v_ctx, lens_d = cache.gather([0], min_tokens=n + 1)
        outs = plans["decode"](
            graphs["decode"].params, jnp.asarray([[got[-1]]], jnp.int32),
            jnp.asarray([[n]], jnp.int32), jnp.asarray(k_ctx),
            jnp.asarray(v_ctx), jnp.asarray(lens_d),
        )
        kvs = [np.asarray(o[0]).reshape(1, g_, dh) for o in outs[1:]]
        cache.append(0, np.stack(kvs[0::2], 1), np.stack(kvs[1::2], 1))
        got.append(int(np.argmax(np.asarray(outs[0])[0, -1])))
    cache.release(0)
    cache.check_invariants()
    match = got == want_toks
    record["greedy"] = {
        "backend": backend, "tokens": n_new, "match": match,
        "plan": got, "naive": want_toks,
    }
    assert match, (got, want_toks)
    print(f"decode_greedy,{backend},{n_new}tokens,match={match}")

    # 4. continuous batching through the server: mixed prompt lengths,
    # zero sequence loss, zero page leak
    n_seq = 4 if smoke else 12
    new_tokens = 4 if smoke else 8
    prompts = [
        rng.integers(0, cfg.vocab, size=int(rng.integers(3, 10))).astype(np.int32)
        for _ in range(n_seq)
    ]
    cache = PagedKVCache(num_pages=32, page_size=4, **spec)
    server = AsyncPlanServer()
    server.add_llm("lm", prefill=plans["prefill"], decode=plans["decode"],
                   cache=cache, max_batch=3)
    t0 = time.perf_counter()
    handles = [
        server.submit_llm("lm", p, max_new_tokens=new_tokens) for p in prompts
    ]
    while any(not h.done() for h in handles):
        server.step()
    dt = time.perf_counter() - t0
    lost = sum(1 for h in handles if h.exception() is not None)
    st = server.stats["per_llm"]["lm"]
    server.close()
    cache.check_invariants()
    toks = sum(len(h.result(0)) for h in handles if h.exception() is None)
    record["serve"] = {
        "sequences": n_seq, "new_tokens": new_tokens, "lost": lost,
        "generated_tokens": toks, "wall_s": dt, "tok_per_s": toks / dt,
        "prefill_batches": st["prefill_batches"],
        "decode_batches": st["decode_batches"],
        "decode_tokens": st["decode_tokens"],
        "leaked_pages": cache.used_pages,
        "peak_pages": cache.stats["peak_used"],
    }
    assert lost == 0 and cache.used_pages == 0, record["serve"]
    print(f"decode_serve,{n_seq}seq,{toks}tok,{toks / dt:.1f}tok/s,"
          f"prefill={st['prefill_batches']},decode={st['decode_batches']},"
          f"lost={lost},leaked={cache.used_pages}")

    default_name = "BENCH_decode_smoke.json" if smoke else "BENCH_decode.json"
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"decode,saved,{os.path.abspath(out_path)}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny traffic (CI, no TPU)")
    bench_decode(smoke=ap.parse_args().smoke)
