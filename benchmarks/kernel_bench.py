"""Kernel-level benchmarks.

Wall-clock of Pallas interpret mode measures the Python interpreter, not the
algorithm, so this bench reports what is *portable* from this container:

1. correctness-gated compute scaling: packed-BSR buffer sizes and MXU-tile
   counts vs density (the compute contract the TPU kernel executes);
2. measured XLA-CPU wall time of the column-compacted GEMM vs dense (the
   gather+smaller-GEMM path is real on any backend);
3. storage: PBCSR vs CSR vs dense across sparsities (the paper's
   "beats CSR" claim);
4. block-size auto-tuning: with the tuning cache enabled, sweep the candidate
   grid once per GEMM shape and report the chosen blocks (the paper's
   "parameter auto-tuning" applied to Pallas tiling);
5. fusion: the fused-elementwise Pallas kernel vs the unfused jnp chain
   (parity always asserted; the wall-clock win asserted on real hardware
   only) and ``fuse_epilogue`` plan-step reduction + parity on the three
   demo apps.  Results land in ``results/BENCH_fusion.json`` so the perf
   trajectory is recorded across PRs.
6. quant: the INT8 qmatmul kernel (W8A8 + W8-only) vs the fp32 GEMM --
   bytes-moved and parity in every mode, wall-clock speedup asserted on
   real hardware only -- and the three demo apps end-to-end through the
   ``quantize`` pass (fp32-vs-int8 plan ms, weight bytes, max-abs-error,
   parity gated at 5e-2).  Results land in ``results/BENCH_quant.json``.
7. conv: the implicit-GEMM Pallas conv2d (dense f32, channel-pruned, W8,
   W8A8 schemes) vs the lax.conv baseline, plus the three demo apps through
   kernel-backend plans -- every conv must lower through the Pallas kernel
   (zero fallbacks) at parity with the jnp reference plan, step counts at or
   below the PR 2 baseline.  Results land in ``results/BENCH_conv.json``.

``--smoke`` shrinks every shape so CI can exercise the full path without a
TPU (also reachable via ``make bench-smoke``).

Timing methodology: every sample dispatches the jitted callable and blocks
on the result via ``jax.block_until_ready``, so a sample covers dispatch +
device execution and never measures async dispatch alone.  ``--warmup``
extra calls run first (JIT compile + caches) and are discarded; ``--repeat``
timed samples are reduced with the median (robust to scheduler noise).
Baselines (lax.conv / fp32 GEMM) are timed ONCE per shape and shared across
every scheme row of that shape, so scheme-to-scheme ratios within a shape
are against the identical baseline sample.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import Block, Column, project
from repro.core.sparse import CSR, ColumnCompact, PBCSR, dense_nbytes
from repro.kernels import bsr_matmul, matmul, ref
from repro.kernels import ops as kops

K, N, M = 2048, 2048, 256

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


#: global overrides set by --repeat / --warmup (None -> per-bench default:
#: 7 samples, or 3 under --smoke; 1 warmup call)
REPEAT: int | None = None
WARMUP: int | None = None


def _median_time(fn, *args, reps=7):
    reps = REPEAT if REPEAT is not None else reps
    for _ in range(max(1, WARMUP if WARMUP is not None else 1)):
        jax.block_until_ready(fn(*args))  # compile + warm caches, discarded
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))  # sample = dispatch + execution
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_bsr_compute_scaling(k=K, n=N, m=M):
    print("kernel_bsr,density,mxu_tiles,values_bytes,correct")
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    for sp in (0.0, 0.25, 0.5, 0.75):
        if sp == 0.0:
            tiles = (k // 128) * (n // 128)
            vb = dense_nbytes((k, n), jnp.float32)
            ok = True
        else:
            wp, mask = project(w, Block(sp, bm=128, bn=128))
            fmt = PBCSR.from_dense(wp, mask, 128, 128)
            got = bsr_matmul(x[:128], fmt.values, fmt.block_rows)
            want = ref.matmul_ref(x[:128], wp)
            ok = bool(np.allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3))
            tiles = fmt.n_blocks
            vb = int(fmt.values.size) * 4
        print(f"kernel_bsr,{1-sp:.2f},{tiles},{vb},{ok}")


def bench_colcompact_walltime(k=K, n=N, m=M):
    print("kernel_colpack,density,ms_dense,ms_colpack,speedup")
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    f_dense = jax.jit(lambda x, w: x @ w)
    t_dense = _median_time(f_dense, x, w)
    for sp in (0.5, 0.75):
        wp, mask = project(w, Column(sp))
        cc = ColumnCompact.from_dense(wp, mask)
        f_cc = jax.jit(lambda x, v, k: jnp.take(x, k, axis=-1) @ v)
        t_cc = _median_time(f_cc, x, cc.values, cc.kept)
        err = float(jnp.abs(f_cc(x, cc.values, cc.kept) - x @ wp).max())
        assert err < 1e-3, err
        print(f"kernel_colpack,{1-sp:.2f},{t_dense*1e3:.2f},{t_cc*1e3:.2f},{t_dense/t_cc:.2f}")


def bench_storage(side=1024):
    print("storage,sparsity,dense_bytes,csr_bytes,pbcsr_bytes,pbcsr_vs_csr")
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (side, side)))
    for sp in (0.5, 0.75, 0.9):
        wp, mask = project(jnp.asarray(w), Block(sp, bm=128, bn=128, balanced=False))
        pb = PBCSR.from_dense(wp, mask, 128, 128)
        csr = CSR.from_dense(np.asarray(wp), np.asarray(mask))
        d = dense_nbytes((side, side), jnp.float32)
        print(f"storage,{sp},{d},{csr.nbytes},{pb.nbytes},{csr.nbytes/max(pb.nbytes,1):.2f}x")


def bench_tuned_blocks(shapes=None):
    """Enable the tuning cache, trigger one sweep per shape, report winners.

    Shapes stay small because the container runs Pallas in interpret mode;
    on real TPU hardware the same sweep times the compiled kernels.
    """
    cache = kops.tuning_cache()
    prev_enabled, prev_entries = cache.enabled, dict(cache.entries)
    cache.clear()
    cache.enabled = True
    try:
        shapes = shapes or [(8, 256, 256), (32, 512, 256), (8, 128, 512)]
        for m, n, k in shapes:
            x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.1
            w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
            matmul(x, w)  # miss -> sweep -> cached
            matmul(x, w)  # hit
        # the fused-elementwise kernel tunes under its own op key
        x = jax.random.normal(jax.random.PRNGKey(2), (shapes[0][0], 256)) * 0.1
        kops.fused_elementwise(x, [x], (("add", 0), ("activation", "relu")))
        assert cache.sweeps == len(shapes) + 1, (cache.sweeps, len(shapes) + 1)
        print("tuning," + cache.report().replace("\n", "\ntuning,"))
        out = os.environ.get("REPRO_TUNE_CACHE")
        if out:
            print(f"tuning,saved,{cache.save(out)}")
    finally:
        cache.enabled = prev_enabled
        cache.entries = prev_entries


# --------------------------------------------------------------------------- #
# fusion: fused-elementwise kernel + epilogue-program plans                    #
# --------------------------------------------------------------------------- #


def _elementwise_cases(smoke: bool):
    """(name, [M, D] view shape) pairs at table-1-ish scales: the NCHW case
    mirrors a demo-app activation map flattened over its last dim, the LM
    case a transformer residual stream."""
    if smoke:
        return [("app_nchw", (64, 128)), ("lm_residual", (32, 256))]
    return [("app_nchw", (4096, 128)), ("lm_residual", (256, 2048))]


def bench_fusion(smoke: bool = False, out_path: str | None = None) -> dict:
    interpret = kops.interpret_default()
    record: dict = {
        "mode": "interpret" if interpret else "hw",
        "smoke": smoke,
        "elementwise": [],
        "epilogue_plans": [],
    }
    print("fusion,case,steps,ms_unfused,ms_fused,speedup,bytes_unfused,bytes_fused,max_err")
    # 4-step program: activation -> residual add -> gating mul -> layer norm
    for name, (m, d) in _elementwise_cases(smoke):
        x = jax.random.normal(jax.random.PRNGKey(0), (m, d))
        r = jax.random.normal(jax.random.PRNGKey(1), (m, d))
        s = jax.random.normal(jax.random.PRNGKey(2), (m, d))
        scale, bias = jnp.ones(d) * 1.1, jnp.zeros(d) + 0.1
        steps = (("activation", "gelu"), ("add", 0), ("mul", 1), ("norm", 0, 1e-5))

        unfused = jax.jit(
            lambda x, r, s, scale, bias: ref.fused_elementwise_ref(
                x, [r, s], steps, [(scale, bias)]
            )
        )
        fused = jax.jit(
            lambda x, r, s, scale, bias: kops.fused_elementwise(
                x, [r, s], steps, [(scale, bias)]
            )
        )
        err = float(jnp.abs(fused(x, r, s, scale, bias) - unfused(x, r, s, scale, bias)).max())
        assert err < 1e-4, (name, err)  # parity gates the bench in every mode
        t_un = _median_time(unfused, x, r, s, scale, bias, reps=3 if smoke else 7)
        t_fu = _median_time(fused, x, r, s, scale, bias, reps=3 if smoke else 7)
        nb = x.size * x.dtype.itemsize
        # unfused: each step reads the running value (+1 side for add/mul)
        # and writes it back; fused: one read of x + sides, one write.
        bytes_unfused = sum(
            (3 if st[0] in ("add", "mul") else 2) * nb for st in steps
        )
        bytes_fused = (1 + 2) * nb + nb  # x + two sides in, one out
        speedup = t_un / t_fu
        if not interpret:  # interpret timings measure Python, not silicon
            assert speedup > 1.0, (name, speedup)
        row = {
            "case": name, "shape": [m, d], "n_steps": len(steps),
            "ms_unfused": t_un * 1e3, "ms_fused": t_fu * 1e3,
            "speedup": speedup, "bytes_unfused": bytes_unfused,
            "bytes_fused": bytes_fused, "max_err": err,
        }
        record["elementwise"].append(row)
        print(
            f"fusion,{name},{len(steps)},{t_un*1e3:.3f},{t_fu*1e3:.3f},"
            f"{speedup:.2f},{bytes_unfused},{bytes_fused},{err:.2e}"
        )

    # fuse_epilogue: plan-step reduction + parity on the paper's three apps
    from repro.core.graph import DEFAULT_PIPELINE, compile_plan, optimize
    from repro.models.cnn import APPS, app_masks

    no_epi = tuple(
        p for p in DEFAULT_PIPELINE if p not in ("fuse_activation", "fuse_epilogue")
    )
    size = 16 if smoke else 64
    base = 8 if smoke else 16
    print("fusion_epilogue,app,steps_unfused,steps_fused,max_err")
    for app in APPS:
        g = APPS[app](jax.random.PRNGKey(0), base=base)
        masks, structures = app_masks(g, app, sparsity=0.5)
        go = optimize(g, masks, structures)
        go0 = optimize(g, masks, structures, pipeline=no_epi)
        plan = compile_plan(go, backend="reference")
        plan0 = compile_plan(go0, backend="reference")
        c_in = 1 if app == "coloring" else 3
        x = jax.random.normal(jax.random.PRNGKey(1), (1, c_in, size, size))
        err = float(jnp.abs(plan(go.params, x) - plan0(go0.params, x)).max())
        assert len(plan.steps) < len(plan0.steps), app
        assert err < 1e-4, (app, err)
        row = {
            "app": app, "steps_unfused": len(plan0.steps),
            "steps_fused": len(plan.steps), "max_err": err,
        }
        record["epilogue_plans"].append(row)
        print(f"fusion_epilogue,{app},{len(plan0.steps)},{len(plan.steps)},{err:.2e}")

    # smoke numbers are CI plumbing, not perf data: never clobber the
    # cross-PR trajectory artifact with them
    default_name = "BENCH_fusion_smoke.json" if smoke else "BENCH_fusion.json"
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"fusion,saved,{os.path.abspath(out_path)}")
    return record


# --------------------------------------------------------------------------- #
# quant: INT8 kernels + quantized demo-app plans                               #
# --------------------------------------------------------------------------- #


def bench_quant(smoke: bool = False, out_path: str | None = None) -> dict:
    from repro.core.graph import PassContext, PassManager, compile_plan, optimize
    from repro.kernels import qmatmul
    from repro.models.cnn import APP_ACT_SKIP, APP_QUANT_SKIP, APPS, app_masks
    from repro.quant import QTensor, calibrate_plan

    interpret = kops.interpret_default()
    record: dict = {
        "mode": "interpret" if interpret else "hw",
        "smoke": smoke,
        "kernels": [],
        "apps": [],
    }

    # kernel-level: W8A8 / W8-only qmatmul vs the fp32 Pallas GEMM.
    # interpret-mode wall-clock measures Python, so shapes stay modest there;
    # bytes-moved is the portable story (weight stream shrinks 4x).
    m, n, k = (64, 128, 128) if smoke else (256, 512, 512)
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    qt = QTensor.from_float(w, axis=1)
    x_scale = float(jnp.max(jnp.abs(x))) / 127.0
    f32 = jax.jit(lambda x, w: matmul(x, w))
    t_f32 = _median_time(f32, x, w, reps=3 if smoke else 7)
    want = ref.matmul_ref(x, w)
    print("quant,scheme,MxNxK,ms_fp32,ms_int8,speedup,w_bytes_fp32,w_bytes_int8,max_err")
    for scheme, kw in (("w8", {}), ("w8a8", {"x_scale": x_scale})):
        fq = jax.jit(lambda x, v, s: qmatmul(x, v, s, **kw))
        t_q = _median_time(fq, x, qt.values, qt.scale, reps=3 if smoke else 7)
        err = float(jnp.abs(fq(x, qt.values, qt.scale) - want).max())
        # parity vs fp32 gates the bench in every mode (quantization noise
        # bounded by the per-channel scales); exactness vs the int8 oracle
        # is covered in tests/test_quant.py
        assert err <= 5e-2, (scheme, err)
        speedup = t_f32 / t_q
        if not interpret:  # interpret timings measure Python, not silicon
            assert speedup > 1.0, (scheme, speedup)
        row = {
            "scheme": scheme, "shape": [m, n, k],
            "ms_fp32": t_f32 * 1e3, "ms_int8": t_q * 1e3, "speedup": speedup,
            "w_bytes_fp32": int(w.size) * 4, "w_bytes_int8": qt.nbytes,
            "max_err": err,
        }
        record["kernels"].append(row)
        print(
            f"quant,{scheme},{m}x{n}x{k},{t_f32*1e3:.3f},{t_q*1e3:.3f},"
            f"{speedup:.2f},{int(w.size)*4},{qt.nbytes},{err:.2e}"
        )

    # app-level: calibrate -> quantize pass -> quantized plan vs fp32 plan.
    # CPU times the jnp reference executions of both (XLA-real); on TPU the
    # quant backend runs the INT8 Pallas kernels.  This subsection is a
    # *correctness* gate, so it runs at the fixed regression scale and on
    # the canonical probe shared with tests/test_quant.py in every mode:
    # max-abs error is the max over all output pixels (fat-tailed across
    # probes and growing with frame area), so gating one pinned
    # configuration keeps the 5e-2 contract a meaningful regression signal
    # across PRs (full mode only adds timing reps).
    shapes = {
        "style_transfer": (1, 3, 16, 16),
        "coloring": (1, 1, 16, 16),
        "super_resolution": (1, 3, 8, 8),
    }
    key = jax.random.PRNGKey(0)
    backend = "reference" if interpret else "quant"
    f32_backend = "reference" if interpret else "kernel"
    print("quant_app,app,backend,ms_fp32,ms_int8,w_bytes_fp32,w_bytes_int8,ratio,max_err")
    for app in APPS:
        g = APPS[app](key, base=8)
        masks, structures = app_masks(g, app, sparsity=0.5)
        go = optimize(g, masks, structures)
        plan_f = compile_plan(go, backend=f32_backend)
        batches = [
            jax.random.normal(jax.random.fold_in(key, i), shapes[app])
            for i in range(2)
        ]
        plan_ref = compile_plan(go, backend="reference")
        table = calibrate_plan(plan_ref, go.params, batches)
        gq = PassManager(("quantize",)).run(
            go,
            PassContext(
                calibration=table, quant_skip=APP_QUANT_SKIP[app],
                act_quant_skip=APP_ACT_SKIP[app],
            ),
        )
        plan_q = compile_plan(gq, backend=backend)
        x = jax.random.normal(jax.random.fold_in(key, 99), shapes[app])
        err = float(jnp.abs(plan_q(gq.params, x) - plan_f(go.params, x)).max())
        assert err <= 5e-2, (app, err)  # parity gates the bench in every mode
        mem_f = plan_f.memory_estimate(x)
        mem_q = plan_q.memory_estimate(x)
        ratio = mem_f["param_bytes"] / mem_q["param_bytes"]
        assert ratio >= 3.0, (app, ratio)
        jf = jax.jit(lambda p, x: plan_f(p, x))
        jq = jax.jit(lambda p, x: plan_q(p, x))
        t_f = _median_time(jf, go.params, x, reps=3 if smoke else 7)
        t_q = _median_time(jq, gq.params, x, reps=3 if smoke else 7)
        row = {
            "app": app, "backend": backend,
            "ms_fp32": t_f * 1e3, "ms_int8": t_q * 1e3,
            "w_bytes_fp32": mem_f["param_bytes"],
            "w_bytes_int8": mem_q["param_bytes"],
            "bytes_ratio": ratio,
            "weight_bytes_saved": mem_q["weight_bytes_saved"],
            "max_err": err,
        }
        record["apps"].append(row)
        print(
            f"quant_app,{app},{backend},{t_f*1e3:.2f},{t_q*1e3:.2f},"
            f"{mem_f['param_bytes']},{mem_q['param_bytes']},{ratio:.2f},{err:.2e}"
        )

    # smoke numbers are CI plumbing, not perf data: never clobber the
    # cross-PR trajectory artifact with them
    default_name = "BENCH_quant_smoke.json" if smoke else "BENCH_quant.json"
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"quant,saved,{os.path.abspath(out_path)}")
    return record


# --------------------------------------------------------------------------- #
# conv: implicit-GEMM Pallas kernel + kernel-backend demo-app plans            #
# --------------------------------------------------------------------------- #


def bench_conv(smoke: bool = False, out_path: str | None = None) -> dict:
    from repro.core.graph import compile_plan, optimize
    from repro.models.cnn import APPS, app_masks
    from repro.quant import QTensor

    interpret = kops.interpret_default()
    record: dict = {
        "mode": "interpret" if interpret else "hw",
        "smoke": smoke,
        "kernels": [],
        "apps": [],
    }

    # kernel-level: the implicit-GEMM Pallas conv (all three schemes) vs the
    # XLA lax.conv baseline.  interpret-mode wall-clock measures Python, so
    # shapes stay modest there; parity gates the bench in every mode, the
    # speedup is asserted on real hardware only.  The lax baseline is timed
    # ONCE per shape and shared across the four scheme rows of that shape.
    # The second (full-mode) shape is a wide-channel config whose resident-K
    # workspace overflows the hw VMEM guard: it lowers through the tiled-K
    # contraction path (block_c > 0) instead of falling back to lax.
    shape_list = (
        [(1, 8, 16, 16, 16)] if smoke
        else [(1, 32, 32, 32, 64), (1, 256, 16, 16, 64)]
    )
    key = jax.random.PRNGKey(0)
    reps = 3 if smoke else 7
    print("conv,scheme,NxCxHxW->O,ms_lax,ms_kernel,speedup,max_err")
    for n, c, h, wdt, o in shape_list:
        x = jax.random.normal(key, (n, c, h, wdt)) * 0.5
        w = jax.random.normal(jax.random.PRNGKey(1), (o, c, 3, 3)) * 0.05
        b = jax.random.normal(jax.random.PRNGKey(2), (o,)) * 0.1
        qt = QTensor.from_float(w, axis=0)
        kept = jnp.asarray(np.arange(0, c, 2), jnp.int32)  # half channels live
        x_scale = float(jnp.max(jnp.abs(x))) / 127.0
        base = jax.jit(
            lambda x, w, b: ref.conv2d_ref(x, w, b, stride=1, padding="SAME")
        )
        t_lax = _median_time(base, x, w, b, reps=reps)  # once per shape
        want = base(x, w, b)
        f_dense = jax.jit(lambda x, w, b: kops.conv2d(x, w, b))
        f_chan = jax.jit(lambda x, w, b: kops.conv2d(x, w[:, ::2], b, kept=kept))
        f_w8 = jax.jit(lambda x, v, s, b: kops.conv2d(x, v, b, w_scale=s))
        f_w8a8 = jax.jit(
            lambda x, v, s, b: kops.conv2d(x, v, b, w_scale=s, x_scale=x_scale)
        )
        want_chan = ref.conv2d_ref(jnp.take(x, kept, axis=1), w[:, ::2], b)
        # int8 parity tolerance: a8 rounding noise accumulates over the
        # K = C*kh*kw contraction (~sqrt(K) growth), so the wide-channel
        # shape gets a proportionally wider bound than the 32-channel one
        tol8 = max(5e-2, 5e-2 * (c / 32) ** 0.5)
        cases = (
            ("dense+f32", lambda: f_dense(x, w, b), want, 1e-4),
            ("chanprune+f32", lambda: f_chan(x, w, b), want_chan, 1e-4),
            ("dense+w8", lambda: f_w8(x, qt.values, qt.scale, b), want, tol8),
            ("dense+w8a8", lambda: f_w8a8(x, qt.values, qt.scale, b), want, tol8),
        )
        for scheme, fn, target, tol in cases:
            t_k = _median_time(fn, reps=reps)
            err = float(jnp.abs(fn() - target).max())
            # parity gates the bench in every mode (int8 schemes against the
            # fp32 baseline carry bounded quantization noise)
            assert err <= tol, (scheme, err, tol)
            speedup = t_lax / t_k
            if not interpret:  # interpret timings measure Python, not silicon
                assert speedup > 1.0, (scheme, speedup)
            row = {
                "scheme": scheme, "shape": [n, c, h, wdt, o],
                "ms_lax": t_lax * 1e3, "ms_kernel": t_k * 1e3,
                "speedup": speedup, "max_err": err,
            }
            record["kernels"].append(row)
            print(
                f"conv,{scheme},{n}x{c}x{h}x{wdt}->{o},{t_lax*1e3:.3f},"
                f"{t_k*1e3:.3f},{speedup:.2f},{err:.2e}"
            )

    # app-level acceptance: every conv of the three demo apps lowers through
    # the Pallas kernel (zero fallbacks), at parity with the jnp reference
    # plan, with plan step counts at or below the PR 2 baseline.
    step_caps = {"style_transfer": 33, "coloring": 30, "super_resolution": 37}
    shapes = {
        "style_transfer": (1, 3, 16, 16),
        "coloring": (1, 1, 16, 16),
        "super_resolution": (1, 3, 8, 8),
    }
    print("conv_app,app,steps,convs,fallbacks,ms_reference,ms_kernel,max_err")
    for app in APPS:
        g = APPS[app](key, base=8 if smoke else 16)
        masks, structures = app_masks(g, app, sparsity=0.5)
        go = optimize(g, masks, structures)
        plan_k = compile_plan(go, backend="kernel")
        plan_r = compile_plan(go, backend="reference")
        assert len(plan_k.steps) <= step_caps[app], (app, len(plan_k.steps))
        xa = jax.random.normal(jax.random.PRNGKey(3), shapes[app])
        kops.reset_conv_fallbacks()
        yk = plan_k(go.params, xa)  # eager: fallback counters see every call
        fallbacks = kops.conv_fallback_counts()
        assert not fallbacks, (app, fallbacks)
        err = float(jnp.abs(yk - plan_r(go.params, xa)).max())
        assert err <= 1e-4, (app, err)  # parity gates the bench in every mode
        n_conv = sum(1 for s in plan_k.steps if s.node.op == "conv2d")
        jk = jax.jit(lambda p, x: plan_k(p, x))
        jr = jax.jit(lambda p, x: plan_r(p, x))
        t_r = _median_time(jr, go.params, xa, reps=reps)
        t_k = _median_time(jk, go.params, xa, reps=reps)
        row = {
            "app": app, "plan_steps": len(plan_k.steps), "conv_steps": n_conv,
            "fallbacks": fallbacks, "ms_reference": t_r * 1e3,
            "ms_kernel": t_k * 1e3, "max_err": err,
        }
        record["apps"].append(row)
        print(
            f"conv_app,{app},{len(plan_k.steps)},{n_conv},{fallbacks},"
            f"{t_r*1e3:.2f},{t_k*1e3:.2f},{err:.2e}"
        )

    # smoke numbers are CI plumbing, not perf data: never clobber the
    # cross-PR trajectory artifact with them
    default_name = "BENCH_conv_smoke.json" if smoke else "BENCH_conv.json"
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"conv,saved,{os.path.abspath(out_path)}")
    return record


def main(smoke: bool = False):
    if smoke:
        bench_bsr_compute_scaling(k=256, n=256, m=128)
        bench_colcompact_walltime(k=256, n=256, m=64)
        bench_storage(side=256)
        bench_tuned_blocks(shapes=[(8, 128, 128)])
        bench_fusion(smoke=True)
        bench_quant(smoke=True)
        bench_conv(smoke=True)
    else:
        bench_bsr_compute_scaling()
        bench_colcompact_walltime()
        bench_storage()
        bench_tuned_blocks()
        bench_fusion()
        bench_quant()
        bench_conv()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI, no TPU)")
    ap.add_argument("--repeat", type=int, default=None,
                    help="timed samples per measurement (default 7, 3 in "
                         "smoke); each sample blocks on the result")
    ap.add_argument("--warmup", type=int, default=None,
                    help="discarded warm-up calls before timing (default 1; "
                         "covers JIT compile)")
    cli = ap.parse_args()
    REPEAT, WARMUP = cli.repeat, cli.warmup
    main(smoke=cli.smoke)
