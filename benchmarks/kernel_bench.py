"""Kernel-level benchmarks.

Wall-clock of Pallas interpret mode measures the Python interpreter, not the
algorithm, so this bench reports what is *portable* from this container:

1. correctness-gated compute scaling: packed-BSR buffer sizes and MXU-tile
   counts vs density (the compute contract the TPU kernel executes);
2. measured XLA-CPU wall time of the column-compacted GEMM vs dense (the
   gather+smaller-GEMM path is real on any backend);
3. storage: PBCSR vs CSR vs dense across sparsities (the paper's
   "beats CSR" claim);
4. block-size auto-tuning: with the tuning cache enabled, sweep the candidate
   grid once per GEMM shape and report the chosen blocks (the paper's
   "parameter auto-tuning" applied to Pallas tiling).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import Block, Column, project
from repro.core.sparse import CSR, ColumnCompact, PBCSR, dense_nbytes
from repro.kernels import bsr_matmul, matmul, ref
from repro.kernels import ops as kops

K, N, M = 2048, 2048, 256


def _median_time(fn, *args, reps=7):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_bsr_compute_scaling():
    print("kernel_bsr,density,mxu_tiles,values_bytes,correct")
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    for sp in (0.0, 0.25, 0.5, 0.75):
        if sp == 0.0:
            tiles = (K // 128) * (N // 128)
            vb = dense_nbytes((K, N), jnp.float32)
            ok = True
        else:
            wp, mask = project(w, Block(sp, bm=128, bn=128))
            fmt = PBCSR.from_dense(wp, mask, 128, 128)
            got = bsr_matmul(x[:128], fmt.values, fmt.block_rows)
            want = ref.matmul_ref(x[:128], wp)
            ok = bool(np.allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3))
            tiles = fmt.n_blocks
            vb = int(fmt.values.size) * 4
        print(f"kernel_bsr,{1-sp:.2f},{tiles},{vb},{ok}")


def bench_colcompact_walltime():
    print("kernel_colpack,density,ms_dense,ms_colpack,speedup")
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    f_dense = jax.jit(lambda x, w: x @ w)
    t_dense = _median_time(f_dense, x, w)
    for sp in (0.5, 0.75):
        wp, mask = project(w, Column(sp))
        cc = ColumnCompact.from_dense(wp, mask)
        f_cc = jax.jit(lambda x, v, k: jnp.take(x, k, axis=-1) @ v)
        t_cc = _median_time(f_cc, x, cc.values, cc.kept)
        err = float(jnp.abs(f_cc(x, cc.values, cc.kept) - x @ wp).max())
        assert err < 1e-3, err
        print(f"kernel_colpack,{1-sp:.2f},{t_dense*1e3:.2f},{t_cc*1e3:.2f},{t_dense/t_cc:.2f}")


def bench_storage():
    print("storage,sparsity,dense_bytes,csr_bytes,pbcsr_bytes,pbcsr_vs_csr")
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (1024, 1024)))
    for sp in (0.5, 0.75, 0.9):
        wp, mask = project(jnp.asarray(w), Block(sp, bm=128, bn=128, balanced=False))
        pb = PBCSR.from_dense(wp, mask, 128, 128)
        csr = CSR.from_dense(np.asarray(wp), np.asarray(mask))
        d = dense_nbytes((1024, 1024), jnp.float32)
        print(f"storage,{sp},{d},{csr.nbytes},{pb.nbytes},{csr.nbytes/max(pb.nbytes,1):.2f}x")


def bench_tuned_blocks():
    """Enable the tuning cache, trigger one sweep per shape, report winners.

    Shapes stay small because the container runs Pallas in interpret mode;
    on real TPU hardware the same sweep times the compiled kernels.
    """
    cache = kops.tuning_cache()
    prev_enabled, prev_entries = cache.enabled, dict(cache.entries)
    cache.clear()
    cache.enabled = True
    try:
        shapes = [(8, 256, 256), (32, 512, 256), (8, 128, 512)]
        for m, n, k in shapes:
            x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.1
            w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
            matmul(x, w)  # miss -> sweep -> cached
            matmul(x, w)  # hit
        assert cache.sweeps == len(shapes), (cache.sweeps, len(shapes))
        print("tuning," + cache.report().replace("\n", "\ntuning,"))
        out = os.environ.get("REPRO_TUNE_CACHE")
        if out:
            print(f"tuning,saved,{cache.save(out)}")
    finally:
        cache.enabled = prev_enabled
        cache.entries = prev_entries


def main():
    bench_bsr_compute_scaling()
    bench_colcompact_walltime()
    bench_storage()
    bench_tuned_blocks()


if __name__ == "__main__":
    main()
