"""Observability overhead benchmark: tracing must be (nearly) free.

The telemetry contract (ARCHITECTURE.md section 8) promises two ceilings,
both gated here against a **bare-loop baseline** -- a re-implementation of
the executor's untraced step loop with ZERO obs code in it (no ``enabled()``
branch, no argument validation, no observer checks), so the measured ratios
charge the instrumentation for everything it adds:

1. **disabled-mode <= 1%** -- with tracing off, ``plan(params, x)`` may cost
   at most 1% over the bare loop.  The disabled path is one module-flag
   check per run plus the shared stateless ``NULL_SPAN`` -- this gate is
   what keeps per-step spans out of the hot loop when nobody is looking.
2. **traced-mode <= 5%** -- with a tracing session armed, the full per-step
   span machinery (one ``cat="plan"`` span + one ``cat="step"`` span per
   step, out-shape annotation included) may cost at most 5% end-to-end on
   the eager reference plans.

Timing discipline: the three variants are interleaved round-robin (so a
frequency-scaling drift hits all of them equally) and each is scored by its
**min over reps** -- the noise-robust statistic for lower-bounded wall-clock.
Because a 1% gate on millisecond-scale Python loops still flakes under CI
jitter, each app gets up to ``--attempts`` independent measurement rounds
and keeps its best (lowest-overhead) round; the gate fails only if every
attempt missed.  Also recorded: registry exporter sizes + snapshot cost for
a serving-shaped registry, and a profiler self-check.

Writes ``results/BENCH_obs.json`` (``--smoke``: ``BENCH_obs_smoke.json``,
wired into ``make bench-smoke``); gates feed the cross-PR floors in
``benchmarks/trajectory.py``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import compile_plan
from repro.models.cnn import APPS
from repro.obs import metrics, profile_plan, trace

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

DISABLED_CEIL = 1.01  # disabled-mode overhead vs bare loop
TRACED_CEIL = 1.05  # traced-mode overhead vs bare loop


def _bare_runner(plan):
    """The executor's untraced step loop with all obs/validation stripped:
    the honest baseline the instrumentation is charged against."""
    handlers, rt = plan._handlers, plan._rt
    steps, inputs, outputs = plan.steps, plan.graph.inputs, plan.graph.outputs

    def run(params, *args):
        env = dict(zip(inputs, args))
        for step in steps:
            n = step.node
            xs = [env[i] for i in n.inputs]
            env[n.name] = handlers[n.op](params.get(n.name, {}), xs, n.attrs, rt)
            for f in step.frees:
                del env[f]
        outs = tuple(env[o] for o in outputs)
        return outs[0] if len(outs) == 1 else outs

    return run


def _measure_once(plan, params, x, reps: int) -> dict:
    """One interleaved round: min-of-reps wall ms for bare / disabled /
    traced, plus the traced run's event count."""
    bare = _bare_runner(plan)
    assert not trace.enabled()
    # warm every variant (jit caches, allocator) outside the timed window
    jax.block_until_ready(bare(params, x))
    jax.block_until_ready(plan(params, x))
    with trace.tracing():
        jax.block_until_ready(plan(params, x))
    t = {"bare": [], "disabled": [], "traced": []}
    events_per_run = 0
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(bare(params, x))
        t["bare"].append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(plan(params, x))
        t["disabled"].append(time.perf_counter() - t0)
        with trace.tracing() as buf:
            t0 = time.perf_counter()
            jax.block_until_ready(plan(params, x))
            t["traced"].append(time.perf_counter() - t0)
        events_per_run = len(buf)
    ms = {k: float(np.min(v)) * 1e3 for k, v in t.items()}
    return {
        "bare_ms": ms["bare"],
        "disabled_ms": ms["disabled"],
        "traced_ms": ms["traced"],
        "disabled_overhead": ms["disabled"] / ms["bare"],
        "traced_overhead": ms["traced"] / ms["bare"],
        "events_per_run": events_per_run,
    }


def bench_obs(smoke: bool = False, out_path: str | None = None,
              attempts: int = 5) -> dict:
    record: dict = {
        "mode": "interpret",  # eager reference plans: wall-clock is Python
        "smoke": smoke,
        "ceilings": {"disabled": DISABLED_CEIL, "traced": TRACED_CEIL},
        "overhead": [],
        "registry": {},
        "profiler": {},
    }
    base, size = (8, 12) if smoke else (16, 24)
    reps = 20 if smoke else 40
    rng = np.random.default_rng(0)

    # 1. per-app overhead gates (best-of-attempts; see module docstring)
    print("obs_overhead,app,bare_ms,disabled_ms,traced_ms,"
          "disabled_ovh,traced_ovh,attempts")
    for app in APPS:
        g = APPS[app](jax.random.PRNGKey(0), base=base)
        plan = compile_plan(g, backend="reference")
        c = 1 if app == "coloring" else 3
        x = jnp.asarray(rng.standard_normal((1, c, size, size)), jnp.float32)
        best = None
        for attempt in range(1, attempts + 1):
            m = _measure_once(plan, g.params, x, reps)
            if best is None or (
                max(m["disabled_overhead"] - DISABLED_CEIL,
                    m["traced_overhead"] - TRACED_CEIL)
                < max(best["disabled_overhead"] - DISABLED_CEIL,
                      best["traced_overhead"] - TRACED_CEIL)
            ):
                best = m
            if (best["disabled_overhead"] <= DISABLED_CEIL
                    and best["traced_overhead"] <= TRACED_CEIL):
                break
        row = {"app": app, "steps": len(plan.steps),
               "attempts": attempt, **best}
        record["overhead"].append(row)
        print(f"obs_overhead,{app},{row['bare_ms']:.3f},"
              f"{row['disabled_ms']:.3f},{row['traced_ms']:.3f},"
              f"{row['disabled_overhead']:.4f},{row['traced_overhead']:.4f},"
              f"{attempt}")
        assert row["disabled_overhead"] <= DISABLED_CEIL, row
        assert row["traced_overhead"] <= TRACED_CEIL, row
        # traced run really traced: plan span + one span per step, paired
        assert row["events_per_run"] == 2 * (len(plan.steps) + 1), row

    # 2. registry exporter cost on a serving-shaped registry
    reg = metrics.MetricsRegistry()
    n_series = 30 if smoke else 120
    for i in range(n_series):
        reg.counter("bench_events_total", plan=f"p{i % 8}", event=f"e{i}").inc(i)
        h = reg.histogram("bench_latency_seconds", plan=f"p{i % 8}")
        h.observe(0.001 * (i + 1))
    t0 = time.perf_counter()
    snap = reg.snapshot()
    snap_us = (time.perf_counter() - t0) * 1e6
    record["registry"] = {
        "series": n_series,
        "snapshot_us": snap_us,
        "json_bytes": len(reg.to_json()),
        "prometheus_bytes": len(reg.to_prometheus()),
        "families": len(snap),
    }
    print(f"obs_registry,series={n_series},snapshot_us={snap_us:.1f},"
          f"json_bytes={record['registry']['json_bytes']},"
          f"prom_bytes={record['registry']['prometheus_bytes']}")

    # 3. profiler self-check: rows == steps, shares sum to 100%
    app = "style_transfer"
    g = APPS[app](jax.random.PRNGKey(0), base=base)
    plan = compile_plan(g, backend="reference")
    x = jnp.asarray(rng.standard_normal((1, 3, size, size)), jnp.float32)
    prof = profile_plan(plan, g.params, x, runs=2, warmup=1)
    pct_sum = float(sum(s.pct for s in prof.steps))
    record["profiler"] = {
        "app": app,
        "rows": len(prof.steps),
        "steps": len(plan.steps),
        "total_ms": prof.total_ms,
        "pct_sum": pct_sum,
        "trace_events": len(prof.trace),
    }
    assert len(prof.steps) == len(plan.steps)
    assert abs(pct_sum - 100.0) < 1e-6
    print(f"obs_profiler,{app},rows={len(prof.steps)},"
          f"total_ms={prof.total_ms:.2f}")

    default_name = "BENCH_obs_smoke.json" if smoke else "BENCH_obs.json"
    out_path = out_path or os.path.join(RESULTS_DIR, default_name)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"obs,saved,{os.path.abspath(out_path)}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI)")
    ap.add_argument("--attempts", type=int, default=5,
                    help="measurement rounds per app; keep the best")
    args = ap.parse_args()
    bench_obs(smoke=args.smoke, attempts=args.attempts)
