"""Table 1 analogue: the paper's three apps under
{unpruned, pruned, pruned+compiler} on this host's XLA-CPU.

The paper measured ms/frame on a Galaxy S10 (Adreno 640); we measure the same
three-way contrast on CPU-XLA (absolute numbers differ; the *shape* of the
table -- monotone speedups from pruning and again from the compiler passes --
is the reproduction target).  FLOP counts come from XLA cost analysis of the
lowered graphs, so the compiler claim is hardware-independent.

Paper Table 1 (ms):     style 283/178/67   coloring 137/85/38   SR 269/192/73
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import compile_plan, lower, optimize
from repro.utils.jax_compat import cost_analysis
from repro.core.graph.ir import Graph
from repro.models.cnn import (  # noqa: F401  (re-exported for tests/scripts)
    APPS,
    PAPER_RECIPE,
    PAPER_TABLE1,
    _channel_mask,
    _pattern_mask,
    app_masks,
)

INPUT_SHAPES = {
    "style_transfer": (1, 3, 128, 128),
    "coloring": (1, 1, 128, 128),
    "super_resolution": (1, 3, 96, 96),
}

#: ``--smoke`` (make bench-smoke): tiny frames so CI exercises the full
#: measurement path -- the numbers are not meaningful at this scale
SMOKE_SHAPES = {
    "style_transfer": (1, 3, 32, 32),
    "coloring": (1, 1, 32, 32),
    "super_resolution": (1, 3, 16, 16),
}


# --------------------------------------------------------------------------- #
# measurement                                                                  #
# --------------------------------------------------------------------------- #


def count_graph_flops(g: Graph, x_shape: Tuple[int, ...]) -> float:
    fn = lower(g, use_kernels=False)
    x = jax.ShapeDtypeStruct(x_shape, jnp.float32)
    params = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), g.params)
    lowered = jax.jit(fn).lower(params, x)
    return float(cost_analysis(lowered.compile()).get("flops", 0.0))


def graph_param_bytes(g: Graph) -> int:
    return int(sum(np.asarray(v).nbytes for v in jax.tree.leaves(g.params)))


def _time_call(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_app(
    app: str, sparsity: float = 0.5, base: int = 32, reps: int = 5,
    shapes: Dict[str, Tuple[int, ...]] = INPUT_SHAPES,
) -> Dict[str, Dict]:
    g = APPS[app](jax.random.PRNGKey(0), base=base)
    x = jax.random.normal(jax.random.PRNGKey(1), shapes[app], jnp.float32)

    # 1) unpruned
    f_dense = jax.jit(lower(g, use_kernels=False))
    t_dense = _time_call(f_dense, g.params, x, reps=reps)

    # 2) pruned (masked dense: ADMM output before any compiler work)
    masks, structures = app_masks(g, app, sparsity)
    pm = {
        k: ({**v, "w": v["w"] * masks[k]} if k in masks else v)
        for k, v in g.params.items()
    }
    t_pruned = _time_call(f_dense, pm, x, reps=reps)

    # 3) pruned + compiler (PassManager pipeline -> execution plan)
    go = optimize(g, masks, structures)
    plan = compile_plan(go, backend="reference")
    f_opt = jax.jit(plan)
    t_opt = _time_call(f_opt, go.params, x, reps=reps)
    mem = plan.memory_estimate(jax.ShapeDtypeStruct(shapes[app], jnp.float32))

    flops = {
        "unpruned": count_graph_flops(g, shapes[app]),
        "pruned_compiler": count_graph_flops(go, shapes[app]),
    }
    bytes_ = {"unpruned": graph_param_bytes(g), "pruned_compiler": graph_param_bytes(go)}
    # numerical agreement between pruned and pruned+compiler
    err = float(jnp.abs(f_dense(pm, x) - f_opt(go.params, x)).max())
    return {
        "ms": {"unpruned": t_dense * 1e3, "pruned": t_pruned * 1e3, "pruned_compiler": t_opt * 1e3},
        "flops": flops,
        "param_bytes": bytes_,
        "agreement_max_err": err,
        "paper_ms": PAPER_TABLE1[app],
        "plan_steps": len(plan.steps),
        "peak_activation_bytes": mem["peak_activation_bytes"],
    }


def main(smoke: bool = False) -> None:
    print("app,variant,ms_per_frame,flops,param_bytes,paper_ms")
    for app in APPS:
        r = (
            bench_app(app, base=8, reps=2, shapes=SMOKE_SHAPES)
            if smoke
            else bench_app(app)
        )
        for variant in ("unpruned", "pruned", "pruned_compiler"):
            print(
                f"{app},{variant},{r['ms'][variant]:.2f},"
                f"{r['flops'].get(variant if variant != 'pruned' else 'unpruned', 0):.3e},"
                f"{r['param_bytes'].get(variant if variant != 'pruned' else 'unpruned', 0)},"
                f"{r['paper_ms'][variant]}"
            )
        sp = r["ms"]["unpruned"] / r["ms"]["pruned_compiler"]
        psp = r["paper_ms"]["unpruned"] / r["paper_ms"]["pruned_compiler"]
        print(
            f"# {app}: ours {sp:.2f}x end-to-end (paper {psp:.2f}x); "
            f"flop cut {r['flops']['unpruned'] / max(r['flops']['pruned_compiler'],1):.2f}x; "
            f"agreement {r['agreement_max_err']:.2e}; "
            f"plan {r['plan_steps']} steps, peak act {r['peak_activation_bytes']/1e6:.2f} MB"
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI, no TPU)")
    main(smoke=ap.parse_args().smoke)
