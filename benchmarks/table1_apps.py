"""Table 1 analogue: the paper's three apps under
{unpruned, pruned, pruned+compiler} on this host's XLA-CPU.

The paper measured ms/frame on a Galaxy S10 (Adreno 640); we measure the same
three-way contrast on CPU-XLA (absolute numbers differ; the *shape* of the
table -- monotone speedups from pruning and again from the compiler passes --
is the reproduction target).  FLOP counts come from XLA cost analysis of the
lowered graphs, so the compiler claim is hardware-independent.

Paper Table 1 (ms):     style 283/178/67   coloring 137/85/38   SR 269/192/73
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import lower, optimize
from repro.core.graph.ir import Graph
from repro.core.pruning import Column, PatternKernel, project
from repro.core.pruning.projections import _pattern_library
from repro.models.cnn import APPS, PAPER_RECIPE, PAPER_TABLE1

INPUT_SHAPES = {
    "style_transfer": (1, 3, 128, 128),
    "coloring": (1, 1, 128, 128),
    "super_resolution": (1, 3, 96, 96),
}


# --------------------------------------------------------------------------- #
# the paper's pruning recipes on conv graphs                                   #
# --------------------------------------------------------------------------- #


def _channel_mask(w, keep_frac: float):
    """Kill the lowest-energy input channels entirely.  [Co, Ci, kh, kw]."""
    energy = jnp.sum(w.astype(jnp.float32) ** 2, axis=(0, 2, 3))  # [Ci]
    ci = w.shape[1]
    n_keep = max(1, int(round(ci * keep_frac)))
    thresh = jnp.sort(energy)[ci - n_keep]
    return (energy >= thresh).astype(w.dtype)[None, :, None, None] * jnp.ones_like(w)


def _pattern_mask(w, connectivity_channels: float):
    """Per-kernel best pattern + channel-granular connectivity pruning."""
    st = PatternKernel()
    _, mask = project(w, st)
    if connectivity_channels > 0:
        mask = mask * _channel_mask(w, 1.0 - connectivity_channels)
    return mask


def app_masks(g: Graph, app: str, sparsity: float = 0.5):
    """Masks + structure metadata per the paper's recipe for ``app``."""
    recipe = PAPER_RECIPE[app]
    masks, structures = {}, {}
    for node in g.nodes:
        p = g.params.get(node.name, {})
        w = p.get("w")
        if w is None:
            continue
        if node.op == "conv2d":
            if w.shape[1] <= 4:  # never prune the image-input conv
                continue
            if recipe == "column":
                # column pruning at channel granularity (TPU-exploitable)
                masks[node.name] = _channel_mask(w, 1.0 - sparsity)
                structures[node.name] = Column(sparsity)
            else:
                if w.shape[2] != 3:
                    continue  # patterns are defined for 3x3 kernels
                masks[node.name] = _pattern_mask(w, sparsity)
                structures[node.name] = PatternKernel(connectivity=sparsity)
        elif node.op == "linear" and w.shape[0] >= 64:
            wp, m = project(w, Column(sparsity))
            masks[node.name] = m
            structures[node.name] = Column(sparsity)
    return masks, structures


# --------------------------------------------------------------------------- #
# measurement                                                                  #
# --------------------------------------------------------------------------- #


def count_graph_flops(g: Graph, x_shape: Tuple[int, ...]) -> float:
    fn = lower(g, use_kernels=False)
    x = jax.ShapeDtypeStruct(x_shape, jnp.float32)
    params = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), g.params)
    lowered = jax.jit(fn).lower(params, x)
    return float(lowered.compile().cost_analysis().get("flops", 0.0))


def graph_param_bytes(g: Graph) -> int:
    return int(sum(np.asarray(v).nbytes for v in jax.tree.leaves(g.params)))


def _time_call(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_app(app: str, sparsity: float = 0.5, base: int = 32) -> Dict[str, Dict]:
    g = APPS[app](jax.random.PRNGKey(0), base=base)
    x = jax.random.normal(jax.random.PRNGKey(1), INPUT_SHAPES[app], jnp.float32)

    # 1) unpruned
    f_dense = jax.jit(lower(g, use_kernels=False))
    t_dense = _time_call(f_dense, g.params, x)

    # 2) pruned (masked dense: ADMM output before any compiler work)
    masks, structures = app_masks(g, app, sparsity)
    pm = {
        k: ({**v, "w": v["w"] * masks[k]} if k in masks else v)
        for k, v in g.params.items()
    }
    t_pruned = _time_call(f_dense, pm, x)

    # 3) pruned + compiler (norm-fold, act-fuse, sparse substitution, DCE)
    go = optimize(g, masks, structures)
    f_opt = jax.jit(lower(go, use_kernels=False))
    t_opt = _time_call(f_opt, go.params, x)

    flops = {
        "unpruned": count_graph_flops(g, INPUT_SHAPES[app]),
        "pruned_compiler": count_graph_flops(go, INPUT_SHAPES[app]),
    }
    bytes_ = {"unpruned": graph_param_bytes(g), "pruned_compiler": graph_param_bytes(go)}
    # numerical agreement between pruned and pruned+compiler
    err = float(jnp.abs(f_dense(pm, x) - f_opt(go.params, x)).max())
    return {
        "ms": {"unpruned": t_dense * 1e3, "pruned": t_pruned * 1e3, "pruned_compiler": t_opt * 1e3},
        "flops": flops,
        "param_bytes": bytes_,
        "agreement_max_err": err,
        "paper_ms": PAPER_TABLE1[app],
    }


def main() -> None:
    print("app,variant,ms_per_frame,flops,param_bytes,paper_ms")
    for app in APPS:
        r = bench_app(app)
        for variant in ("unpruned", "pruned", "pruned_compiler"):
            print(
                f"{app},{variant},{r['ms'][variant]:.2f},"
                f"{r['flops'].get(variant if variant != 'pruned' else 'unpruned', 0):.3e},"
                f"{r['param_bytes'].get(variant if variant != 'pruned' else 'unpruned', 0)},"
                f"{r['paper_ms'][variant]}"
            )
        sp = r["ms"]["unpruned"] / r["ms"]["pruned_compiler"]
        psp = r["paper_ms"]["unpruned"] / r["paper_ms"]["pruned_compiler"]
        print(
            f"# {app}: ours {sp:.2f}x end-to-end (paper {psp:.2f}x); "
            f"flop cut {r['flops']['unpruned'] / max(r['flops']['pruned_compiler'],1):.2f}x; "
            f"agreement {r['agreement_max_err']:.2e}"
        )


if __name__ == "__main__":
    main()
