"""Benchmark harness (deliverable d): one section per paper table/figure,
plus the roofline summary from the dry-run artifacts.

  table1   -> benchmarks/table1_apps.py   (paper Table 1, 3 apps x 3 variants)
  kernels  -> benchmarks/kernel_bench.py  (sparse-execution + storage tables)
  fusion   -> benchmarks/kernel_bench.py::bench_fusion
              (fused-elementwise kernel + fuse_epilogue plans; writes
              results/BENCH_fusion.json)
  admm     -> benchmarks/admm_bench.py    (pruning convergence/quality)
  roofline -> results/dryrun summary      (EXPERIMENTS.md section Roofline)

Output: CSV-ish lines ``name,...`` per table.
"""

from __future__ import annotations

import glob
import json
import os
import sys


def _roofline_summary() -> None:
    base = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    files = sorted(glob.glob(os.path.join(base, "*__single.json")))
    if not files:
        print("roofline,SKIP(no dry-run artifacts; run python -m repro.launch.dryrun --all)")
        return
    from repro.launch.roofline import analyze_record

    print("roofline,arch,shape,dominant,t_compute_s,t_memory_s,t_collective_s,useful,frac")
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "run":
            continue
        a = analyze_record(rec)
        if a is None:
            print(f"roofline,{rec['arch']},{rec['shape']},FAILED,,,,,")
            continue
        print(
            f"roofline,{a['arch']},{a['shape']},{a['dominant']},"
            f"{a['t_compute_s']:.5f},{a['t_memory_s']:.5f},{a['t_collective_s']:.5f},"
            f"{a['useful_ratio']:.2f},{a['roofline_fraction']:.2f}"
        )


def main() -> None:
    sections = sys.argv[1:] or ["table1", "kernels", "fusion", "admm", "roofline"]
    if "table1" in sections:
        from . import table1_apps

        table1_apps.main()
    if "kernels" in sections:
        from . import kernel_bench

        kernel_bench.main()  # includes the fusion section + BENCH_fusion.json
    elif "fusion" in sections:
        from . import kernel_bench

        kernel_bench.bench_fusion()
    if "admm" in sections:
        from . import admm_bench

        admm_bench.main()
    if "roofline" in sections:
        _roofline_summary()


if __name__ == "__main__":
    main()
