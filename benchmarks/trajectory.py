"""Cross-PR bench regression trajectory.

``results/BENCH_*.json`` snapshots are one-shot: each bench overwrites its
file, so a perf regression only shows up if someone diffs the JSON by hand.
This module merges every committed snapshot into a single, *accumulating*
``results/BENCH_trajectory.json`` keyed by ``PR -> bench -> case``, and
asserts **floors** over the merged trajectory:

* **parity always** -- every case with a ``max_err`` is gated in every mode
  (f32 cases at 1e-4, int8 schemes at the repo-wide 5e-2 contract);
* **interpret-mode ratio floors** for the known-slow cases -- interpret-mode
  wall-clock measures the Python interpreter, not silicon, so speedups are
  *not* asserted > 1 there; instead each case carries a floor pinned just
  under its measured ratio so a regression (e.g. a kernel suddenly running
  4x more grid steps) still fails CI.  A ``note`` on the floor documents
  why the case is slow when it is;
* **hw-only speedup gates** -- any kernel case recorded from a real-TPU run
  (``mode == "hw"``) must beat its baseline outright (> 1.0).

Usage::

  python -m benchmarks.trajectory --merge --pr 6   # after a full bench run
  python -m benchmarks.trajectory --check          # CI / make bench-smoke

``--merge`` reads the full-mode ``BENCH_*.json`` files (smoke files are CI
plumbing, except the committed serving parity reference), updates the PR's
entry in the trajectory file, then runs the checker.  ``--check`` loads the
committed trajectory and asserts every floor on every recorded PR -- this is
the step wired into ``make bench-smoke`` and CI, so a floor regression fails
the smoke job even though CI never runs the full benches.
"""

from __future__ import annotations

import argparse
import fnmatch
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
TRAJECTORY = "BENCH_trajectory.json"

# --------------------------------------------------------------------------- #
# floors                                                                       #
# --------------------------------------------------------------------------- #
#
# Keyed ``(bench, case-pattern)`` (fnmatch).  Fields:
#   max_err        parity ceiling, asserted in every mode
#   min_ratio      interpret-mode speedup floor (hw runs use the > 1.0 gate
#                  instead); pinned just under the measured ratio
#   max_steps      plan-step ceiling (fusion acceptance)
#   zero_fallbacks every conv lowered through the Pallas kernel
#   min_ratio_note documentation for why a floor sits below 1.0

FLOORS: dict = {
    # conv kernel interpret ratios compare a fixed ~1ms-per-grid-step Python
    # dispatch floor against an XLA-CPU baseline that scales with host CPU
    # speed, so they are machine-dependent: the PR-4 container measured
    # dense+f32 at 0.96x where this one measures ~0.5x on identical code.
    # Floors sit below the slowest host observed; the real perf contract is
    # the hw-mode gate (speedup > 1.0), asserted whenever mode != interpret.
    ("conv", "kernel:dense+f32:*"): {"max_err": 1e-4, "min_ratio": 0.25},
    ("conv", "kernel:chanprune+f32:*"): {"max_err": 1e-4, "min_ratio": 0.3},
    ("conv", "kernel:dense+w8:*"): {"max_err": 1.5e-1, "min_ratio": 0.25},
    ("conv", "kernel:dense+w8a8:*"): {
        "max_err": 1.5e-1,
        "min_ratio": 0.06,
        "min_ratio_note": (
            "w8a8 interpret ratio is an XLA-CPU artifact, not a kernel "
            "property: the baseline lax.conv runs XLA's fast f32 path while "
            "the interpreted kernel's int8xint8->int32 jnp.dot lowers to "
            "XLA-CPU's slow integer GEMM (~4x the f32 GEMM on the same "
            "shape).  On TPU the int8 MXU path is the fast one (hw gate "
            "asserts > 1.0).  Re-measured for PR 6 after tiled-K landed: "
            "the int8-GEMM artifact is unchanged; the headline ratio moved "
            "0.25x -> ~0.1x only because the faster PR-6 host shrank the "
            "lax baseline ~4.6x while the interpreter's Python floor stayed "
            "put (see the machine-dependence note above)."
        ),
    },
    ("conv", "app:*"): {"max_err": 1e-4, "zero_fallbacks": True},
    ("fusion", "elementwise:app_nchw"): {
        "max_err": 1e-4,
        "min_ratio": 0.6,
        "min_ratio_note": (
            "interpret-mode grid steps cost ~1ms of Python each; PR 6 "
            "re-seeded the interpret default block_m to the full padded M "
            "(one grid step), lifting this case from 0.13x to ~0.9x.  The "
            "remaining gap vs the unfused jnp chain is interpreter "
            "dispatch, not data movement (hw gate asserts > 1.0)."
        ),
    },
    ("fusion", "elementwise:lm_residual"): {"max_err": 1e-4, "min_ratio": 0.7},
    ("fusion", "plan:style_transfer"): {"max_err": 1e-4, "max_steps": 33},
    ("fusion", "plan:coloring"): {"max_err": 1e-4, "max_steps": 30},
    ("fusion", "plan:super_resolution"): {"max_err": 1e-4, "max_steps": 37},
    ("quant", "kernel:w8"): {"max_err": 5e-2, "min_ratio": 1.2},
    ("quant", "kernel:w8a8"): {
        "max_err": 5e-2,
        "min_ratio": 0.5,
        "min_ratio_note": (
            "same XLA-CPU integer-GEMM artifact as conv w8a8; the int8 "
            "weight stream is still 4x smaller (bytes_ratio gates in "
            "BENCH_quant.json) and the hw gate asserts > 1.0 on TPU."
        ),
    },
    ("quant", "app:*"): {"max_err": 5e-2},
    ("serving", "parity:*"): {"max_err": 1e-4},
    ("serving_smoke", "parity:*"): {"max_err": 1e-4},
    # multi-tenant overload gates (full + committed smoke reference): at 2x
    # capacity with a 10:1 hot/light skew, the in-quota light tenant loses
    # nothing and stays within its deadline SLO, the hot tenant's excess is
    # absorbed by quota + ladder transitions (require_ladder), and the armed
    # watchdog never fires (the overload response is policy, not a hang).
    ("serving", "multi_tenant"): {
        "zero_lost": True, "max_light_miss_rate": 0.1,
        "require_ladder": True, "zero_watchdog": True,
    },
    ("serving_smoke", "multi_tenant"): {
        "zero_lost": True, "max_light_miss_rate": 0.1,
        "require_ladder": True, "zero_watchdog": True,
    },
    # robustness gates (full + committed smoke reference): degraded-mode
    # overhead is guarded-under-total-failure vs the eager reference plan --
    # both are Python-dispatch bound, so the ratio is machine-stable (~1.0x
    # measured); 3.0x is the "guard rails must stay cheap" ceiling.  The
    # chaos cases gate semantics, not speed: zero lost requests, a surviving
    # scheduler thread, bit-exact total-demotion output, breaker recovery.
    ("robustness", "degraded:*"): {"max_err": 1e-4, "max_overhead": 3.0},
    ("robustness_smoke", "degraded:*"): {"max_err": 1e-4, "max_overhead": 3.0},
    ("robustness", "chaos"): {
        "max_err": 1e-4, "zero_lost": True, "require_survival": True,
    },
    ("robustness_smoke", "chaos"): {
        "max_err": 1e-4, "zero_lost": True, "require_survival": True,
    },
    ("robustness", "chaos_total"): {
        "zero_lost": True, "require_survival": True, "require_bitexact": True,
    },
    ("robustness_smoke", "chaos_total"): {
        "zero_lost": True, "require_survival": True, "require_bitexact": True,
    },
    ("robustness", "recovery"): {"require_recovered": True},
    ("robustness_smoke", "recovery"): {"require_recovered": True},
    # autoregressive-decode gates (full + committed smoke reference): the
    # decoder lowering must be invisible in the logits (parity), greedy
    # decode through the paged KV pipeline must match the naive jnp loop
    # token-for-token, epilogue fusion must actually shrink both phase
    # plans, and continuous-batching serve must lose zero sequences and
    # leak zero cache pages.
    ("decode", "parity:*"): {"max_err": 1e-4},
    ("decode_smoke", "parity:*"): {"max_err": 1e-4},
    ("decode", "greedy"): {"require_match": True},
    ("decode_smoke", "greedy"): {"require_match": True},
    ("decode", "plan:*"): {"require_fusion": True},
    ("decode_smoke", "plan:*"): {"require_fusion": True},
    ("decode", "serve"): {"zero_lost": True, "zero_leak": True},
    ("decode_smoke", "serve"): {"zero_lost": True, "zero_leak": True},
    # observability gates (full + committed smoke reference): telemetry must
    # stay (nearly) free.  Overheads are vs the bare-loop baseline (see
    # benchmarks/obs_bench.py): with tracing disabled the instrumented plan
    # may cost <= 1% extra; with a tracing session armed, the full per-step
    # span machinery may cost <= 5% end-to-end.
    ("obs", "overhead:*"): {
        "max_disabled_overhead": 1.01, "max_traced_overhead": 1.05,
    },
    ("obs_smoke", "overhead:*"): {
        "max_disabled_overhead": 1.01, "max_traced_overhead": 1.05,
    },
}


# --------------------------------------------------------------------------- #
# case extraction (one flat dict per bench snapshot)                           #
# --------------------------------------------------------------------------- #


def _cases_from(bench: str, rec: dict) -> dict:
    """Flatten a BENCH_<bench>.json record into ``{case_key: fields}``."""
    mode = rec.get("mode", "interpret")
    cases: dict = {}

    def put(key, **fields):
        cases[key] = {"mode": mode, **fields}

    if bench == "conv":
        for r in rec.get("kernels", ()):
            n, c, h, w, o = r["shape"]
            put(f"kernel:{r['scheme']}:{n}x{c}x{h}x{w}-{o}",
                speedup=r["speedup"], max_err=r["max_err"])
        for r in rec.get("apps", ()):
            put(f"app:{r['app']}", max_err=r["max_err"],
                plan_steps=r["plan_steps"], fallbacks=r["fallbacks"])
    elif bench == "fusion":
        for r in rec.get("elementwise", ()):
            put(f"elementwise:{r['case']}",
                speedup=r["speedup"], max_err=r["max_err"])
        for r in rec.get("epilogue_plans", ()):
            put(f"plan:{r['app']}", max_err=r["max_err"],
                plan_steps=r["steps_fused"], steps_unfused=r["steps_unfused"])
    elif bench == "quant":
        for r in rec.get("kernels", ()):
            put(f"kernel:{r['scheme']}",
                speedup=r["speedup"], max_err=r["max_err"])
        for r in rec.get("apps", ()):
            put(f"app:{r['app']}", max_err=r["max_err"],
                bytes_ratio=r["bytes_ratio"])
    elif bench.startswith("robustness"):
        for r in rec.get("degraded", ()):
            put(f"degraded:{r['app']}", max_err=r["max_err"],
                overhead=r["overhead"], clean_overhead=r.get("clean_overhead"))
        for key in ("chaos", "chaos_total"):
            c = rec.get(key)
            if c:
                put(key, max_err=c["max_err"], lost=c["lost_requests"],
                    injected=c["injected_faults"], bitexact=c["bitexact"],
                    survived=c["scheduler_survived"])
        rcv = rec.get("recovery")
        if rcv:
            put("recovery", recovered=rcv["recovered"],
                breaker_trips=rcv["breaker_trips"])
    elif bench.startswith("obs"):
        for r in rec.get("overhead", ()):
            put(f"overhead:{r['app']}",
                disabled_overhead=r["disabled_overhead"],
                traced_overhead=r["traced_overhead"],
                steps=r["steps"])
    elif bench.startswith("decode"):
        for r in rec.get("parity", ()):
            put(f"parity:{r['case']}", max_err=r["max_err"])
        g = rec.get("greedy")
        if g:
            put("greedy", match=g["match"], tokens=g["tokens"],
                backend=g["backend"])
        for r in rec.get("plans", ()):
            put(f"plan:{r['phase']}", plan_steps=r["steps_fused"],
                steps_unfused=r["steps_unfused"])
        srv = rec.get("serve")
        if srv:
            put("serve", lost=srv["lost"],
                leaked_pages=srv["leaked_pages"],
                tok_per_s=srv["tok_per_s"],
                decode_tokens=srv["decode_tokens"])
    elif bench.startswith("serving"):
        for r in rec.get("parity", ()):
            put(f"parity:{r['app']}", max_err=r["max_err"])
        thr = rec.get("throughput")
        if thr:
            put("throughput", req_per_s=thr["req_per_s"],
                deadline_miss_rate=thr["deadline_miss_rate"],
                speedup_vs_serial=thr.get("speedup_vs_serial"))
        mt = rec.get("multi_tenant")
        if mt:
            put("multi_tenant",
                lost=mt["light"]["lost"] + mt["light"]["turned_away"],
                light_miss_rate=mt["light"]["deadline_miss_rate"],
                ladder_transitions=(mt["hot"]["ladder_up"]
                                    + mt["hot"]["ladder_down"]),
                hot_absorbed=(mt["hot"]["ladder_shed"]
                              + mt["hot"]["throttled"]),
                watchdog_timeouts=mt["watchdog_timeouts"])
    else:  # unknown bench: record parity-bearing rows generically
        for section in rec.values():
            if isinstance(section, list):
                for i, r in enumerate(section):
                    if isinstance(r, dict) and "max_err" in r:
                        put(f"row:{i}", max_err=r["max_err"])
    return cases


def _floor_for(bench: str, case: str):
    for (b, pat), spec in FLOORS.items():
        if b == bench and fnmatch.fnmatch(case, pat):
            return spec
    return None


# --------------------------------------------------------------------------- #
# merge + check                                                                #
# --------------------------------------------------------------------------- #


def collect(results_dir: str = RESULTS_DIR) -> dict:
    """Read every full-mode BENCH_*.json (plus the committed serving smoke
    parity reference) into ``{bench: cases}``."""
    benches: dict = {}
    for path in sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        if name == "trajectory":
            continue
        if name.endswith("_smoke") and name not in (
            "serving_smoke", "robustness_smoke", "obs_smoke", "decode_smoke",
        ):
            continue  # smoke runs are CI plumbing, not perf data
        with open(path) as f:
            rec = json.load(f)
        cases = _cases_from(name, rec)
        if cases:
            benches[name] = cases
    return benches


def merge(pr: int, results_dir: str = RESULTS_DIR) -> dict:
    """Fold the current snapshots into the trajectory file under ``pr``."""
    path = os.path.join(results_dir, TRAJECTORY)
    traj = {"schema": 1, "entries": {}}
    if os.path.exists(path):
        with open(path) as f:
            traj = json.load(f)
    benches = collect(results_dir)
    for bench, cases in benches.items():
        for case, fields in cases.items():
            floor = _floor_for(bench, case)
            if floor:
                fields["floor"] = floor
    traj["entries"][str(pr)] = benches
    with open(path, "w") as f:
        json.dump(traj, f, indent=1, sort_keys=True)
    print(f"trajectory: PR {pr} merged ({sum(len(c) for c in benches.values())}"
          f" cases over {len(benches)} benches) -> {os.path.abspath(path)}")
    return traj


def check(traj: dict | None = None, results_dir: str = RESULTS_DIR) -> int:
    """Assert every floor over every recorded PR entry.  Returns the number
    of cases checked; raises AssertionError listing ALL violations."""
    if traj is None:
        path = os.path.join(results_dir, TRAJECTORY)
        with open(path) as f:
            traj = json.load(f)
    violations, checked = [], 0
    for pr, benches in sorted(traj["entries"].items(), key=lambda kv: int(kv[0])):
        for bench, cases in sorted(benches.items()):
            for case, fields in sorted(cases.items()):
                floor = _floor_for(bench, case)
                if floor is None:
                    continue
                checked += 1
                tag = f"PR {pr} {bench}/{case}"
                err = fields.get("max_err")
                if "max_err" in floor and err is not None and err > floor["max_err"]:
                    violations.append(f"{tag}: max_err {err:.3e} > {floor['max_err']:.0e}")
                ratio = fields.get("speedup")
                if ratio is not None:
                    if fields.get("mode") == "hw":
                        if ratio <= 1.0:  # hw-only gate: must beat baseline
                            violations.append(f"{tag}: hw speedup {ratio:.2f} <= 1.0")
                    elif "min_ratio" in floor and ratio < floor["min_ratio"]:
                        violations.append(
                            f"{tag}: interpret ratio {ratio:.2f} < floor "
                            f"{floor['min_ratio']}"
                        )
                steps = fields.get("plan_steps")
                if "max_steps" in floor and steps is not None and steps > floor["max_steps"]:
                    violations.append(f"{tag}: plan_steps {steps} > {floor['max_steps']}")
                if floor.get("zero_fallbacks") and fields.get("fallbacks"):
                    violations.append(f"{tag}: fallbacks {fields['fallbacks']}")
                over = fields.get("overhead")
                if "max_overhead" in floor and over is not None and over > floor["max_overhead"]:
                    violations.append(
                        f"{tag}: degraded overhead {over:.2f}x > "
                        f"{floor['max_overhead']}x"
                    )
                if floor.get("zero_lost") and fields.get("lost"):
                    violations.append(f"{tag}: {fields['lost']} lost requests")
                if floor.get("require_match") and fields.get("match") is False:
                    violations.append(
                        f"{tag}: greedy decode diverged from the jnp loop"
                    )
                if floor.get("require_fusion"):
                    su = fields.get("steps_unfused")
                    if steps is not None and su is not None and steps >= su:
                        violations.append(
                            f"{tag}: no plan-step reduction ({steps} >= {su})"
                        )
                if floor.get("zero_leak") and fields.get("leaked_pages"):
                    violations.append(
                        f"{tag}: {fields['leaked_pages']} KV pages leaked"
                    )
                if floor.get("require_survival") and fields.get("survived") is False:
                    violations.append(f"{tag}: scheduler thread died")
                if floor.get("require_bitexact") and fields.get("bitexact") is False:
                    violations.append(f"{tag}: total demotion not bit-exact")
                if floor.get("require_recovered") and fields.get("recovered") is False:
                    violations.append(f"{tag}: breakers did not recover")
                lmr = fields.get("light_miss_rate")
                if ("max_light_miss_rate" in floor and lmr is not None
                        and lmr > floor["max_light_miss_rate"]):
                    violations.append(
                        f"{tag}: in-SLO tenant miss rate {lmr:.3f} > "
                        f"{floor['max_light_miss_rate']}"
                    )
                if (floor.get("require_ladder")
                        and not fields.get("ladder_transitions")):
                    violations.append(
                        f"{tag}: no ladder transitions -- what absorbed the "
                        f"overload?"
                    )
                if floor.get("zero_watchdog") and fields.get("watchdog_timeouts"):
                    violations.append(
                        f"{tag}: {fields['watchdog_timeouts']} watchdog "
                        f"timeouts (the ladder, not the watchdog, must "
                        f"absorb overload)"
                    )
                d_ovh = fields.get("disabled_overhead")
                if ("max_disabled_overhead" in floor and d_ovh is not None
                        and d_ovh > floor["max_disabled_overhead"]):
                    violations.append(
                        f"{tag}: disabled-mode overhead {d_ovh:.4f}x > "
                        f"{floor['max_disabled_overhead']}x"
                    )
                t_ovh = fields.get("traced_overhead")
                if ("max_traced_overhead" in floor and t_ovh is not None
                        and t_ovh > floor["max_traced_overhead"]):
                    violations.append(
                        f"{tag}: traced-mode overhead {t_ovh:.4f}x > "
                        f"{floor['max_traced_overhead']}x"
                    )
    if violations:
        raise AssertionError(
            "bench trajectory floor regressions:\n  " + "\n  ".join(violations)
        )
    print(f"trajectory: {checked} floors hold over "
          f"{len(traj['entries'])} PR entr{'y' if len(traj['entries']) == 1 else 'ies'}")
    return checked


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--merge", action="store_true",
                    help="fold the current BENCH_*.json snapshots into the "
                         "trajectory under --pr, then check")
    ap.add_argument("--pr", type=int, default=None,
                    help="PR number for --merge (required with --merge)")
    ap.add_argument("--check", action="store_true",
                    help="assert floors on the committed trajectory (CI)")
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    args = ap.parse_args()
    if args.merge:
        if args.pr is None:
            ap.error("--merge requires --pr")
        traj = merge(args.pr, args.results_dir)
        check(traj, args.results_dir)
    elif args.check:
        check(results_dir=args.results_dir)
    else:
        ap.error("pass --merge --pr N or --check")


if __name__ == "__main__":
    main()
