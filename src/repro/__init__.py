"""repro: production-scale JAX framework for ADMM structured pruning +
compiler-optimized sparse execution (IJCAI-20, Niu & Zhao et al.)."""
__version__ = "0.1.0"
