"""Process-wide metrics registry: named counters, gauges, and
bounded-reservoir histograms with a label system.

Before this module, every subsystem grew its own counter dict --
``ops._CONV_FALLBACKS``, the executor's ``_GUARD_FALLBACKS``, per-server
``_PlanEntry.stats`` -- none sharing a schema or an export path.  This
registry is the one place those numbers live (the old accessors are now
*views* over it), and the one place an external system scrapes:

* :class:`MetricsRegistry` -- a named family per metric (``counter`` /
  ``gauge`` / ``histogram``), each holding one **series** per label set
  (``plan``, ``op``, ``scheme``, ``backend``, ``reason``, ...).  Label
  values are stringified; a family's label *names* are pinned by its first
  series, so a typo'd label set fails loudly instead of forking the family.
* **bounded reservoirs** -- histograms keep the most recent ``reservoir``
  observations for percentiles but accumulate ``count``/``sum``/``min``/
  ``max`` over *every* observation, so a long-running server plateaus in
  memory while its totals stay exact.
* **exporters** -- :meth:`snapshot` (plain dicts), :meth:`to_json`, and
  :meth:`to_prometheus` (text exposition format: counters/gauges verbatim,
  histograms as summary-style quantiles + ``_count``/``_sum``).
* **state transplant** -- :meth:`dump_state` / :meth:`load_state` give the
  test suite's global-state-isolation fixture an exact snapshot/restore,
  the same contract the TuningCache singleton already honors.

The module-level :func:`registry` returns the process singleton; handles
are cheap enough to resolve at the call site::

    from repro.obs import metrics
    metrics.registry().counter("conv_fallback_total", reason="groups").inc()

This module is a leaf: stdlib-only, importable from anywhere in the repo
(kernels, executor, serving) without cycles.
"""

from __future__ import annotations

import json
import threading
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "registry",
]

#: default histogram reservoir: matches the serving latency reservoir bound
DEFAULT_RESERVOIR = 4096

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Series:
    """One (family, label-set) time series.  Counters/gauges hold a float;
    histograms add a bounded reservoir plus exact running aggregates."""

    __slots__ = ("value", "reservoir", "count", "sum", "min", "max")

    def __init__(self, reservoir: Optional[int] = None):
        self.value = 0.0
        self.reservoir: Optional[Deque[float]] = (
            None if reservoir is None else deque(maxlen=reservoir)
        )
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None


class _Handle:
    """Caller-facing view of one series.  Mutations go through the owning
    registry's lock, so handles are safe to cache and share across threads."""

    __slots__ = ("_reg", "name", "labels", "_series")

    def __init__(self, reg: "MetricsRegistry", name: str, labels: LabelKey,
                 series: _Series):
        self._reg = reg
        self.name = name
        self.labels = dict(labels)
        self._series = series


class Counter(_Handle):
    """Monotonic count.  ``inc`` with a negative amount is a bug upstream
    and raises -- a counter that can go down is a gauge."""

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({amount})")
        with self._reg._lock:
            self._series.value += amount

    @property
    def value(self) -> float:
        with self._reg._lock:
            return self._series.value


class Gauge(_Handle):
    """Point-in-time value; ``set`` overwrites, ``set_max`` keeps the
    high-water mark (queue-depth peaks), ``add`` adjusts in place."""

    def set(self, value: float) -> None:
        with self._reg._lock:
            self._series.value = float(value)

    def set_max(self, value: float) -> None:
        with self._reg._lock:
            self._series.value = max(self._series.value, float(value))

    def add(self, amount: float) -> None:
        with self._reg._lock:
            self._series.value += amount

    @property
    def value(self) -> float:
        with self._reg._lock:
            return self._series.value


class Histogram(_Handle):
    """Bounded-reservoir distribution: percentiles come from the most
    recent ``reservoir`` observations, count/sum/min/max from all of them."""

    def observe(self, value: float) -> None:
        v = float(value)
        with self._reg._lock:
            s = self._series
            s.reservoir.append(v)
            s.count += 1
            s.sum += v
            s.min = v if s.min is None else min(s.min, v)
            s.max = v if s.max is None else max(s.max, v)

    @property
    def count(self) -> int:
        with self._reg._lock:
            return self._series.count

    @property
    def sum(self) -> float:
        with self._reg._lock:
            return self._series.sum

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile (``q`` in [0, 100]) over the
        reservoir; 0.0 when nothing has been observed."""
        with self._reg._lock:
            data = sorted(self._series.reservoir)
        if not data:
            return 0.0
        if len(data) == 1:
            return data[0]
        rank = (q / 100.0) * (len(data) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(data) - 1)
        frac = rank - lo
        return data[lo] * (1.0 - frac) + data[hi] * frac

    def stats(self) -> Dict[str, float]:
        """The standard latency reduction: count/mean/p50/p95/p99."""
        with self._reg._lock:
            count, total = self._series.count, self._series.sum
        return {
            "count": count,
            "mean": (total / count) if count else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    __slots__ = ("name", "kind", "help", "reservoir", "label_names", "series")

    def __init__(self, name: str, kind: str, help: str, reservoir: Optional[int]):
        self.name = name
        self.kind = kind
        self.help = help
        self.reservoir = reservoir
        #: pinned by the first series: all series of a family share a schema
        self.label_names: Optional[Tuple[str, ...]] = None
        self.series: Dict[LabelKey, _Series] = {}


class MetricsRegistry:
    """Thread-safe named-metric registry; see the module docstring.  The
    process singleton is :func:`registry`; fresh instances are cheap (tests
    use private ones to probe semantics without touching global state)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}

    # -- family / series resolution ------------------------------------------ #
    def _resolve(self, name: str, kind: str, help: str,
                 reservoir: Optional[int], labels: Dict[str, Any]) -> _Handle:
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(name, kind, help, reservoir)
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} is a {fam.kind}, not a {kind} -- one "
                    f"name, one type"
                )
            names = tuple(k for k, _ in key)
            if fam.label_names is None:
                fam.label_names = names
            elif fam.label_names != names:
                raise ValueError(
                    f"metric {name!r} takes labels {fam.label_names}, "
                    f"got {names} -- label names are pinned per family"
                )
            s = fam.series.get(key)
            if s is None:
                s = fam.series[key] = _Series(
                    fam.reservoir if kind == "histogram" else None
                )
            return _KINDS[kind](self, name, key, s)

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._resolve(name, "counter", help, None, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._resolve(name, "gauge", help, None, labels)

    def histogram(self, name: str, help: str = "",
                  reservoir: int = DEFAULT_RESERVOIR, **labels) -> Histogram:
        return self._resolve(name, "histogram", help, reservoir, labels)

    # -- views ----------------------------------------------------------------- #
    def series(self, name: str) -> List[Tuple[Dict[str, str], _Series]]:
        """(labels dict, series) per series of ``name``; [] if unknown --
        the raw material of the back-compat counter views."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return []
            return [(dict(k), s) for k, s in fam.series.items()]

    def label_counts(self, name: str, *label_names: str) -> Dict[str, float]:
        """Collapse a counter family to ``{"v1[/v2/...]": value}`` over the
        given label names -- the shape of the legacy counter dicts
        (``conv_fallback_counts`` et al.)."""
        out: Dict[str, float] = {}
        for labels, s in self.series(name):
            key = "/".join(labels.get(ln, "") for ln in label_names)
            out[key] = out.get(key, 0.0) + s.value
        return out

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._families)

    # -- reset / state transplant ---------------------------------------------- #
    def reset(self, name: Optional[str] = None) -> None:
        """Drop every series of ``name`` (or every family when None).  The
        family itself survives a named reset so its type/labels stay pinned."""
        with self._lock:
            if name is None:
                self._families.clear()
            elif name in self._families:
                self._families[name].series.clear()

    def dump_state(self) -> Dict[str, Any]:
        """Deep-copy of the full registry state, suitable for
        :meth:`load_state` (the conftest isolation fixture's snapshot)."""
        with self._lock:
            out: Dict[str, Any] = {}
            for name, fam in self._families.items():
                out[name] = {
                    "kind": fam.kind,
                    "help": fam.help,
                    "reservoir": fam.reservoir,
                    "label_names": fam.label_names,
                    "series": {
                        k: {
                            "value": s.value,
                            "reservoir": None if s.reservoir is None
                            else list(s.reservoir),
                            "count": s.count,
                            "sum": s.sum,
                            "min": s.min,
                            "max": s.max,
                        }
                        for k, s in fam.series.items()
                    },
                }
            return out

    def load_state(self, state: Dict[str, Any]) -> None:
        """Restore exactly the families/series of ``state`` (not a merge:
        families created since the snapshot are discarded)."""
        with self._lock:
            self._families.clear()
            for name, f in state.items():
                fam = _Family(name, f["kind"], f["help"], f["reservoir"])
                fam.label_names = (
                    None if f["label_names"] is None else tuple(f["label_names"])
                )
                for k, sv in f["series"].items():
                    s = _Series(f["reservoir"] if f["kind"] == "histogram" else None)
                    s.value = sv["value"]
                    if sv["reservoir"] is not None:
                        s.reservoir.extend(sv["reservoir"])
                    s.count, s.sum = sv["count"], sv["sum"]
                    s.min, s.max = sv["min"], sv["max"]
                    fam.series[tuple(tuple(p) for p in k)] = s
                self._families[name] = fam

    # -- exporters -------------------------------------------------------------- #
    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view of every family: the JSON-export payload and the
        ``--metrics-dump`` record."""
        with self._lock:
            out: Dict[str, Any] = {}
            for name, fam in sorted(self._families.items()):
                samples = []
                for key, s in fam.series.items():
                    sample: Dict[str, Any] = {"labels": dict(key)}
                    if fam.kind == "histogram":
                        data = sorted(s.reservoir)
                        sample.update(
                            count=s.count, sum=s.sum, min=s.min, max=s.max,
                            p50=_pct(data, 50), p95=_pct(data, 95),
                            p99=_pct(data, 99),
                        )
                    else:
                        sample["value"] = s.value
                    samples.append(sample)
                out[name] = {"type": fam.kind, "help": fam.help,
                             "samples": samples}
            return out

    def to_json(self, **json_kwargs) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, **json_kwargs)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format.  Histograms export as
        summaries (``{quantile="0.5"}`` series + ``_count``/``_sum``) --
        reservoir percentiles, not cumulative buckets."""
        lines: List[str] = []
        snap = self.snapshot()
        for name, fam in snap.items():
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            kind = "summary" if fam["type"] == "histogram" else fam["type"]
            lines.append(f"# TYPE {name} {kind}")
            for s in fam["samples"]:
                base = s["labels"]
                if fam["type"] == "histogram":
                    for q, field in (("0.5", "p50"), ("0.95", "p95"),
                                     ("0.99", "p99")):
                        lines.append(_prom_line(
                            name, {**base, "quantile": q}, s[field]
                        ))
                    lines.append(_prom_line(f"{name}_count", base, s["count"]))
                    lines.append(_prom_line(f"{name}_sum", base, s["sum"]))
                else:
                    lines.append(_prom_line(name, base, s["value"]))
        return "\n".join(lines) + ("\n" if lines else "")


def _pct(data: List[float], q: float) -> float:
    if not data:
        return 0.0
    if len(data) == 1:
        return data[0]
    rank = (q / 100.0) * (len(data) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(data) - 1)
    frac = rank - lo
    return data[lo] * (1.0 - frac) + data[hi] * frac


def _prom_line(name: str, labels: Dict[str, str], value: Any) -> str:
    if labels:
        body = ",".join(
            f'{k}="{_prom_escape(v)}"' for k, v in sorted(labels.items())
        )
        return f"{name}{{{body}}} {_prom_value(value)}"
    return f"{name} {_prom_value(value)}"


def _prom_escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _prom_value(v: Any) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry every subsystem records into."""
    return _REGISTRY


def iter_series(name: str) -> Iterator[Tuple[Dict[str, str], float]]:
    """Convenience over the singleton: (labels, value) per series."""
    for labels, s in _REGISTRY.series(name):
        yield labels, s.value
