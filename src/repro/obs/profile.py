"""Plan profiler: run an ExecutionPlan under tracing and reduce to a
per-step cost table.

:func:`profile_plan` is the paper's "where does the millisecond go"
instrument: it executes a compiled plan eagerly inside a private tracing
session (the caller's tracing state is restored afterwards), pairs the
per-step spans the executor emits, and joins them with the plan's
abstract-eval memory estimate into one table per step:

* wall milliseconds (median over ``runs`` traced executions) and share of
  the total;
* estimated bytes moved -- the step's input + parameter + output bytes
  from :meth:`ExecutionPlan.memory_estimate` (HBM traffic if nothing
  fuses; an upper bound when epilogues run in-tile);
* kernel-vs-reference attribution -- whether the step dispatched a
  Pallas-backed handler, the shared jnp implementation, or (for guarded
  plans) was demoted to the reference oracle mid-run.

Surfaces: ``python -m repro.launch.profile`` (text table + Chrome trace
out) and the ``repro.obs`` test/bench suite.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import trace as _trace

__all__ = ["StepProfile", "PlanProfile", "profile_plan"]


@dataclasses.dataclass
class StepProfile:
    name: str
    op: str
    ms: float
    pct: float
    bytes_moved: int
    attribution: str  # "kernel" | "quant" | "reference" | "shared" | "demoted"
    out_shape: Tuple[int, ...]
    demotions: int = 0


@dataclasses.dataclass
class PlanProfile:
    backend: str
    steps: List[StepProfile]
    total_ms: float
    runs: int
    memory: Dict[str, Any]
    trace: Optional[Any] = None  # TraceBuffer of the last traced run

    def to_json(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "total_ms": self.total_ms,
            "runs": self.runs,
            "peak_activation_bytes": self.memory["peak_activation_bytes"],
            "param_bytes": self.memory["param_bytes"],
            "steps": [dataclasses.asdict(s) for s in self.steps],
        }

    def save_json(self, path: str) -> str:
        import os

        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return os.path.abspath(path)

    def render_text(self, top: Optional[int] = None) -> str:
        """Aligned per-step table, hottest first; ``top`` truncates."""
        rows = sorted(self.steps, key=lambda s: -s.ms)
        if top is not None:
            rows = rows[:top]
        name_w = max([len("step")] + [len(s.name) for s in rows])
        op_w = max([len("op")] + [len(s.op) for s in rows])
        lines = [
            f"plan profile: backend={self.backend} steps={len(self.steps)} "
            f"total={self.total_ms:.3f}ms over {self.runs} run(s)",
            f"{'step':{name_w}s}  {'op':{op_w}s}  {'ms':>9s}  {'%':>6s}  "
            f"{'est bytes':>10s}  {'via':<9s}  out",
        ]
        for s in rows:
            via = s.attribution + (f"(x{s.demotions})" if s.demotions else "")
            lines.append(
                f"{s.name:{name_w}s}  {s.op:{op_w}s}  {s.ms:9.3f}  "
                f"{s.pct:5.1f}%  {_human_bytes(s.bytes_moved):>10s}  "
                f"{via:<9s}  {list(s.out_shape)}"
            )
        return "\n".join(lines)


def _human_bytes(n: int) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}GB"


def _struct_of(x):
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


def _nbytes(struct) -> int:
    size = 1
    for d in struct.shape:
        size *= int(d)
    return size * jnp.dtype(struct.dtype).itemsize


def _attribution(plan) -> Dict[str, str]:
    """op -> how this plan's backend dispatches it: a backend-specific
    handler ("kernel"/"quant"/"reference") or the implementation shared
    with the reference table ("shared")."""
    from ..core.graph.executor import handlers_for

    ref = handlers_for("reference")
    if plan.backend == "guarded":
        primary = handlers_for(plan.guard.primary)
        label = plan.guard.primary
    else:
        primary = handlers_for(plan.backend)
        label = plan.backend
    out: Dict[str, str] = {}
    for step in plan.steps:
        op = step.node.op
        if label == "reference":
            out[op] = "reference"
            continue
        h = primary.get(op, ref.get(op))
        out[op] = "shared" if h is ref.get(op) else label
    return out


def profile_plan(
    plan,
    params,
    *args,
    runs: int = 1,
    warmup: int = 1,
    clock=time.perf_counter,
) -> PlanProfile:
    """Execute ``plan(params, *args)`` eagerly under tracing and reduce the
    per-step spans to a :class:`PlanProfile`.  ``warmup`` untraced runs
    absorb jit/Pallas compilation; ``runs`` traced runs are reduced to a
    per-step *median* so one GC pause cannot masquerade as a hot step.
    The caller's tracing state is saved and restored around the session."""
    if runs < 1 or warmup < 0:
        raise ValueError(f"need runs >= 1, warmup >= 0; got {runs}/{warmup}")
    for _ in range(warmup):
        jax.block_until_ready(plan(params, *args))

    n_steps = len(plan.steps)
    prev = _trace.state()
    try:
        buf = _trace.start_tracing(clock)
        for _ in range(runs):
            jax.block_until_ready(plan(params, *args))
    finally:
        _trace.restore(prev)

    step_spans = [s for s in buf.spans() if s["cat"] == "step"]
    if len(step_spans) != runs * n_steps:
        raise RuntimeError(
            f"expected {runs}x{n_steps} step spans, got {len(step_spans)} -- "
            "was the plan executed under jit, or tracing toggled mid-run?"
        )
    demote_ts = [
        (ev["tid"], ev["ts"]) for ev in buf.instants("guard")
        if ev["name"].startswith("demote:")
    ]

    # per-step median over the runs (spans arrive in execution order)
    per_step_ms: List[List[float]] = [[] for _ in range(n_steps)]
    demotions = [0] * n_steps
    for r in range(runs):
        for i in range(n_steps):
            sp = step_spans[r * n_steps + i]
            per_step_ms[i].append(sp["dur"] / 1e3)
            demotions[i] += sum(
                1 for tid, ts in demote_ts
                if tid == sp["tid"] and sp["ts"] <= ts <= sp["ts"] + sp["dur"]
            )

    mem = plan.memory_estimate(*[_struct_of(a) for a in args])
    out_bytes = {name: b for name, b, _live in mem["per_step"]}
    # bytes moved = inputs + params + output of each step (name -> bytes of
    # every value the step touches; graph inputs seed the map)
    val_bytes: Dict[str, int] = {
        name: _nbytes(_struct_of(a))
        for name, a in zip(plan.graph.inputs, args)
    }
    attribution = _attribution(plan)
    rows: List[StepProfile] = []
    total_ms = 0.0
    for i, step in enumerate(plan.steps):
        n = step.node
        samples = sorted(per_step_ms[i])
        ms = samples[len(samples) // 2]
        total_ms += ms
        pbytes = sum(
            _nbytes(_struct_of(v))
            for v in jax.tree.leaves(params.get(n.name, {}))
        )
        in_bytes = sum(val_bytes.get(x, 0) for x in n.inputs)
        val_bytes[n.name] = out_bytes.get(n.name, 0)
        attr = attribution[n.op]
        if demotions[i]:
            attr = "demoted"
        rows.append(StepProfile(
            name=n.name, op=n.op, ms=ms, pct=0.0,
            bytes_moved=in_bytes + pbytes + out_bytes.get(n.name, 0),
            attribution=attr,
            out_shape=tuple(
                step_spans[i]["args"].get("out_shape", ())
            ),
            demotions=demotions[i],
        ))
    for r in rows:
        r.pct = (100.0 * r.ms / total_ms) if total_ms else 0.0
    return PlanProfile(
        backend=plan.backend, steps=rows, total_ms=total_ms, runs=runs,
        memory={k: mem[k] for k in ("peak_activation_bytes", "param_bytes",
                                    "param_bytes_by_dtype",
                                    "weight_bytes_saved")},
        trace=buf,
    )
