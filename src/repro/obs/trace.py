"""Structured tracing: nestable spans emitting Chrome-trace-format JSON.

The output of a traced run loads directly into ``chrome://tracing`` or
Perfetto (https://ui.perfetto.dev): duration events (``ph: "B"/"E"``) nest
per thread into the familiar flame view, instant events (``ph: "i"``) mark
point occurrences (guard demotions, watchdog trips), and async events
(``ph: "b"/"n"/"e"`` with an ``id``) follow a serving request across
threads from admission to completion.

Overhead contract (gated by ``benchmarks/obs_bench.py``):

* **disabled** (the default): every hook is guarded by the module-level
  :func:`enabled` flag; :func:`span` returns one shared no-op singleton and
  :func:`instant` returns before building anything, so an untraced run
  allocates nothing and pays one predictable branch per hook (<= 1% on an
  end-to-end demo-app plan).
* **enabled**: each span appends two small dicts to an in-memory buffer
  under a lock (<= 5% end to end).  Nothing is serialized until
  :meth:`TraceBuffer.chrome_trace` / :meth:`TraceBuffer.save`.

The clock is injectable per buffer (``start_tracing(clock=...)``) so tests
assert exact durations; timestamps are emitted in microseconds, the Chrome
trace unit.  Tracing state is process-global by design -- one switch arms
every instrumented layer (executor steps, compiler passes, serving
requests) -- and :func:`state` / :func:`restore` give the test-isolation
fixture an exact snapshot, like the metrics registry's ``dump_state``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "TraceBuffer",
    "enabled",
    "span",
    "instant",
    "async_begin",
    "async_instant",
    "async_end",
    "start_tracing",
    "stop_tracing",
    "tracing",
    "current_buffer",
]

#: hot-path switch: every instrumentation hook reads this module attribute
#: first and bails before allocating anything when tracing is off
_ENABLED = False
_BUFFER: Optional["TraceBuffer"] = None
_LOCK = threading.Lock()  # guards the enable/disable transitions only


def enabled() -> bool:
    return _ENABLED


class TraceBuffer:
    """An in-memory list of Chrome-trace events with its own clock.

    Recording is lock-free: ``list.append`` is atomic under the GIL, and
    ``add`` is bound straight to it so the hot path is one C call --
    the <= 5% traced-mode gate in ``benchmarks/obs_bench.py`` leans on
    this.  Readers snapshot via ``list(...)`` (also atomic), so
    cross-thread produce/read interleavings are safe without a mutex."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.pid = os.getpid()
        self._events: List[Dict[str, Any]] = []
        #: append one raw Chrome-trace event dict (the hot path)
        self.add = self._events.append

    # -- recording -------------------------------------------------------------- #
    def now_us(self) -> float:
        return self.clock() * 1e6

    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    # -- export ----------------------------------------------------------------- #
    def chrome_trace(self) -> Dict[str, Any]:
        """The JSON-object Chrome trace form (Perfetto-loadable)."""
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
        return os.path.abspath(path)

    # -- structured views -------------------------------------------------------- #
    def spans(self) -> List[Dict[str, Any]]:
        """Pair the B/E duration events per thread into
        ``{name, cat, ts, dur, args, tid}`` dicts (start order).  Raises on
        mismatched pairs -- the trace-validity check the tests drive."""
        stacks: Dict[int, List[Dict[str, Any]]] = {}
        out: List[Dict[str, Any]] = []
        for ev in self.events:
            ph = ev.get("ph")
            if ph == "B":
                rec = {
                    "name": ev["name"], "cat": ev.get("cat", ""),
                    "ts": ev["ts"], "dur": None,
                    "args": ev.get("args", {}), "tid": ev["tid"],
                }
                stacks.setdefault(ev["tid"], []).append(rec)
                out.append(rec)
            elif ph == "E":
                stack = stacks.get(ev["tid"])
                if not stack:
                    raise ValueError(
                        f"unbalanced trace: E event with empty stack on "
                        f"tid {ev['tid']}"
                    )
                rec = stack.pop()
                rec["dur"] = ev["ts"] - rec["ts"]
        dangling = [r["name"] for s in stacks.values() for r in s]
        if dangling:
            raise ValueError(f"unbalanced trace: unclosed spans {dangling}")
        return out

    def instants(self, cat: Optional[str] = None) -> List[Dict[str, Any]]:
        return [
            ev for ev in self.events
            if ev.get("ph") == "i" and (cat is None or ev.get("cat") == cat)
        ]

    def async_events(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [
            ev for ev in self.events
            if ev.get("ph") in ("b", "n", "e")
            and (name is None or ev.get("name") == name)
        ]


class _Span:
    """A live duration event: B recorded at ``__enter__``, E at
    ``__exit__``.  ``set`` mutates the B event's args in place (the dict is
    not serialized until export), so callers can attach results computed
    mid-span -- output shapes, demotion verdicts -- without a second event."""

    __slots__ = ("_buf", "_begin")

    def __init__(self, buf: TraceBuffer, name: str, cat: str,
                 args: Dict[str, Any]):
        self._buf = buf
        self._begin = {
            "name": name, "cat": cat, "ph": "B", "pid": buf.pid,
            "tid": _get_ident(), "ts": buf.clock() * 1e6, "args": args,
        }

    def __enter__(self) -> "_Span":
        self._buf.add(self._begin)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        b = self._begin
        if exc_type is not None:
            b["args"]["error"] = exc_type.__name__
        buf = self._buf
        buf.add({
            "name": b["name"], "cat": b["cat"], "ph": "E", "pid": b["pid"],
            "tid": b["tid"], "ts": buf.clock() * 1e6,
        })
        return False

    def set(self, key: str, value: Any) -> None:
        self._begin["args"][key] = value


class _NullSpan:
    """The shared disabled-mode span: no state, no allocation, reusable and
    re-entrant (``__enter__`` returns self, every method is a no-op)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, key: str, value: Any) -> None:
        pass


NULL_SPAN = _NullSpan()

_get_ident = threading.get_ident  # module-global bind: hot-path lookup


def span(name: str, cat: str = "repro", **args):
    """A nestable duration span (``with span("step", op="conv2d"): ...``).
    Returns the shared :data:`NULL_SPAN` when tracing is disabled."""
    buf = _BUFFER
    if not _ENABLED or buf is None:
        return NULL_SPAN
    return _Span(buf, name, cat, args)


def instant(name: str, cat: str = "repro", **args) -> None:
    """A point event (``ph: "i"``, thread scope) -- demotions, fallbacks,
    watchdog trips.  No-op when disabled."""
    buf = _BUFFER
    if not _ENABLED or buf is None:
        return
    buf.add({
        "name": name, "cat": cat, "ph": "i", "s": "t", "pid": buf.pid,
        "tid": _get_ident(), "ts": buf.now_us(), "args": args,
    })


def _async_event(ph: str, name: str, event_id, cat: str, args) -> None:
    buf = _BUFFER
    if not _ENABLED or buf is None:
        return
    buf.add({
        "name": name, "cat": cat, "ph": ph, "id": str(event_id),
        "pid": buf.pid, "tid": _get_ident(), "ts": buf.now_us(),
        "args": args,
    })


def async_begin(name: str, event_id, cat: str = "repro", **args) -> None:
    """Open an async span (``ph: "b"``): a logical operation that crosses
    threads -- e.g. a serving request from admission to completion."""
    _async_event("b", name, event_id, cat, args)


def async_instant(name: str, event_id, cat: str = "repro", **args) -> None:
    """A milestone inside an open async span (``ph: "n"``) -- e.g. the
    moment a queued request is picked into a macro-batch."""
    _async_event("n", name, event_id, cat, args)


def async_end(name: str, event_id, cat: str = "repro", **args) -> None:
    _async_event("e", name, event_id, cat, args)


# --------------------------------------------------------------------------- #
# session control                                                              #
# --------------------------------------------------------------------------- #


def start_tracing(clock=time.perf_counter) -> TraceBuffer:
    """Arm tracing with a fresh buffer (replacing any active one) and
    return it.  The injectable ``clock`` is seconds-valued; events are
    stamped in microseconds."""
    global _ENABLED, _BUFFER
    with _LOCK:
        _BUFFER = TraceBuffer(clock)
        _ENABLED = True
        return _BUFFER


def stop_tracing() -> Optional[TraceBuffer]:
    """Disarm tracing; returns the buffer that was recording (if any)."""
    global _ENABLED, _BUFFER
    with _LOCK:
        buf, _BUFFER = _BUFFER, None
        _ENABLED = False
        return buf


class tracing:
    """``with tracing() as buf: ...`` -- scoped session that restores the
    *previous* tracing state on exit, so nested sessions compose."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._prev: Optional[Tuple[bool, Optional[TraceBuffer]]] = None
        self.buffer: Optional[TraceBuffer] = None

    def __enter__(self) -> TraceBuffer:
        self._prev = state()
        self.buffer = start_tracing(self._clock)
        return self.buffer

    def __exit__(self, exc_type, exc, tb) -> bool:
        restore(self._prev)
        return False


def current_buffer() -> Optional[TraceBuffer]:
    return _BUFFER


def state() -> Tuple[bool, Optional[TraceBuffer]]:
    """(enabled, buffer) -- the exact switch state, for snapshot/restore
    (the conftest isolation fixture and nested ``tracing`` sessions)."""
    return (_ENABLED, _BUFFER)


def restore(snap: Tuple[bool, Optional[TraceBuffer]]) -> None:
    global _ENABLED, _BUFFER
    with _LOCK:
        _ENABLED, _BUFFER = snap
