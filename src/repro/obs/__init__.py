"""Unified observability: metrics registry, structured tracing, profiler.

Three stdlib-light pillars (see ARCHITECTURE.md section 8):

* :mod:`repro.obs.metrics` -- process-wide named counters / gauges /
  bounded-reservoir histograms with labels, JSON + Prometheus exporters.
* :mod:`repro.obs.trace` -- nestable spans with an injectable clock,
  Chrome-trace/Perfetto JSON output, near-zero cost when disabled.
* :mod:`repro.obs.profile` -- ``profile_plan``: run a compiled plan under
  tracing and reduce to a per-step wall-time / bytes / attribution table.
"""

from . import metrics, trace
from .metrics import MetricsRegistry, registry
from .profile import PlanProfile, StepProfile, profile_plan
from .trace import (
    TraceBuffer,
    async_begin,
    async_end,
    async_instant,
    current_buffer,
    instant,
    span,
    start_tracing,
    stop_tracing,
    tracing,
)

__all__ = [
    "metrics",
    "trace",
    "MetricsRegistry",
    "registry",
    "PlanProfile",
    "StepProfile",
    "profile_plan",
    "TraceBuffer",
    "span",
    "instant",
    "async_begin",
    "async_instant",
    "async_end",
    "start_tracing",
    "stop_tracing",
    "tracing",
    "current_buffer",
]
