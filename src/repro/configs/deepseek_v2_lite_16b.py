"""deepseek-v2-lite-16b  [moe] -- 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, MLA kv_lora=512
[arXiv:2405.04434; hf].  Layer 0 uses a dense FFN (d_ff = 10944)."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,           # dense-FFN layers (layer 0)
    vocab=102400,
    head_dim=128,
    kv_lora_rank=512,
    q_lora_rank=0,        # lite: no q compression
    rope_head_dim=64,
    moe=MoEConfig(
        n_routed=64,
        n_shared=2,
        top_k=6,
        d_expert=1408,
        first_dense=1,
    ),
    ffn_activation="silu",
)
