"""paligemma-3b  [vlm] -- 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 -- SigLIP (stub) + gemma backbone  [arXiv:2407.07726; hf].
The vision tower is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings [B, 256, D]; the LM runs prefix-LM attention
(bidirectional over the image prefix)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    vision_tokens=256,
    tie_embeddings=True,
    ffn_activation="gelu",   # gemma GeGLU
)
