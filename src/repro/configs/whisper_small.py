"""whisper-small  [audio] -- 12L(enc)+12L(dec) d_model=768 12H d_ff=3072
vocab=51865 -- enc-dec, conv frontend STUB  [arXiv:2212.04356].
input_specs() provides precomputed frame embeddings [B, 1500, 768]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,          # decoder layers
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    ffn_activation="gelu",
)
