"""Architecture + run configuration dataclasses.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py`` with
the exact values from the assignment table.  ``ShapeConfig`` describes the
four assigned input-shape regimes.  Everything is a frozen dataclass so a
config is hashable static metadata for jit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "RecurrentConfig", "ShapeConfig", "PruneConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 2
    d_expert: int = 0  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    #: layers [0, first_dense) use a dense FFN instead (DeepSeek-V2 layer 0)
    first_dense: int = 1
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """Griffin/RecurrentGemma RG-LRU block config."""

    lru_width: int = 0  # 0 -> d_model
    d_conv: int = 4
    #: block pattern, e.g. ("rec", "rec", "attn") repeated  (1 attn : 2 rec)
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    window: int = 2048  # local-attention window for the attn blocks


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    """How the paper's technique is applied to this arch (None = dense)."""

    enabled: bool = False
    #: structure spec dicts per weight-class glob (see PrunePlan.from_rules)
    rules: Tuple[Tuple[str, Dict[str, Any]], ...] = ()
    #: execution mode: dense | masked | bsr | colpack
    exec_mode: str = "masked"
    sparsity: float = 0.5


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # MLA (DeepSeek) -- 0 disables
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # gated-FFN activation
    ffn_activation: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU
    # subsystem configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    # enc-dec (whisper): encoder layer count (decoder = n_layers)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper 30s @ 50 Hz after conv stub
    # vlm: number of image-prefix tokens from the (stub) vision tower
    vision_tokens: int = 0
    # norms / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # paper technique
    prune: PruneConfig = PruneConfig()
    # compile strategy: unroll layers (exact HLO accounting) vs scan
    use_scan: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding/unembedding width: vocab rounded up to a 256 multiple so
        the vocab axis shards evenly on any mesh (padded logits are masked to
        -inf in the unembed -- see models/transformer._unembed)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.ssm is not None

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state: SSM or bounded-window hybrid."""
        return self.ssm is not None or self.recurrent is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
