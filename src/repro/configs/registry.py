"""Config registry: ``--arch <id>`` -> ArchConfig, plus reduced smoke configs.

``get_config(arch_id)`` returns the exact assigned full-size config;
``smoke_config(arch_id)`` returns a same-family reduced config (small layers,
tiny vocab, few experts) that runs a forward/train step on CPU in seconds --
the full configs are only ever lowered via ShapeDtypeStructs (dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ArchConfig, MoEConfig, RecurrentConfig, SSMConfig, SHAPES, ShapeConfig

ARCH_IDS: List[str] = [
    "qwen2.5-3b",
    "qwen3-14b",
    "granite-3-2b",
    "phi4-mini-3.8b",
    "deepseek-v2-lite-16b",
    "deepseek-v2-236b",
    "paligemma-3b",
    "mamba2-1.3b",
    "whisper-small",
    "recurrentgemma-9b",
]

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-14b": "qwen3_14b",
    "granite-3-2b": "granite_3_2b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "paligemma-3b": "paligemma_3b",
    "mamba2-1.3b": "mamba2_1_3b",
    "whisper-small": "whisper_small",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def get_config(arch_id: str) -> ArchConfig:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; one of {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    """Reduced same-family config: 2-3 layers, narrow, tiny vocab."""
    cfg = get_config(arch_id)
    kw: Dict = dict(
        n_layers=3 if (cfg.recurrent or cfg.moe) else 2,
        d_model=128,
        vocab=256,
        dtype="float32",
    )
    if cfg.family == "ssm":
        kw.update(n_heads=0, n_kv_heads=0, d_ff=0,
                  ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16))
    else:
        n_heads = 4
        n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2
        if cfg.n_kv_heads == 1:
            n_kv = 1
        kw.update(n_heads=n_heads, n_kv_heads=n_kv, head_dim=32, d_ff=256)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_routed=8, n_shared=cfg.moe.n_shared, top_k=2, d_expert=64
        )
        kw["kv_lora_rank"] = 32 if cfg.kv_lora_rank else 0
        kw["q_lora_rank"] = 48 if cfg.q_lora_rank else 0
        kw["rope_head_dim"] = 16 if cfg.kv_lora_rank else cfg.rope_head_dim
    if cfg.recurrent:
        kw["recurrent"] = RecurrentConfig(
            lru_width=128, d_conv=4, pattern=cfg.recurrent.pattern, window=32
        )
    if cfg.is_encdec:
        kw.update(n_layers=2, encoder_layers=2, encoder_seq=64)
    if cfg.vision_tokens:
        kw["vision_tokens"] = 16
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)


def shape_cells(arch_id: str):
    """The (shape, status) matrix row for one arch: 'run' or 'SKIP(reason)'.

    Skip rules (DESIGN.md section 7):
    * long_500k needs sub-quadratic decode state -> SSM / hybrid only.
    * decode shapes skipped for encoder-only archs (none assigned; whisper is
      enc-dec and runs them).
    """
    cfg = get_config(arch_id)
    cells = {}
    for name, shape in SHAPES.items():
        if name == "long_500k" and not cfg.supports_long_context:
            cells[name] = "SKIP(full-attention arch: 512k dense KV is not sub-quadratic)"
        else:
            cells[name] = "run"
    return cells
