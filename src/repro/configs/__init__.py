from .base import ArchConfig, MoEConfig, PruneConfig, RecurrentConfig, SHAPES, SSMConfig, ShapeConfig
from .registry import ARCH_IDS, get_config, shape_cells, smoke_config
