"""mamba2-1.3b  [ssm] -- 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality)  [arXiv:2405.21060]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)
