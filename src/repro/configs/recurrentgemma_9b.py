"""recurrentgemma-9b  [hybrid] -- 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 -- RG-LRU + local attn 1:2  [arXiv:2402.19427].
Block pattern (rec, rec, attn) repeating; local window 2048."""
from .base import ArchConfig, RecurrentConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    recurrent=RecurrentConfig(
        lru_width=4096,
        d_conv=4,
        pattern=("rec", "rec", "attn"),
        window=2048,
    ),
    tie_embeddings=True,
    ffn_activation="gelu",
)
