"""deepseek-v2-236b  [moe] -- 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160 routed top-6 + 2 shared, MLA kv_lora=512 q_lora=1536
[arXiv:2405.04434; hf].  Layer 0 dense FFN (d_ff = 12288)."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,           # dense-FFN layers (layer 0)
    vocab=102400,
    head_dim=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    moe=MoEConfig(
        n_routed=160,
        n_shared=2,
        top_k=6,
        d_expert=1536,
        first_dense=1,
    ),
    ffn_activation="silu",
)
