from .pipeline import PipelineState, SyntheticPipeline
