"""Deterministic synthetic data pipeline (checkpointable, shardable).

No dataset files exist in this container (DESIGN.md section 10), so the
pipeline generates *learnable* token streams: an order-1 Markov chain with a
low-entropy transition structure derived from the seed.  Properties that
matter for the framework (and are tested):

* **deterministic**: batch(step) is a pure function of (seed, step) -- two
  hosts, or a restarted host, produce identical data;
* **checkpointable**: the pipeline state is a single step counter, saved in
  every checkpoint and restored on resume (no replayed or skipped batches);
* **shardable**: ``global_batch(step)`` returns the full array; hosts slice
  their data-parallel shard by index, so placement is exact on any mesh.

For the VLM/audio families the pipeline also emits the stub-frontend
embeddings (patch/frame features) as seeded gaussians.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from ..configs.base import ArchConfig

__all__ = ["PipelineState", "SyntheticPipeline"]


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"data_step": self.step}

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(step=int(d["data_step"]))


class SyntheticPipeline:
    """Markov-chain token batches + modality stubs."""

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        batch: int,
        seq: int,
        seed: int = 0,
        branching: int = 4,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.vocab = cfg.vocab
        # low-entropy transition table: from each token, only ``branching``
        # successors are likely -- a model that learns it beats uniform loss.
        rng = np.random.default_rng(seed)
        self._succ = rng.integers(0, self.vocab, size=(self.vocab, branching))
        self.state = PipelineState()

    # ------------------------------------------------------------------ #
    def _tokens_for(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.batch, self.seq
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        choices = rng.integers(0, self._succ.shape[1], size=(b, s))
        noise = rng.random((b, s)) < 0.05  # 5% uniform noise
        noise_tok = rng.integers(0, self.vocab, size=(b, s))
        for t in range(1, s):
            nxt = self._succ[toks[:, t - 1], choices[:, t]]
            toks[:, t] = np.where(noise[:, t], noise_tok[:, t], nxt)
        return toks

    def global_batch(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Full global batch for ``step`` (defaults to the cursor)."""
        step = self.state.step if step is None else step
        toks = self._tokens_for(step)
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1].copy(),
            "labels": toks[:, 1:].copy(),
        }
        rng = np.random.default_rng((self.seed, step, 7))
        if self.cfg.vision_tokens:
            batch["patch_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.vision_tokens, self.cfg.d_model), np.float32
            )
        if self.cfg.is_encdec:
            batch["frames"] = rng.standard_normal(
                (self.batch, self.cfg.encoder_seq, self.cfg.d_model), np.float32
            )
        return batch

    def next(self) -> Dict[str, np.ndarray]:
        out = self.global_batch(self.state.step)
        self.state.step += 1
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # ------------------------------------------------------------------ #
    def host_shard(
        self, batch: Dict[str, np.ndarray], host_id: int, n_hosts: int
    ) -> Dict[str, np.ndarray]:
        """Slice this host's data-parallel rows (exact, contiguous)."""
        per = self.batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in batch.items()}
