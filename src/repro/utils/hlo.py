"""HLO-text analysis: collective-byte accounting for the roofline.

``collective_bytes(hlo_text)`` sums the operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in a compiled (post-SPMD, per-device) module.  cost_analysis() does not
report these, so we parse the text (DESIGN.md section 8).

Async pairs: ``*-start`` ops carry the operands; their ``*-done`` twins are
skipped so nothing is double counted.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

__all__ = ["collective_bytes", "DTYPE_BYTES", "op_histogram"]

DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# an operand like "bf16[8,128,1024]" (layout annotations optional)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# definition line: "%name = <result-type> op(...)" or "name.1 = ..."
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\S*)\s+([a-z][\w\-]*)\(([^)]*)\)",
    re.M,
)
_OPERAND_NAME_RE = re.compile(r"%?([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0  # token/opaque types
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def _type_bytes(type_str: str) -> int:
    """Bytes of a result type string (handles tuple types)."""
    return sum(_shape_bytes(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(type_str))


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Returns (total_operand_bytes, per-op-kind breakdown), per device.

    Post-optimization HLO prints operands as bare names (``all-reduce(%fusion.3)``),
    so this is a two-pass parse: first map instruction name -> result type,
    then sum the *operand* types of every collective (falling back to the
    collective's own result type when an operand is unresolvable, e.g. a
    parameter declared without a def line in scoped printouts).
    """
    types: Dict[str, str] = {}
    collectives = []
    for m in _DEF_RE.finditer(hlo_text):
        name, rtype, op, operands = m.groups()
        types[name] = rtype
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
            if op == c + "-done":
                base = "SKIP"
                break
        if base and base != "SKIP":
            collectives.append((base, rtype, operands))
    per_kind: Dict[str, int] = defaultdict(int)
    for kind, rtype, operands in collectives:
        total = 0
        # operands may be typed (unoptimized HLO) or bare names (optimized)
        typed = sum(
            _shape_bytes(sm.group(1), sm.group(2)) for sm in _SHAPE_RE.finditer(operands)
        )
        if typed:
            total = typed
        else:
            for om in _OPERAND_NAME_RE.finditer(operands):
                t = types.get(om.group(1))
                if t:
                    total += _type_bytes(t)
            if total == 0:
                total = _type_bytes(rtype)  # conservative fallback
        per_kind[kind] += total
    return sum(per_kind.values()), dict(per_kind)


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Counts of interesting ops (fusion/reshape/collective) for perf iteration."""
    ops = defaultdict(int)
    for name in (
        "fusion", "custom-call", "convolution", "dot", "transpose", "reshape",
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute", "dynamic-slice", "dynamic-update-slice", "while",
    ):
        ops[name] = len(re.findall(rf"\b{name}(?:\.\d+)?\(", hlo_text)) + len(
            re.findall(rf"= [^\n]*?\b{name}\(", hlo_text)
        )
    # cheap heuristic is noisy; prefer exact "= <type> op(" matches
    exact = defaultdict(int)
    for m in re.finditer(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9-]*)\(", hlo_text):
        exact[m.group(1)] += 1
    return dict(exact)
