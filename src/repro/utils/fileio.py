"""Crash-safe file writes shared across the repo.

Any state a process persists for its *next* life -- the kernel tuning
cache, a server's ``--metrics-dump`` snapshot -- must survive the process
dying mid-write.  The classic recipe: write a temp file **in the target
directory** (``os.replace`` is only atomic within one filesystem), fsync,
then atomically rename over the destination.  A reader (or a concurrent
writer) can never observe a truncated or interleaved file, and an
interrupted write leaves the previous snapshot intact.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Callable, TextIO

__all__ = ["atomic_write_json", "atomic_write_text"]


def _atomic_write(path: str, write: Callable[[TextIO], None], prefix: str) -> str:
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(prefix=prefix, suffix=".tmp", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            write(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def atomic_write_text(path: str, text: str, *, prefix: str = ".tmp-") -> str:
    """Atomically replace ``path`` with ``text`` (tempfile + fsync +
    ``os.replace``).  Returns ``path``.  On any failure the temp file is
    removed and the previous ``path`` contents are untouched."""
    return _atomic_write(path, lambda f: f.write(text), prefix)


def atomic_write_json(
    path: str, payload: Any, *, indent: int = 2, sort_keys: bool = True,
    prefix: str = ".tmp-",
) -> str:
    """:func:`atomic_write_text` for a JSON payload.  Serialization streams
    into the temp file, so a dump that dies half-way (disk full, unserializable
    leaf) leaves the destination untouched."""

    def write(f: TextIO) -> None:
        json.dump(payload, f, indent=indent, sort_keys=sort_keys)
        f.write("\n")

    return _atomic_write(path, write, prefix)
