"""Shared retry-with-backoff helper.

Promoted out of ``training/fault_tolerance.py`` (which keeps a back-compat
re-export) so the serving layer can reuse the same policy for
``QueueFullError`` submit retries.  Adds full jitter and injectable
sleep/rng so tests -- and the chaos suite -- drive the schedule
deterministically without wall-clock waits.
"""

from __future__ import annotations

import random
import time
from typing import Any, Callable, Optional, Tuple

__all__ = ["retry_call"]


def retry_call(
    fn: Callable[[], Any],
    *,
    retries: int = 3,
    backoff: float = 1.0,
    backoff_factor: float = 2.0,
    jitter: float = 0.0,
    retry_on: Tuple[type, ...] = (OSError, IOError, RuntimeError),
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
    rng: Optional[random.Random] = None,
) -> Any:
    """Run ``fn`` with exponential backoff on transient errors.

    Attempt ``i``'s failure sleeps ``backoff * backoff_factor**i`` seconds,
    stretched by up to ``jitter`` fraction (``delay * (1 + jitter * U[0,1))``)
    to decorrelate retry storms across concurrent callers.  The final
    failure re-raises.  ``on_retry(attempt, exc)`` observes every retried
    failure (attempt is 0-based)."""
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    if jitter < 0:
        raise ValueError(f"jitter must be >= 0, got {jitter}")
    rng = rng or random
    delay = backoff
    for attempt in range(retries + 1):
        try:
            return fn()
        except retry_on as e:  # noqa: PERF203
            if attempt == retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            sleep(delay * (1.0 + jitter * rng.random()))
            delay *= backoff_factor
