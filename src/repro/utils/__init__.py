from .fileio import atomic_write_json, atomic_write_text
from .flops import model_flops, param_counts
from .hlo import collective_bytes, op_histogram
from .retry import retry_call
