"""Version shims for jax API drift (container ships jax 0.4.37).

* ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax`` and
  renamed its replication-check kwarg ``check_rep`` -> ``check_vma``.
  Call sites use the new name; the shim translates downward.
* ``jax.lax.axis_size`` (static mapped-axis size) only exists on newer jax;
  0.4.x exposes the same number via ``jax.core.axis_frame``.
* ``Compiled.cost_analysis()`` returns a list of per-device-program dicts on
  jax<=0.4.x and a plain dict on newer jax.
"""

from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_VMA = "check_vma" in inspect.signature(_shard_map).parameters

__all__ = ["shard_map", "axis_size", "cost_analysis"]


def shard_map(f, *args, **kw):
    if "check_vma" in kw and not _HAS_VMA:
        kw["check_rep"] = kw.pop("check_vma")
    return _shard_map(f, *args, **kw)


def axis_size(axis_name) -> int:
    """Static size of a mapped mesh axis (usable in Python control flow)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax.core import axis_frame

    return int(axis_frame(axis_name))


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a single dict on every jax version."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
