"""Analytic MODEL_FLOPS accounting (roofline numerator, DESIGN.md section 8).

``MODEL_FLOPS = 6 * N * D`` for training, ``2 * N_active * D`` for inference,
with N the (active) parameter count and D the processed tokens.  Attention
score FLOPs are excluded by convention (the 6ND rule); the ratio against HLO
FLOPs therefore dips below 1 for long-context shapes -- expected and noted
per cell.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig

__all__ = ["param_counts", "model_flops"]


def param_counts(cfg: ArchConfig, params_shapes: Any) -> Dict[str, float]:
    """(total, active) parameter counts from eval_shape'd params.

    ``active`` scales routed-expert weights by top_k / n_routed and excludes
    the unembedding-free share the same way the 6ND convention does (we keep
    embeddings in N, as MaxText/PaLM accounting does).
    """
    total = 0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += n
        if cfg.moe is not None and "['experts']" in name:
            active += n * (cfg.moe.top_k / cfg.moe.n_routed)
        else:
            active += n
    return {"total": float(total), "active": float(active)}


def model_flops(
    cfg: ArchConfig, shape: ShapeConfig, counts: Dict[str, float]
) -> float:
    """Whole-step model FLOPs (all chips together)."""
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; the KV/state read is the memory story,
    # FLOPs remain 2*N per token
    return 2.0 * n_active * shape.global_batch
