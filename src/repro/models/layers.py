"""Shared neural-net layers (pure JAX, params = nested dicts).

``PrunedLinear`` is the integration point of the paper's technique: one layer
type whose *execution mode* is chosen by the compiler layer --

* ``dense``   plain ``x @ w`` (XLA native; dry-run baseline),
* ``masked``  ``x @ (w * mask)`` (ADMM training / masked fine-tune),
* ``bsr``     packed PBCSR blocks via the Pallas block-sparse kernel,
* ``colpack`` ColumnCompact gather + smaller dense GEMM.

Param init functions return nested dicts; ``repro.models.sharding`` assigns
PartitionSpecs by path pattern.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

__all__ = [
    "init_linear",
    "linear",
    "init_rmsnorm",
    "rmsnorm",
    "init_layernorm",
    "layernorm",
    "init_embedding",
    "embed",
    "rope_freqs",
    "apply_rope",
    "init_conv1d",
    "causal_conv1d",
    "conv1d_step",
]

Array = jax.Array
Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# linear (the pruned workhorse)                                                #
# --------------------------------------------------------------------------- #


def init_linear(
    key: Array, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.bfloat16,
    scale: Optional[float] = None,
) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p: Params = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(
    p: Params,
    x: Array,
    *,
    mode: str = "dense",
    activation: Optional[str] = None,
    use_pallas: bool = False,
) -> Array:
    """Apply a (possibly pruned) linear layer.

    ``mode`` selects the execution engine; packed modes expect the packed
    params produced by the compiler layer (values/kept or values/block_rows).
    ``use_pallas`` routes dense/masked through the fused Pallas matmul
    (real-TPU path); default jnp keeps CPU tests fast and the dry-run HLO
    clean for XLA fusion analysis.
    """
    if mode in ("dense", "masked"):
        w = p["w"]
        if mode == "masked":
            w = w * p["mask"].astype(w.dtype)
        if use_pallas:
            return kops.matmul(x, w, p.get("b"), activation=activation)
        y = x @ w
        if "b" in p:
            y = y + p["b"]
        return _act(y, activation)
    if mode == "bsr":
        return kops.bsr_matmul(
            x, p["values"], p["block_rows"], p.get("b"),
            activation=activation, bands=p.get("bands"),
        )
    if mode == "bsr_xla":
        # XLA-native block-sparse execution (GSPMD-shardable; used by the
        # dry-run/pjit path where a Pallas custom-call cannot lower on CPU):
        # gather the x block-rows each output block-column needs, one einsum.
        # FLOPs scale with density exactly like the Pallas kernel.
        values, rows = p["values"], p["block_rows"]  # [Nb,S,bm,bn], [Nb,S]
        nb, s, bm, bn = values.shape
        lead = x.shape[:-1]
        xb = x.reshape(*lead, x.shape[-1] // bm, bm)
        xg = jnp.take(xb, jnp.maximum(rows, 0), axis=-2)  # [..., Nb, S, bm]
        y = jnp.einsum("...jsb,jsbn->...jn", xg, values)
        y = y.reshape(*lead, nb * bn)
        if "b" in p:
            y = y + p["b"]
        return _act(y, activation)
    if mode == "colpack":
        return kops.col_matmul(
            x, p["values"], p["kept"], p.get("b"), activation=activation
        )
    if mode == "colpack_xla":
        y = jnp.take(x, p["kept"], axis=-1) @ p["values"]
        if "b" in p:
            y = y + p["b"]
        return _act(y, activation)
    raise ValueError(f"unknown linear mode {mode!r}")


def init_pruned_linear(
    key: Array,
    d_in: int,
    d_out: int,
    *,
    exec_mode: str,
    sparsity: float,
    bm: int = 128,
    bn: int = 128,
    bias: bool = False,
    dtype=jnp.bfloat16,
) -> Params:
    """Packed-parameter init for the sparse execution modes.

    Synthetic-but-valid packing (kept indices / block rows are deterministic
    stripes): shapes are what a real ADMM->compiler pipeline would emit, so
    dry-run lowering and CPU smoke execution both work.
    """
    scale = 1.0 / math.sqrt(d_in)
    if exec_mode in ("colpack", "colpack_xla"):
        k_kept = max(1, int(round(d_in * (1.0 - sparsity))))
        p: Params = {
            "values": (jax.random.normal(key, (k_kept, d_out), jnp.float32) * scale).astype(dtype),
            "kept": jnp.arange(k_kept, dtype=jnp.int32) * (d_in // k_kept),
        }
    elif exec_mode in ("bsr", "bsr_xla"):
        kb, nb = d_in // bm, d_out // bn
        s = max(1, int(round(kb * (1.0 - sparsity))))
        p = {
            "values": (jax.random.normal(key, (nb, s, bm, bn), jnp.float32) * scale).astype(dtype),
            # stripe pattern: block-column j reads rows (j+i) % kb
            "block_rows": (
                (jnp.arange(nb, dtype=jnp.int32)[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :])
                % kb
            ),
        }
    else:
        raise ValueError(exec_mode)
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def _act(x: Array, name: Optional[str]) -> Array:
    if name is None:
        return x
    return {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu,
            "tanh": jnp.tanh}[name](x)


# --------------------------------------------------------------------------- #
# norms                                                                        #
# --------------------------------------------------------------------------- #


def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


def init_layernorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


# --------------------------------------------------------------------------- #
# embedding                                                                    #
# --------------------------------------------------------------------------- #


def init_embedding(key: Array, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


# --------------------------------------------------------------------------- #
# RoPE                                                                         #
# --------------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [..., S, H, dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [dh/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# causal depthwise conv1d (mamba / griffin stem)                               #
# --------------------------------------------------------------------------- #


def init_conv1d(key: Array, channels: int, width: int, dtype=jnp.bfloat16) -> Params:
    scale = 1.0 / math.sqrt(width)
    return {
        "w": (jax.random.normal(key, (width, channels), jnp.float32) * scale).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(p: Params, x: Array) -> Array:
    """Depthwise causal conv over sequence.  x: [B, S, C] -> [B, S, C]."""
    width = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # width is 4: unrolled taps fuse into one kernel
        out = out + pad[:, i : i + x.shape[1], :].astype(jnp.float32) * p["w"][i].astype(jnp.float32)
    return (out + p["b"].astype(jnp.float32)).astype(x.dtype)


def conv1d_step(p: Params, window: Array, x_t: Array) -> Tuple[Array, Array]:
    """Single decode step.  window: [B, width-1, C] past inputs; returns
    (y_t [B, C], new_window)."""
    width = p["w"].shape[0]
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # [B, width, C]
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32), p["w"].astype(jnp.float32))
    y = (y + p["b"].astype(jnp.float32)).astype(x_t.dtype)
    return y, full[:, 1:, :]
