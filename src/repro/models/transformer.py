"""Generic decoder-only LM covering the dense / MoE / SSM / hybrid families.

Every layer has a *block kind*:

* ``attn``       pre-norm GQA (or MLA) + pre-norm FFN (MLP or MoE)
* ``localattn``  same but sliding-window attention (hybrid archs)
* ``mamba``      single pre-norm Mamba-2 mixer (no FFN, as in Mamba)
* ``rec``        pre-norm RG-LRU recurrent block + pre-norm MLP (Griffin)

``block_kinds(cfg)`` derives the per-layer pattern from the ArchConfig;
``forward`` runs full sequences (train/prefill), ``decode_step`` one token
against per-layer caches.  Layers are a python list (unrolled lowering =
exact dry-run HLO accounting; ``cfg.use_scan`` stacks homogeneous layers).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from . import ffn as ffn_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import embed, init_embedding, init_linear, init_rmsnorm, linear, rmsnorm

Array = jax.Array
Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# structure                                                                    #
# --------------------------------------------------------------------------- #


def block_kinds(cfg: ArchConfig) -> List[str]:
    if cfg.ssm is not None:
        return ["mamba"] * cfg.n_layers
    if cfg.recurrent is not None:
        pat = cfg.recurrent.pattern
        return [pat[i % len(pat)] for i in range(cfg.n_layers)]
    return ["attn"] * cfg.n_layers


def scan_plan(cfg: ArchConfig) -> Tuple[List[int], int, int, List[int]]:
    """Layer grouping for scan-mode lowering (compile-time at 512 devices).

    Returns (prefix_layers, unit_len, n_units, suffix_layers): ``prefix`` and
    ``suffix`` run unrolled (structurally distinct layers, e.g. DeepSeek's
    dense-FFN layer 0 or a hybrid pattern remainder); the middle
    ``n_units`` repetitions of the ``unit_len``-layer pattern run as one
    ``lax.scan`` over stacked params.
    """
    kinds = block_kinds(cfg)
    prefix: List[int] = []
    start = 0
    if cfg.moe is not None and cfg.moe.first_dense > 0:
        prefix = list(range(cfg.moe.first_dense))
        start = cfg.moe.first_dense
    unit = len(cfg.recurrent.pattern) if cfg.recurrent is not None else 1
    body = cfg.n_layers - start
    n_units = body // unit
    suffix = list(range(start + n_units * unit, cfg.n_layers))
    return prefix, unit, n_units, suffix


def _attn_kind(cfg: ArchConfig) -> str:
    return "mla" if cfg.kv_lora_rank else "gqa"


def _is_moe_layer(cfg: ArchConfig, i: int) -> bool:
    return cfg.moe is not None and i >= cfg.moe.first_dense


# --------------------------------------------------------------------------- #
# init                                                                         #
# --------------------------------------------------------------------------- #


def init_layer(key: Array, cfg: ArchConfig, i: int, dtype=jnp.bfloat16) -> Params:
    kind = block_kinds(cfg)[i]
    keys = jax.random.split(key, 4)
    p: Params = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba2(keys[0], cfg, dtype)
        return p
    if kind == "rec":
        p["mixer"] = rglru_mod.init_rglru_block(keys[0], cfg, dtype)
    else:  # attn / localattn
        if _attn_kind(cfg) == "mla":
            p["attn"] = attn_mod.init_mla(keys[0], cfg, dtype)
        else:
            p["attn"] = attn_mod.init_gqa(keys[0], cfg, dtype)
    p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
    if kind in ("attn", "localattn") and _is_moe_layer(cfg, i):
        p["moe"] = ffn_mod.init_moe(keys[1], cfg, dtype)
    else:
        prune = None
        if cfg.prune.enabled:
            # paper recipe (DESIGN.md section 7): column-prune the FFN
            prune = ("colpack_xla", cfg.prune.sparsity)
        p["ffn"] = ffn_mod.init_mlp(keys[1], cfg.d_model, cfg.d_ff, dtype, prune=prune)
    return p


def init_lm(key: Array, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    p: Params = {
        "embed": init_embedding(keys[0], cfg.vocab_padded, cfg.d_model, dtype),
        "layers": [init_layer(keys[i + 1], cfg, i, dtype) for i in range(cfg.n_layers)],
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(keys[-1], cfg.d_model, cfg.vocab_padded, dtype=dtype)
    if cfg.vision_tokens:
        p["vision_proj"] = init_linear(keys[-2], cfg.d_model, cfg.d_model, dtype=dtype)
    return p


# --------------------------------------------------------------------------- #
# forward (train / prefill)                                                    #
# --------------------------------------------------------------------------- #


def _apply_block(
    p: Params,
    cfg: ArchConfig,
    kind: str,
    i: int,
    x: Array,
    positions: Array,
    *,
    prefix_len: int = 0,
    attn_impl: str = "auto",
    mode: str = "dense",
    attn_chunk: int = 1024,
) -> Tuple[Array, Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "mamba":
        return x + ssm_mod.mamba2_forward(p["mixer"], cfg, h), aux
    if kind == "rec":
        mixed = rglru_mod.rglru_block(p["mixer"], cfg, h)
    elif _attn_kind(cfg) == "mla":
        mixed = attn_mod.mla_attention(p["attn"], cfg, h, positions, impl=attn_impl)
    else:
        window = cfg.recurrent.window if (kind == "localattn" and cfg.recurrent) else None
        mixed = attn_mod.gqa_attention(
            p["attn"], cfg, h, positions,
            window=window, prefix_len=prefix_len, impl=attn_impl, mode=mode,
            chunk=attn_chunk,
        )
    x = x + mixed
    h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if "moe" in p:
        y, aux = ffn_mod.moe(p["moe"], cfg, h2, activation=cfg.ffn_activation)
    else:
        y = ffn_mod.mlp(p["ffn"], h2, activation=cfg.ffn_activation, mode=mode)
    return x + y, aux


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: Array,  # [B, S] int32
    *,
    patch_embeds: Optional[Array] = None,  # [B, P, D] VLM stub frontend
    attn_impl: str = "auto",
    mode: str = "dense",
    remat: bool = False,
    layout_scan: bool = False,
    remat_policy: str = "full",
    residual_spec=None,
    attn_chunk: int = 1024,
) -> Tuple[Array, Array]:
    """Returns (logits [B, S_text, V], aux_loss).

    ``remat=True`` checkpoints each block (recompute activations in the
    backward pass) -- the standard memory/compute trade for train_4k at the
    production mesh.  ``layout_scan=True`` lowers the repeated layer pattern
    as one ``lax.scan`` over stacked params (see scan_plan) -- compile time
    at 512 devices stays seconds instead of minutes."""
    x = embed(params["embed"], tokens)
    prefix_len = 0
    if patch_embeds is not None:
        vis = linear(params["vision_proj"], patch_embeds)
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
        prefix_len = patch_embeds.shape[1]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kinds = block_kinds(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def run_block(p_, x_, kind, i):
        def blk(p__, x__):
            out, aux = _apply_block(
                p__, cfg, kind, i, x__, positions,
                prefix_len=prefix_len, attn_impl=attn_impl, mode=mode,
                attn_chunk=attn_chunk,
            )
            if residual_spec is not None:
                # e.g. sequence parallelism: keep the residual stream sharded
                # over ('model') along S between blocks
                out = jax.lax.with_sharding_constraint(out, residual_spec)
            return out, aux

        if remat:
            if remat_policy == "dots":
                # save matmul outputs (incl. the TP-collective results): the
                # backward pass re-reads instead of recompute+re-communicate
                blk = jax.checkpoint(
                    blk, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                )
            else:
                blk = jax.checkpoint(blk)
        return blk(p_, x_)

    if not layout_scan:
        for i, (p, kind) in enumerate(zip(params["layers"], kinds)):
            x, aux = run_block(p, x, kind, i)
            aux_total = aux_total + aux
    else:
        prefix, unit, n_units, suffix = scan_plan(cfg)
        for i in prefix:
            x, aux = run_block(params["layers"][i], x, kinds[i], i)
            aux_total = aux_total + aux
        start = len(prefix)
        if n_units > 0:
            # stack each pattern position's layers: dict pos -> [n_units, ...]
            stacked = {
                pos: jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[params["layers"][start + u * unit + pos] for u in range(n_units)],
                )
                for pos in range(unit)
            }

            def body(carry, unit_params):
                x_, aux_ = carry
                for pos in range(unit):
                    kind = kinds[start + pos]
                    x_, a = run_block(unit_params[pos], x_, kind, start + pos)
                    aux_ = aux_ + a
                return (x_, aux_), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
        for i in suffix:
            x, aux = run_block(params["layers"][i], x, kinds[i], i)
            aux_total = aux_total + aux
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefix_len:
        x = x[:, prefix_len:]
    logits = _unembed(params, cfg, x)
    return logits, aux_total


def _unembed(params: Params, cfg: ArchConfig, x: Array) -> Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = linear(params["lm_head"], x)
    if cfg.vocab_padded != cfg.vocab:  # mask pad classes (never predicted)
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def loss_fn(
    params: Params,
    cfg: ArchConfig,
    batch: Dict[str, Array],
    *,
    attn_impl: str = "auto",
    mode: str = "dense",
    remat: bool = False,
    layout_scan: bool = False,
    remat_policy: str = "full",
    residual_spec=None,
    attn_chunk: int = 1024,
) -> Tuple[Array, Dict[str, Array]]:
    """Next-token cross entropy (+ MoE aux)."""
    logits, aux = forward(
        params, cfg, batch["tokens"],
        patch_embeds=batch.get("patch_embeds"), attn_impl=attn_impl, mode=mode,
        remat=remat, layout_scan=layout_scan, remat_policy=remat_policy,
        residual_spec=residual_spec, attn_chunk=attn_chunk,
    )
    labels = batch["labels"]
    # CE via logsumexp: one f32 reduction instead of a full log_softmax copy
    logits32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    picked = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    weights = batch.get("weights", jnp.ones_like(nll))
    ce = jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    total = ce + aux_w * aux
    return total, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------- #
# prefill (forward + populated caches, for the serving engine)                 #
# --------------------------------------------------------------------------- #


def prefill(
    params: Params,
    cfg: ArchConfig,
    tokens: Array,
    max_len: int,
    *,
    patch_embeds: Optional[Array] = None,
    attn_impl: str = "auto",
) -> Tuple[Array, List[Params]]:
    """Returns (logits [B, S_text, V], caches positioned at S)."""
    x = embed(params["embed"], tokens)
    prefix_len = 0
    if patch_embeds is not None:
        vis = linear(params["vision_proj"], patch_embeds)
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
        prefix_len = patch_embeds.shape[1]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kinds = block_kinds(cfg)
    caches: List[Params] = []
    for i, (p, kind) in enumerate(zip(params["layers"], kinds)):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        if kind == "mamba":
            mixed, cache = ssm_mod.mamba2_forward(p["mixer"], cfg, h, return_state=True)
            x = x + mixed
            caches.append(cache)
            continue
        if kind == "rec":
            mixed, cache = rglru_mod.rglru_block(p["mixer"], cfg, h, return_state=True)
        elif _attn_kind(cfg) == "mla":
            mixed, cache = attn_mod.mla_prefill(
                p["attn"], cfg, h, positions, max_len, impl=attn_impl
            )
        else:
            window = cfg.recurrent.window if (kind == "localattn" and cfg.recurrent) else None
            mixed, cache = attn_mod.gqa_prefill(
                p["attn"], cfg, h, positions, max_len,
                window=window, prefix_len=prefix_len, impl=attn_impl,
            )
        x = x + mixed
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if "moe" in p:
            y, _ = ffn_mod.moe(p["moe"], cfg, h2, activation=cfg.ffn_activation)
        else:
            y = ffn_mod.mlp(p["ffn"], h2, activation=cfg.ffn_activation)
        x = x + y
        caches.append(cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefix_len:
        x = x[:, prefix_len:]
    return _unembed(params, cfg, x), caches


# --------------------------------------------------------------------------- #
# decode                                                                       #
# --------------------------------------------------------------------------- #


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> List[Params]:
    caches: List[Params] = []
    for i, kind in enumerate(block_kinds(cfg)):
        if kind == "mamba":
            caches.append(ssm_mod.init_mamba2_cache(cfg, batch, dtype))
        elif kind == "rec":
            caches.append(rglru_mod.init_rglru_cache(cfg, batch, dtype))
        elif _attn_kind(cfg) == "mla":
            caches.append(attn_mod.init_mla_cache(cfg, batch, max_len, dtype))
        else:
            window = cfg.recurrent.window if (kind == "localattn" and cfg.recurrent) else None
            caches.append(
                attn_mod.init_kv_cache(cfg, batch, max_len, window=window, dtype=dtype)
            )
    return caches


def decode_step(
    params: Params,
    cfg: ArchConfig,
    tokens_t: Array,  # [B, 1] int32
    caches: List[Params],
    *,
    mode: str = "dense",
) -> Tuple[Array, List[Params]]:
    """One token for the whole stack.  Returns (logits [B, 1, V], caches)."""
    x = embed(params["embed"], tokens_t)
    kinds = block_kinds(cfg)
    new_caches: List[Params] = []
    for i, (p, kind, cache) in enumerate(zip(params["layers"], kinds, caches)):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        if kind == "mamba":
            mixed, cache = ssm_mod.mamba2_step(p["mixer"], cfg, h, cache)
            x = x + mixed
            new_caches.append(cache)
            continue
        if kind == "rec":
            mixed, cache = rglru_mod.rglru_step(p["mixer"], cfg, h, cache)
        elif _attn_kind(cfg) == "mla":
            mixed, cache = attn_mod.mla_decode_step(p["attn"], cfg, h, cache)
        else:
            window = cfg.recurrent.window if (kind == "localattn" and cfg.recurrent) else None
            mixed, cache = attn_mod.gqa_decode_step(
                p["attn"], cfg, h, cache, window=window, mode=mode
            )
        x = x + mixed
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if "moe" in p:
            y, _ = ffn_mod.moe(p["moe"], cfg, h2, activation=cfg.ffn_activation)
        else:
            y = ffn_mod.mlp(p["ffn"], h2, activation=cfg.ffn_activation, mode=mode)
        x = x + y
        new_caches.append(cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _unembed(params, cfg, x), new_caches
