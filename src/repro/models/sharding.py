"""Path-pattern -> PartitionSpec rules (MaxText-style logical sharding).

Tensor-parallel layout over the ``model`` mesh axis; batch over
``("pod","data")`` (or ``("data",)`` single-pod).  Rules are ordered; first
substring match on the ``jax.tree_util.keystr`` path wins.  Anything
unmatched is replicated -- safe default for norms/scalars.
"""

from __future__ import annotations

import re
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_pspecs", "param_shardings", "batch_spec", "DEFAULT_RULES", "FSDP_RULES"]

PyTree = Any

# (substring-regex, spec) -- specs written with "model" TP axis only; the
# batch axes never appear in parameter specs.
DEFAULT_RULES: List[Tuple[str, P]] = [
    # embeddings / unembedding: vocab-sharded
    (r"\['embed'\].*table", P("model", None)),
    (r"\['lm_head'\].*\['w'\]", P(None, "model")),
    # MoE expert stacks [E, D, F]: expert-parallel
    (r"\['experts'\]\['w_gate'\]", P("model", None, None)),
    (r"\['experts'\]\['w_up'\]", P("model", None, None)),
    (r"\['experts'\]\['w_down'\]", P("model", None, None)),
    (r"\['router'\]", P(None)),
    # attention: heads over model
    (r"\['(w_q|w_k|w_v|w_uq|w_uk|w_uv)'\]\['w'\]", P(None, "model")),
    (r"\['(w_q|w_k|w_v|w_uq|w_uk|w_uv)'\]\['b'\]", P("model")),
    (r"\['w_o'\]\['w'\]", P("model", None)),
    (r"\['(w_dq|w_dkv|w_kr)'\]\['w'\]", P(None, None)),  # small latent projs
    # gated FFN: column-parallel in, row-parallel out
    (r"\['(w_gate|w_up|in_proj|gate_proj|w_r|w_i)'\]\['w'\]", P(None, "model")),
    (r"\['(w_gate|w_up|in_proj|gate_proj|w_r|w_i)'\]\['b'\]", P("model")),
    (r"\['(w_down|out_proj)'\]\['w'\]", P("model", None)),
    # packed sparse weights: PBCSR values [Nb, S, bm, bn] -> output-column
    # sharded (block-cols over model); ColumnCompact values like the dense w.
    (r"\['values'\]", P("model", None, None, None)),
    (r"\['block_rows'\]", P("model", None)),
    # conv1d stems, norms, scalars: replicated
]


# FSDP variant: weights additionally sharded over ``data`` so >100B-param
# configs (deepseek-v2-236b) fit per-chip HBM; GSPMD all-gathers shards at
# use sites (the memory <-> collective trade recorded in section Roofline).
FSDP_RULES: List[Tuple[str, P]] = [
    (r"\['embed'\].*table", P("model", "data")),
    (r"\['lm_head'\]\['w'\]", P("data", "model")),
    (r"\['experts'\]\['w_gate'\]", P("model", "data", None)),
    (r"\['experts'\]\['w_up'\]", P("model", "data", None)),
    (r"\['experts'\]\['w_down'\]", P("model", "data", None)),
    (r"\['router'\]", P(None)),
    (r"\['(w_q|w_k|w_v|w_uq|w_uk|w_uv)'\]\['w'\]", P("data", "model")),
    (r"\['(w_q|w_k|w_v|w_uq|w_uk|w_uv)'\]\['b'\]", P("model")),
    (r"\['w_o'\]\['w'\]", P("model", "data")),
    (r"\['(w_dq|w_dkv|w_kr)'\]\['w'\]", P("data", None)),
    (r"\['(w_gate|w_up|in_proj|gate_proj|w_r|w_i)'\]\['w'\]", P("data", "model")),
    (r"\['(w_gate|w_up|in_proj|gate_proj|w_r|w_i)'\]\['b'\]", P("model")),
    (r"\['(w_down|out_proj)'\]\['w'\]", P("model", "data")),
    (r"\['values'\]", P("model", None, None, None)),
    (r"\['block_rows'\]", P("model", None)),
]


def _spec_for(path: str, rules) -> Optional[P]:
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return None


def param_pspecs(params: PyTree, rules=None) -> PyTree:
    """Mirror tree of PartitionSpecs (P() for unmatched leaves)."""
    rules = DEFAULT_RULES if rules is None else rules

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        s = _spec_for(jax.tree_util.keystr(path), rules)
        if s is None:
            specs.append(P())
            continue
        nd = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        if len(s) > nd:  # e.g. a 2-D rule hit a packed 1-D leaf: replicate
            specs.append(P())
        else:
            specs.append(P(*s, *([None] * (nd - len(s)))))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(mesh: Mesh, params: PyTree, rules=None) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params, rules)
    )


def batch_spec(mesh: Mesh) -> P:
    """Batch axis spec: ('pod','data') when the pod axis exists."""
    if "pod" in mesh.axis_names:
        return P(("pod", "data"))
    return P("data")
