"""Decoder-block lowering: ``models/transformer.py`` params -> graph IR.

This is the bridge between the jnp model stack and the plan compiler: a
dense GQA decoder (the qwen-family shape) becomes a :class:`Graph` of
registered executor ops, so the whole PassManager pipeline (epilogue fusion,
CSE, DCE -- and quantize/sparsify when calibrated) applies to autoregressive
inference exactly as it does to the CNN demo apps.

Two phases, two graphs (an autoregressive server compiles both):

* ``phase="prefill"``: inputs ``(tokens [B, S], positions [B, S],
  lengths [B])`` -> outputs ``(logits [B, S, V_pad], k_rope_0, v_0, ...,
  k_rope_{L-1}, v_{L-1})`` with per-layer k/v as ``[B, S, G*dh]`` (k is
  post-RoPE -- the cache stores roped keys, matching ``gqa_prefill``).
  ``lengths`` masks each row to its own prompt inside the padded batch.
* ``phase="decode"``: inputs ``(tokens [B, 1], positions [B, 1],
  k_ctx [B, L, S, G, dh], v_ctx [B, L, S, G, dh], lengths [B])`` -> outputs
  ``(logits [B, 1, V_pad], k_rope_0, v_0, ...)`` with the fresh per-layer
  k/v as ``[B, 1, G*dh]``.  The attention op merges the fresh KV into the
  gathered cache span at slot == length -- ``gqa_decode_step`` semantics
  over a paged gather instead of a ring buffer.

The lowering is *op-per-layer-component* on purpose: RoPE and the residual
adds/final norm start as standalone nodes and the ``fuse_epilogue`` pass
folds them into their producing GEMMs (rope -> q/k projections, residual
add -> w_o/w_down, final rmsnorm -> the last w_down), which is the
measurable plan-step reduction BENCH_decode tracks.
"""

from __future__ import annotations

from typing import Any, Dict

from ..configs.base import ArchConfig
from ..core.graph.ir import Graph, GraphBuilder
from .transformer import block_kinds

__all__ = ["build_decoder_graph", "decoder_cache_spec"]

Params = Dict[str, Any]


def decoder_cache_spec(cfg: ArchConfig) -> Dict[str, int]:
    """The per-token KV footprint the paged cache must provision:
    ``n_layers x n_kv_heads x head_dim`` per token for each of k and v."""
    return {
        "n_layers": cfg.n_layers,
        "n_kv_heads": cfg.n_kv_heads,
        "head_dim": cfg.resolved_head_dim,
    }


def _check_supported(params: Params, cfg: ArchConfig) -> None:
    kinds = set(block_kinds(cfg))
    if kinds != {"attn"}:
        raise NotImplementedError(
            f"decoder lowering supports dense GQA blocks only, got {kinds}"
        )
    if cfg.kv_lora_rank:
        raise NotImplementedError("MLA attention is not lowered yet")
    if cfg.qk_norm:
        raise NotImplementedError("qk_norm is not lowered yet")
    if cfg.moe is not None or cfg.vision_tokens or cfg.is_encdec:
        raise NotImplementedError("MoE/VLM/enc-dec configs are not lowered")
    layer0 = params["layers"][0]
    if "w" not in layer0["attn"]["w_q"] or "w" not in layer0["ffn"]["w_gate"]:
        raise NotImplementedError(
            "pruned/packed decoder params are not lowered yet (dense 'w' only)"
        )


def _linear_params(p: Params) -> Params:
    out = {"w": p["w"]}
    if "b" in p:
        out["b"] = p["b"]
    return out


def build_decoder_graph(
    params: Params, cfg: ArchConfig, *, phase: str = "prefill"
) -> Graph:
    """Lower ``init_lm`` params into an executable decoder graph for one
    phase.  Pass the result through ``passes.optimize`` before
    ``compile_plan`` to get the fused production plan."""
    if phase not in ("prefill", "decode"):
        raise ValueError(f"phase must be prefill|decode, got {phase!r}")
    _check_supported(params, cfg)
    decode = phase == "decode"
    h, g = cfg.n_heads, cfg.n_kv_heads
    theta = cfg.rope_theta
    eps = cfg.norm_eps

    inputs = ["tokens", "positions"]
    if decode:
        inputs += ["k_ctx", "v_ctx"]
    inputs.append("lengths")
    b = GraphBuilder(inputs)

    x = b.add("embed", "tokens", name="embed",
              params={"table": params["embed"]["table"]})
    outputs = ["logits"]
    for i, lp in enumerate(params["layers"]):
        hn = b.add("rmsnorm", x, name=f"norm1_{i}",
                   params={"scale": lp["norm1"]["scale"]}, eps=eps)
        ap = lp["attn"]
        q = b.add("linear", hn, name=f"q_{i}", params=_linear_params(ap["w_q"]))
        k = b.add("linear", hn, name=f"k_{i}", params=_linear_params(ap["w_k"]))
        v = b.add("linear", hn, name=f"v_{i}", params=_linear_params(ap["w_v"]))
        qr = b.add("rope", (q, "positions"), name=f"q_rope_{i}",
                   heads=h, theta=theta)
        kr = b.add("rope", (k, "positions"), name=f"k_rope_{i}",
                   heads=g, theta=theta)
        attn_inputs = (
            (qr, kr, v, "k_ctx", "v_ctx", "lengths") if decode
            else (qr, kr, v, "lengths")
        )
        attrs: Dict[str, Any] = dict(
            phase=phase, n_heads=h, n_kv_heads=g,
        )
        if decode:
            attrs["layer"] = i
        at = b.add("attention", attn_inputs, name=f"attn_{i}", **attrs)
        o = b.add("linear", at, name=f"o_{i}", params=_linear_params(ap["w_o"]))
        x1 = b.add("add", (o, x), name=f"res1_{i}")
        h2 = b.add("rmsnorm", x1, name=f"norm2_{i}",
                   params={"scale": lp["norm2"]["scale"]}, eps=eps)
        gu = b.add("ffn", h2, name=f"gu_{i}",
                   params={"w_gate": lp["ffn"]["w_gate"]["w"],
                           "w_up": lp["ffn"]["w_up"]["w"]},
                   activation=cfg.ffn_activation)
        dn = b.add("linear", gu, name=f"down_{i}",
                   params=_linear_params(lp["ffn"]["w_down"]))
        x = b.add("add", (dn, x1), name=f"res2_{i}")
        outputs += [kr, v]

    fin = b.add("rmsnorm", x, name="final_norm",
                params={"scale": params["final_norm"]["scale"]}, eps=eps)
    if cfg.tie_embeddings:
        w_out = params["embed"]["table"].T
    else:
        w_out = params["lm_head"]["w"]
    b.add("unembed", fin, name="logits", params={"w": w_out},
          vocab=cfg.vocab)
    return b.build(outputs)
