"""Mamba-2 (SSD, state-space duality) block -- arXiv:2405.21060.

Chunked SSD: the sequence is cut into chunks of length L; within a chunk the
dual (quadratic, attention-like) form runs on the MXU, across chunks a linear
recurrence carries the [H, N, P] state.  Decode is the pure recurrence --
constant state, which is why mamba2 runs the ``long_500k`` shape.

Shapes: x [B, S, D]; inner width P_total = expand*D split into H heads of
P = head_dim; B/C projections have N = d_state per group (n_groups shared
across heads).  Gated RMSNorm + out_proj close the block.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import causal_conv1d, conv1d_step, init_conv1d, init_linear, init_rmsnorm, linear, rmsnorm

Array = jax.Array
Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    n_heads = d_inner // sc.head_dim
    return d_inner, n_heads, sc.d_state, sc.head_dim, sc.n_groups


def init_mamba2(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    sc = cfg.ssm
    d_inner, h, n, p_dim, g = _dims(cfg)
    d_xbc = d_inner + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(k1, cfg.d_model, 2 * d_inner + 2 * g * n + h, dtype=dtype),
        "conv": init_conv1d(k2, d_xbc, sc.d_conv, dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": init_rmsnorm(d_inner, dtype),
        "out_proj": init_linear(k3, d_inner, cfg.d_model, dtype=dtype),
    }


def _split_proj(cfg: ArchConfig, proj: Array):
    d_inner, h, n, p_dim, g = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg: ArchConfig, xbc: Array):
    d_inner, h, n, p_dim, g = _dims(cfg)
    x, bc = jnp.split(xbc, [d_inner], axis=-1)
    b_proj, c_proj = jnp.split(bc, 2, axis=-1)
    return x, b_proj, c_proj


def mamba2_forward(p: Params, cfg: ArchConfig, x: Array, *, return_state: bool = False):
    """Full-sequence chunked SSD.  x: [B, S, D] -> [B, S, D].

    ``return_state=True`` additionally returns the decode cache after the
    sequence (final SSD state + conv window) -- the chunked-prefill path for
    serving."""
    sc = cfg.ssm
    d_inner, h, n, p_dim, g = _dims(cfg)
    bsz, s, _ = x.shape
    L = min(sc.chunk, s)
    while s % L:  # largest chunk <= cfg that divides S (exactness over speed)
        L -= 1
    nc = s // L

    proj = linear(p["in_proj"], x)
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = jax.nn.silu(causal_conv1d(p["conv"], xbc_raw).astype(jnp.float32)).astype(x.dtype)
    xs, b_proj, c_proj = _split_xbc(cfg, xbc)

    xs = xs.reshape(bsz, nc, L, h, p_dim).astype(jnp.float32)
    B = b_proj.reshape(bsz, nc, L, g, n).astype(jnp.float32)
    C = c_proj.reshape(bsz, nc, L, g, n).astype(jnp.float32)
    rep = h // g
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    dt = dt.reshape(bsz, nc, L, h)
    A = -jnp.exp(p["A_log"])  # [H] negative
    dA = dt * A  # log-decay per step  [B,nc,L,H]

    # cumulative log decay within chunk
    cum = jnp.cumsum(dA, axis=2)  # [B,nc,L,H]
    # intra-chunk (dual quadratic form):
    # Y_intra[t] = sum_{s<=t} (C_t . B_s) exp(cum_t - cum_s) dt_s x_s
    # mask BEFORE exp: the upper triangle has positive exponents whose inf
    # would poison gradients through the where (d/dx where(c, inf*0) = nan)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,T,S,H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bnlgd,bnsgd->bnlsg", C, B)  # [B,nc,T,S,G]
    cb = jnp.repeat(cb, rep, axis=-1)  # -> [B,nc,T,S,H]
    att = cb * decay * dt[:, :, None, :, :]  # weight on x_s
    y_intra = jnp.einsum("bnlsh,bnshp->bnlhp", att, xs)

    # chunk states: S_c = sum_s exp(cum_last - cum_s) dt_s B_s x_s^T  [B,nc,H,N,P]
    last = cum[:, :, -1:, :]  # [B,nc,1,H]
    w = jnp.exp(last - cum) * dt  # [B,nc,L,H]
    Bh = jnp.repeat(B, rep, axis=-2) if g > 1 else jnp.broadcast_to(
        B, (bsz, nc, L, h, n)
    ) if g == 1 else B
    states = jnp.einsum("bnlh,bnlhd,bnlhp->bnhdp", w, Bh, xs)

    # inter-chunk recurrence over nc (python loop: nc known statically)
    chunk_decay = jnp.exp(last[:, :, 0, :])  # [B,nc,H]
    hstate = jnp.zeros((bsz, h, n, p_dim), jnp.float32)
    y_inter_chunks = []
    Ch = jnp.repeat(C, rep, axis=-2) if g > 1 else jnp.broadcast_to(
        C, (bsz, nc, L, h, n)
    ) if g == 1 else C
    for ci in range(nc):
        # contribution of h entering this chunk
        dec_t = jnp.exp(cum[:, ci])  # [B,L,H]
        y_in = jnp.einsum("blhd,bhdp,blh->blhp", Ch[:, ci], hstate, dec_t)
        y_inter_chunks.append(y_in)
        hstate = hstate * chunk_decay[:, ci][:, :, None, None] + states[:, ci]
    y_inter = jnp.stack(y_inter_chunks, axis=1)  # [B,nc,L,H,P]

    y = y_intra + y_inter + p["D"][None, None, None, :, None] * xs
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), cfg.norm_eps)
    out = linear(p["out_proj"], y)
    if not return_state:
        return out
    width = p["conv"]["w"].shape[0]
    pad = jnp.pad(xbc_raw, ((0, 0), (width - 1, 0), (0, 0)))
    cache = {"state": hstate, "conv": pad[:, -(width - 1):, :]}
    return out, cache


# ------------------------------ decode ------------------------------------- #


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    sc = cfg.ssm
    d_inner, h, n, p_dim, g = _dims(cfg)
    d_xbc = d_inner + 2 * g * n
    return {
        "state": jnp.zeros((batch, h, n, p_dim), jnp.float32),
        "conv": jnp.zeros((batch, sc.d_conv - 1, d_xbc), dtype),
    }


def mamba2_step(
    p: Params, cfg: ArchConfig, x_t: Array, cache: Params
) -> Tuple[Array, Params]:
    """One decode step.  x_t: [B, 1, D]."""
    d_inner, h, n, p_dim, g = _dims(cfg)
    bsz = x_t.shape[0]
    proj = linear(p["in_proj"], x_t[:, 0])  # [B, ...]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_win = conv1d_step(p["conv"], cache["conv"], xbc)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x_t.dtype)
    xs, b_proj, c_proj = _split_xbc(cfg, xbc)
    xs = xs.reshape(bsz, h, p_dim).astype(jnp.float32)
    B = b_proj.reshape(bsz, g, n).astype(jnp.float32)
    C = c_proj.reshape(bsz, g, n).astype(jnp.float32)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)
    Ch = jnp.repeat(C, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))  # [B,H]
    state = cache["state"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhd,bhp->bhdp", dt, Bh, xs
    )
    y = jnp.einsum("bhd,bhdp->bhp", Ch, state) + p["D"][None, :, None] * xs
    y = y.reshape(bsz, 1, d_inner).astype(x_t.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype)[:, None, :], cfg.norm_eps)
    return linear(p["out_proj"], y), {"state": state, "conv": conv_win}
