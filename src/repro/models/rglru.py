"""RG-LRU recurrent block (Griffin, arXiv:2402.19427) for RecurrentGemma.

The recurrent block: x -> (linear branch, gate branch); linear branch goes
conv1d -> RG-LRU; output = out_proj(rglru_out * gelu(gate)).

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_r x_t)          recurrence gate
    i_t = sigmoid(W_i x_t)          input gate
    a_t = a^(c * r_t)               with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence form uses ``jax.lax.associative_scan`` over the affine maps
(h -> a*h + b), giving O(log S) depth -- the TPU-friendly way to run a linear
recurrence at train/prefill time.  Decode is the plain recurrence with a
[B, W] state -- constant memory, so recurrentgemma runs ``long_500k``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import causal_conv1d, conv1d_step, init_conv1d, init_linear, linear

Array = jax.Array
Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed exponent scale


def _width(cfg: ArchConfig) -> int:
    return cfg.recurrent.lru_width or cfg.d_model


def init_rglru_block(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    w = _width(cfg)
    keys = jax.random.split(key, 6)
    return {
        "in_proj": init_linear(keys[0], cfg.d_model, w, dtype=dtype),
        "gate_proj": init_linear(keys[1], cfg.d_model, w, dtype=dtype),
        "conv": init_conv1d(keys[2], w, cfg.recurrent.d_conv, dtype=dtype),
        "w_r": init_linear(keys[3], w, w, dtype=dtype),
        "w_i": init_linear(keys[4], w, w, dtype=dtype),
        # Lambda init so a = sigmoid(Lambda)^c in ~(0.9, 0.999)
        "lam": jnp.log(jnp.linspace(0.9, 0.999, w) ** (1.0 / _C) /
                       (1 - jnp.linspace(0.9, 0.999, w) ** (1.0 / _C))).astype(jnp.float32),
        "out_proj": init_linear(keys[5], w, cfg.d_model, dtype=dtype),
    }


def _gates(p: Params, x: Array):
    """x: [..., W] (post conv).  Returns (a, gated_input) in f32."""
    r = jax.nn.sigmoid(linear(p["w_r"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["w_i"], x).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(p["lam"])  # log a_base, [W]
    log_a = _C * r * log_a_base  # [..., W]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, b


def rglru_block(p: Params, cfg: ArchConfig, x: Array, *, return_state: bool = False):
    """Full-sequence recurrent block.  x: [B, S, D].

    ``return_state=True`` also returns the decode cache (final h + conv
    window) for chunked prefill."""
    gate = jax.nn.gelu(linear(p["gate_proj"], x).astype(jnp.float32))
    u_raw = linear(p["in_proj"], x)
    u = causal_conv1d(p["conv"], u_raw)
    a, b = _gates(p, u)  # [B, S, W] each, f32

    # associative scan over affine maps h -> a h + b along S
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate).astype(x.dtype)
    out = linear(p["out_proj"], y)
    if not return_state:
        return out
    width = p["conv"]["w"].shape[0]
    pad = jnp.pad(u_raw, ((0, 0), (width - 1, 0), (0, 0)))
    cache = {"h": h[:, -1], "conv": pad[:, -(width - 1):, :]}
    return out, cache


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    w = _width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.recurrent.d_conv - 1, w), dtype),
    }


def rglru_step(
    p: Params, cfg: ArchConfig, x_t: Array, cache: Params
) -> Tuple[Array, Params]:
    """One decode step.  x_t: [B, 1, D]."""
    gate = jax.nn.gelu(linear(p["gate_proj"], x_t[:, 0]).astype(jnp.float32))
    u = linear(p["in_proj"], x_t[:, 0])
    u, conv_win = conv1d_step(p["conv"], cache["conv"], u)
    a, b = _gates(p, u)  # [B, W]
    h = a * cache["h"] + b
    y = (h * gate).astype(x_t.dtype)[:, None, :]
    return linear(p["out_proj"], y), {"h": h, "conv": conv_win}
