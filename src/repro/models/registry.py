"""Uniform model API over all families + ShapeDtypeStruct input specs.

``get_model(cfg)`` returns a :class:`Model` namespace with:

* ``init(key)``                     -> params
* ``loss(params, batch)``           -> (scalar, metrics)     [train_*]
* ``forward(params, batch)``        -> logits                [prefill_*]
* ``init_cache(batch, max_len)``    -> caches
* ``decode_step(params, batch, caches)`` -> (logits, caches) [decode_* / long_*]
* ``input_specs(shape)``            -> (step_name, batch-spec pytree of
                                        ShapeDtypeStruct, cache specs or None)

The same pattern as shannon/kernels: weak-type-correct ShapeDtypeStructs,
shardable, zero device allocation -- the multi-pod dry-run lowers every cell
from these.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec as encdec_mod
from . import transformer as lm_mod

Array = jax.Array
Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[Array], Params]
    loss: Callable[[Params, Dict[str, Array]], Tuple[Array, Dict]]
    forward: Callable[[Params, Dict[str, Array]], Array]
    init_cache: Callable[[int, int], Any]
    decode_step: Callable[[Params, Dict[str, Array], Any], Tuple[Array, Any]]
    input_specs: Callable[[ShapeConfig], Tuple[str, Dict[str, Any], Any]]


def _token_spec(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def get_model(cfg: ArchConfig, *, attn_impl: str = "auto") -> Model:
    dtype = jnp.dtype(cfg.dtype)
    if cfg.is_encdec:
        return _encdec_model(cfg, dtype)
    return _lm_model(cfg, dtype, attn_impl)


# --------------------------------------------------------------------------- #
# decoder-only (dense / moe / vlm / ssm / hybrid)                              #
# --------------------------------------------------------------------------- #


def _lm_model(cfg: ArchConfig, dtype, attn_impl: str) -> Model:
    is_vlm = cfg.vision_tokens > 0

    def init(key):
        return lm_mod.init_lm(key, cfg)

    def loss(params, batch):
        return lm_mod.loss_fn(params, cfg, batch, attn_impl=attn_impl)

    def forward(params, batch):
        return lm_mod.forward(
            params, cfg, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"), attn_impl=attn_impl,
        )[0]

    def init_cache(batch, max_len):
        return lm_mod.init_cache(cfg, batch, max_len, dtype)

    def decode_step(params, batch, caches):
        return lm_mod.decode_step(params, cfg, batch["tokens_t"], caches)

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            batch = {"tokens": _token_spec(b, s), "labels": _token_spec(b, s)}
            if is_vlm:
                text = s - cfg.vision_tokens
                batch = {
                    "tokens": _token_spec(b, text),
                    "labels": _token_spec(b, text),
                    "patch_embeds": jax.ShapeDtypeStruct(
                        (b, cfg.vision_tokens, cfg.d_model), dtype
                    ),
                }
            return "train_step", batch, None
        if shape.kind == "prefill":
            batch = {"tokens": _token_spec(b, s)}
            if is_vlm:
                batch["tokens"] = _token_spec(b, s - cfg.vision_tokens)
                batch["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.vision_tokens, cfg.d_model), dtype
                )
            return "prefill", batch, None
        # decode: one new token against a cache of size seq_len
        cache_specs = jax.eval_shape(lambda: init_cache(b, s))
        return "serve_step", {"tokens_t": _token_spec(b, 1)}, cache_specs

    return Model(cfg, init, loss, forward, init_cache, decode_step, input_specs)


# --------------------------------------------------------------------------- #
# encoder-decoder (whisper)                                                    #
# --------------------------------------------------------------------------- #


def _encdec_model(cfg: ArchConfig, dtype) -> Model:
    def init(key):
        return encdec_mod.init_encdec(key, cfg)

    def loss(params, batch):
        return encdec_mod.loss_fn(params, cfg, batch)

    def forward(params, batch):
        enc = encdec_mod.encode(params, cfg, batch["frames"])
        return encdec_mod.decode_train(params, cfg, batch["tokens"], enc)

    def init_cache(batch, max_len):
        return encdec_mod.init_cache(cfg, batch, max_len, dtype=dtype)

    def decode_step(params, batch, caches):
        # cross-KV rides along in ``caches`` as (self_caches, cross_kv)
        self_caches, cross_kv = caches
        logits, self_caches = encdec_mod.decode_step(
            params, cfg, batch["tokens_t"], self_caches, cross_kv
        )
        return logits, (self_caches, cross_kv)

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        frames = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dtype)
        if shape.kind == "train":
            return (
                "train_step",
                {"frames": frames, "tokens": _token_spec(b, s), "labels": _token_spec(b, s)},
                None,
            )
        if shape.kind == "prefill":
            return "prefill", {"frames": frames, "tokens": _token_spec(b, s)}, None
        self_caches = jax.eval_shape(lambda: init_cache(b, s))
        dh = cfg.resolved_head_dim
        kv = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.n_kv_heads, dh), dtype)
        cross_kv = [(kv, kv) for _ in range(cfg.n_layers)]
        return "serve_step", {"tokens_t": _token_spec(b, 1)}, (self_caches, cross_kv)

    return Model(cfg, init, loss, forward, init_cache, decode_step, input_specs)
