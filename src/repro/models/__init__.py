from . import attention, cnn, encdec, ffn, layers, rglru, sharding, ssm, transformer
from .registry import Model, get_model
