"""Attention: GQA/MQA (+qk_norm, +bias), MLA (DeepSeek low-rank KV), sliding
window, prefix-LM masks; full and chunked (flash-style) implementations; KV
caches (full / compressed / ring-buffer) with single-token decode steps.

Memory strategy: ``full`` materializes [B, H, Sq, Skv] scores (fine to 8k);
``chunked`` streams KV in blocks with running (max, sum) renormalization --
the standard online-softmax recurrence -- so prefill_32k fits per-device HBM.
The chunk loop is a *python* loop (static unroll) so dry-run HLO FLOPs remain
exact for the roofline (DESIGN.md section 8); pass ``unroll=False`` to trade
accounting for compile time on very long sequences.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import apply_rope, init_linear, init_rmsnorm, linear, rmsnorm

Array = jax.Array
Params = Dict[str, Any]

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# masks                                                                        #
# --------------------------------------------------------------------------- #


def _mask_bias(
    q_pos: Array,  # [Sq] absolute positions of queries
    kv_pos: Array,  # [Skv]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
) -> Array:
    """Additive mask bias [Sq, Skv] built from iota comparisons (never a
    materialized constant table)."""
    qi = q_pos[:, None]
    kj = kv_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok = kj <= qi
        if prefix_len > 0:  # prefix-LM: bidirectional inside the prefix
            both_prefix = (qi < prefix_len) & (kj < prefix_len)
            ok = ok | both_prefix
    if window is not None:
        ok = ok & (qi - kj < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# --------------------------------------------------------------------------- #
# core attention                                                               #
# --------------------------------------------------------------------------- #


def _sdpa_full(q: Array, k: Array, v: Array, bias: Array, scale: float) -> Array:
    """q [B,Sq,H,dh], k [B,Skv,G,dh], v [B,Skv,G,dv]; H = G*rep (dv may differ
    from dh, e.g. MLA's rope-extended queries).  bias [Sq,Skv]."""
    b, sq, h, dh = q.shape
    g = k.shape[2]
    dv = v.shape[-1]
    rep = h // g
    qg = q.reshape(b, sq, g, rep, dh)
    logits = jnp.einsum("bsgrd,btgd->bgrst", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale + bias[None, None, None]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def _sdpa_chunked(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    kv_pos: Array,
    scale: float,
    *,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
    chunk: int = 1024,
) -> Array:
    """Online-softmax over KV chunks (flash-attention recurrence, pure jnp).

    Python loop over chunks -> exact HLO FLOP accounting in the dry-run.
    """
    b, sq, h, dh = q.shape
    g = k.shape[2]
    dv = v.shape[-1]
    rep = h // g
    skv = k.shape[1]
    n_chunks = -(-skv // chunk)
    qg = q.reshape(b, sq, g, rep, dh).astype(jnp.float32)

    m = jnp.full((b, g, rep, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, g, rep, sq), jnp.float32)
    acc = jnp.zeros((b, sq, g, rep, dv), jnp.float32)
    for c in range(n_chunks):
        lo = c * chunk
        hi = min(lo + chunk, skv)
        kc = k[:, lo:hi].astype(jnp.float32)
        vc = v[:, lo:hi].astype(jnp.float32)
        bias = _mask_bias(
            q_pos, kv_pos[lo:hi], causal=causal, window=window, prefix_len=prefix_len
        )
        logits = jnp.einsum("bsgrd,btgd->bgrst", qg, kc) * scale + bias[None, None, None]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * jnp.moveaxis(alpha, 3, 1)[..., None] + jnp.einsum(
            "bgrst,btgd->bsgrd", p, vc
        )
        m = m_new
    out = acc / jnp.moveaxis(jnp.maximum(l, 1e-30), 3, 1)[..., None]
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def sdpa(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    kv_pos: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    impl: str = "auto",
    chunk: int = 1024,
    scale: Optional[float] = None,
) -> Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if impl == "auto":
        impl = "chunked" if k.shape[1] > 8192 and q.shape[1] > 1 else "full"
    if impl == "full":
        bias = _mask_bias(q_pos, kv_pos, causal=causal, window=window, prefix_len=prefix_len)
        return _sdpa_full(q, k, v, bias, scale)
    return _sdpa_chunked(
        q, k, v, q_pos, kv_pos, scale,
        causal=causal, window=window, prefix_len=prefix_len, chunk=chunk,
    )


# --------------------------------------------------------------------------- #
# GQA attention block                                                          #
# --------------------------------------------------------------------------- #


def init_gqa(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    dh = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.prune.enabled and cfg.prune.exec_mode in ("bsr_xla", "bsr"):
        # the paper's attention recipe: MXU-block pruning of q/o projections
        from .layers import init_pruned_linear

        sp = cfg.prune.sparsity
        p: Params = {
            "w_q": init_pruned_linear(k1, cfg.d_model, cfg.n_heads * dh,
                                      exec_mode=cfg.prune.exec_mode, sparsity=sp,
                                      bias=cfg.qkv_bias, dtype=dtype),
            "w_k": init_linear(k2, cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
            "w_v": init_linear(k3, cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
            "w_o": init_pruned_linear(k4, cfg.n_heads * dh, cfg.d_model,
                                      exec_mode=cfg.prune.exec_mode, sparsity=sp, dtype=dtype),
        }
    else:
        p = {
            "w_q": init_linear(k1, cfg.d_model, cfg.n_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
            "w_k": init_linear(k2, cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
            "w_v": init_linear(k3, cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias, dtype=dtype),
            "w_o": init_linear(k4, cfg.n_heads * dh, cfg.d_model, dtype=dtype),
        }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, dtype)
        p["k_norm"] = init_rmsnorm(dh, dtype)
    return p


def _linear_auto(p: Params, x: Array, mode: str = "dense", activation=None) -> Array:
    """Dispatch on packed-param presence (pruned layers carry 'values')."""
    if "values" in p:
        mode = "bsr_xla" if "block_rows" in p else "colpack_xla"
    return linear(p, x, mode=mode, activation=activation)


def gqa_project_qkv(
    p: Params, cfg: ArchConfig, x: Array, positions: Array, *, mode: str = "dense"
) -> Tuple[Array, Array, Array]:
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = _linear_auto(p["w_q"], x, mode).reshape(b, s, cfg.n_heads, dh)
    k = _linear_auto(p["w_k"], x, mode).reshape(b, s, cfg.n_kv_heads, dh)
    v = _linear_auto(p["w_v"], x, mode).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(
    p: Params,
    cfg: ArchConfig,
    x: Array,
    positions: Array,
    *,
    window: Optional[int] = None,
    prefix_len: int = 0,
    causal: bool = True,
    impl: str = "auto",
    mode: str = "dense",
    chunk: int = 1024,
) -> Array:
    """Self-attention over a full sequence (train / prefill).

    ``positions`` is [B, S] for RoPE; the mask uses row 0 (all batch rows
    share the same position grid in train/prefill).
    """
    q, k, v = gqa_project_qkv(p, cfg, x, positions, mode=mode)
    pos1d = positions[0]
    out = sdpa(
        q, k, v, pos1d, pos1d,
        causal=causal, window=window, prefix_len=prefix_len, impl=impl, chunk=chunk,
    )
    b, s = x.shape[:2]
    return _linear_auto(p["w_o"], out.reshape(b, s, -1), mode)


# ----------------------------- KV cache ------------------------------------ #


def init_kv_cache(
    cfg: ArchConfig, batch: int, max_len: int, *, window: Optional[int] = None,
    dtype=jnp.bfloat16,
) -> Params:
    dh = cfg.resolved_head_dim
    size = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, dh), dtype),
        # absolute position of the next token, PER ROW (continuous batching:
        # each slot of the serving batch advances independently)
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def gqa_decode_step(
    p: Params,
    cfg: ArchConfig,
    x_t: Array,  # [B, 1, D]
    cache: Params,
    *,
    window: Optional[int] = None,
    mode: str = "dense",
) -> Tuple[Array, Params]:
    """One decode step.  Ring-buffer writes when ``window`` is set."""
    b = x_t.shape[0]
    dh = cfg.resolved_head_dim
    pos = cache["pos"]  # [B]
    positions = pos[:, None]
    q, k_new, v_new = gqa_project_qkv(p, cfg, x_t, positions, mode=mode)
    size = cache["k"].shape[1]
    slot = pos % size if window is not None else jnp.minimum(pos, size - 1)
    rows = jnp.arange(b)
    k = cache["k"].at[rows, slot].set(k_new[:, 0])
    v = cache["v"].at[rows, slot].set(v_new[:, 0])
    # absolute positions of cache slots, per row
    idx = jnp.arange(size, dtype=jnp.int32)
    if window is None:
        kv_pos = jnp.broadcast_to(idx, (b, size))
        valid = kv_pos <= pos[:, None]
    else:
        wraps = (pos // size)[:, None]
        kv_pos = jnp.where(
            idx[None, :] <= slot[:, None],
            wraps * size + idx[None, :],
            (wraps - 1) * size + idx[None, :],
        )
        valid = (kv_pos >= 0) & (kv_pos <= pos[:, None]) & (
            pos[:, None] - kv_pos < (window or size)
        )
    g = cfg.n_kv_heads
    rep = cfg.n_heads // g
    qg = q.reshape(b, 1, g, rep, dh).astype(jnp.float32)
    logits = jnp.einsum("bsgrd,btgd->bgrst", qg, k.astype(jnp.float32)) / math.sqrt(dh)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.n_heads * dh).astype(x_t.dtype)
    y = _linear_auto(p["w_o"], out, mode)
    return y, {"k": k, "v": v, "pos": pos + 1}


def gqa_prefill(
    p: Params,
    cfg: ArchConfig,
    x: Array,
    positions: Array,
    max_len: int,
    *,
    window: Optional[int] = None,
    prefix_len: int = 0,
    impl: str = "auto",
    mode: str = "dense",
) -> Tuple[Array, Params]:
    """Full-sequence attention + populated KV cache (serving prefill)."""
    b, s, _ = x.shape
    q, k, v = gqa_project_qkv(p, cfg, x, positions, mode=mode)
    pos1d = positions[0]
    out = sdpa(
        q, k, v, pos1d, pos1d,
        causal=True, window=window, prefix_len=prefix_len, impl=impl,
    )
    y = _linear_auto(p["w_o"], out.reshape(b, s, -1), mode)
    size = min(window, max_len) if window else max_len
    if window is None or s <= size:
        pad = size - s if s <= size else 0
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, :size]
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, :size]
    else:
        # ring layout: slot i holds the largest position p < s with p%size==i
        idx = jnp.arange(size)
        slot_pos = idx + size * ((s - 1 - idx) // size)
        kc = jnp.take(k, slot_pos, axis=1)
        vc = jnp.take(v, slot_pos, axis=1)
    cache = {"k": kc, "v": vc, "pos": jnp.full((b,), s, jnp.int32)}
    return y, cache


# --------------------------------------------------------------------------- #
# MLA (DeepSeek-V2 multi-head latent attention)                                #
# --------------------------------------------------------------------------- #


def init_mla(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    dh = cfg.resolved_head_dim
    r = cfg.kv_lora_rank
    dr = cfg.rope_head_dim
    keys = jax.random.split(key, 8)
    p: Params = {}
    if cfg.q_lora_rank:
        p["w_dq"] = init_linear(keys[0], cfg.d_model, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = init_rmsnorm(cfg.q_lora_rank, dtype)
        p["w_uq"] = init_linear(keys[1], cfg.q_lora_rank, cfg.n_heads * (dh + dr), dtype=dtype)
    else:
        p["w_q"] = init_linear(keys[0], cfg.d_model, cfg.n_heads * (dh + dr), dtype=dtype)
    p["w_dkv"] = init_linear(keys[2], cfg.d_model, r, dtype=dtype)
    p["kv_norm"] = init_rmsnorm(r, dtype)
    p["w_kr"] = init_linear(keys[3], cfg.d_model, dr, dtype=dtype)  # shared rope key
    p["w_uk"] = init_linear(keys[4], r, cfg.n_heads * dh, dtype=dtype)
    p["w_uv"] = init_linear(keys[5], r, cfg.n_heads * dh, dtype=dtype)
    p["w_o"] = init_linear(keys[6], cfg.n_heads * dh, cfg.d_model, dtype=dtype)
    return p


def _mla_q(p: Params, cfg: ArchConfig, x: Array, positions: Array) -> Tuple[Array, Array]:
    b, s, _ = x.shape
    dh, dr = cfg.resolved_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], linear(p["w_dq"], x), cfg.norm_eps)
        q = linear(p["w_uq"], cq)
    else:
        q = linear(p["w_q"], x)
    q = q.reshape(b, s, cfg.n_heads, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(
    p: Params, cfg: ArchConfig, x: Array, positions: Array, *, impl: str = "auto"
) -> Array:
    """Full-sequence MLA (train / prefill): decompress K/V per head."""
    b, s, _ = x.shape
    dh, dr = cfg.resolved_head_dim, cfg.rope_head_dim
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv = rmsnorm(p["kv_norm"], linear(p["w_dkv"], x), cfg.norm_eps)  # [B,S,r]
    k_rope = apply_rope(
        linear(p["w_kr"], x).reshape(b, s, 1, dr), positions, cfg.rope_theta
    )  # shared across heads
    k_nope = linear(p["w_uk"], c_kv).reshape(b, s, h, dh)
    v = linear(p["w_uv"], c_kv).reshape(b, s, h, dh)
    # assemble per-head keys/queries with concatenated rope parts
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    scale = 1.0 / math.sqrt(dh + dr)
    pos1d = positions[0]
    out = sdpa(q, k, v, pos1d, pos1d, causal=True, impl=impl, scale=scale)
    return linear(p["w_o"], out.reshape(b, s, h * dh))


def mla_prefill(
    p: Params, cfg: ArchConfig, x: Array, positions: Array, max_len: int,
    *, impl: str = "auto",
) -> Tuple[Array, Params]:
    """Full-sequence MLA + populated compressed cache."""
    b, s, _ = x.shape
    dr = cfg.rope_head_dim
    y = mla_attention(p, cfg, x, positions, impl=impl)
    c_kv = rmsnorm(p["kv_norm"], linear(p["w_dkv"], x), cfg.norm_eps)
    k_rope = apply_rope(
        linear(p["w_kr"], x).reshape(b, s, 1, dr), positions, cfg.rope_theta
    ).reshape(b, s, dr)
    pad = max_len - s
    cache = {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
        "pos": jnp.full((b,), s, jnp.int32),
    }
    return y, cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def mla_decode_step(
    p: Params, cfg: ArchConfig, x_t: Array, cache: Params
) -> Tuple[Array, Params]:
    """Absorbed decode: queries move into latent space; cache stays r-dim.

    score_h(t) = q_nope_h^T W_uk_h c_t + q_rope_h^T k_rope_t
    out_h      = (sum_t p_t c_t) W_uv_h           (absorb on the way out)
    """
    b = x_t.shape[0]
    dh, dr, r, h = cfg.resolved_head_dim, cfg.rope_head_dim, cfg.kv_lora_rank, cfg.n_heads
    pos = cache["pos"]  # [B]
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(p, cfg, x_t, positions)  # [B,1,H,dh],[B,1,H,dr]
    c_new = rmsnorm(p["kv_norm"], linear(p["w_dkv"], x_t), cfg.norm_eps)  # [B,1,r]
    kr_new = apply_rope(
        linear(p["w_kr"], x_t).reshape(b, 1, 1, dr), positions, cfg.rope_theta
    ).reshape(b, 1, dr)
    rows = jnp.arange(b)
    c_kv = cache["c_kv"].at[rows, pos].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[rows, pos].set(kr_new[:, 0])
    w_uk = p["w_uk"]["w"].reshape(r, h, dh)
    # absorb: q_r [B,H,r]
    q_r = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), w_uk.astype(jnp.float32))
    s_nope = jnp.einsum("bhr,btr->bht", q_r, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(jnp.float32), k_rope.astype(jnp.float32))
    size = c_kv.shape[1]
    valid = jnp.arange(size)[None, :] <= pos[:, None]  # [B, T]
    logits = (s_nope + s_rope) / math.sqrt(dh + dr)
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", probs, c_kv.astype(jnp.float32))  # [B,H,r]
    w_uv = p["w_uv"]["w"].reshape(r, h, dh)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * dh).astype(x_t.dtype)
    y = linear(p["w_o"], out)
    return y, {"c_kv": c_kv, "k_rope": k_rope, "pos": pos + 1}


# --------------------------------------------------------------------------- #
# cross attention (whisper decoder)                                            #
# --------------------------------------------------------------------------- #


def init_cross_attention(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    dh = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_q": init_linear(k1, cfg.d_model, cfg.n_heads * dh, dtype=dtype),
        "w_k": init_linear(k2, cfg.d_model, cfg.n_kv_heads * dh, dtype=dtype),
        "w_v": init_linear(k3, cfg.d_model, cfg.n_kv_heads * dh, dtype=dtype),
        "w_o": init_linear(k4, cfg.n_heads * dh, cfg.d_model, dtype=dtype),
    }


def cross_attention_kv(p: Params, cfg: ArchConfig, enc_out: Array) -> Tuple[Array, Array]:
    b, s, _ = enc_out.shape
    dh = cfg.resolved_head_dim
    k = linear(p["w_k"], enc_out).reshape(b, s, cfg.n_kv_heads, dh)
    v = linear(p["w_v"], enc_out).reshape(b, s, cfg.n_kv_heads, dh)
    return k, v


def cross_attention(
    p: Params, cfg: ArchConfig, x: Array, k: Array, v: Array
) -> Array:
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = linear(p["w_q"], x).reshape(b, s, cfg.n_heads, dh)
    q_pos = jnp.arange(s, dtype=jnp.int32)
    kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    out = sdpa(q, k, v, q_pos, kv_pos, causal=False, impl="full")
    return linear(p["w_o"], out.reshape(b, s, -1))
