"""The paper's three demo applications as LR-DSL graphs (models/cnn.py).

Compact-but-faithful versions of:

* **style transfer** -- generative network in the style of Zhang & Dana 2017
  (MSG-Net): conv-in -> downsample convs -> residual blocks (instance norm)
  -> upsample convs -> conv-out.  Pruned with **column pruning** (paper).
* **coloring** -- Iizuka et al. 2016: low-level conv stack -> {mid-level,
  global} branches -> fusion (global feature broadcast + 1x1 conv) ->
  decoder with upsampling.  Pruned with **kernel-pattern pruning** (paper).
* **super resolution** -- WDSR-style (Yu et al. 2018): wide-activation
  residual blocks + pixel-shuffle upsample.  **Kernel-pattern pruning**.

Channel widths are scaled-down (mobile-sized) versions; batch-norm layers are
inserted where the originals have them so the fold_norm pass has real work.
These graphs are the substrate of benchmarks/table1_apps.py (Table 1).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.graph.ir import Graph, GraphBuilder

Array = jax.Array


def _conv_params(key, c_out, c_in, k, dtype=jnp.float32, bias=True):
    scale = 1.0 / math.sqrt(c_in * k * k)
    p = {"w": jax.random.normal(key, (c_out, c_in, k, k), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def _bn_params(c, dtype=jnp.float32):
    return {
        "scale": jnp.ones((c,), dtype),
        "bias": jnp.zeros((c,), dtype),
        "mean": jnp.zeros((c,), dtype),
        "var": jnp.ones((c,), dtype),
    }


def _in_params(c, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


# --------------------------------------------------------------------------- #
# style transfer                                                               #
# --------------------------------------------------------------------------- #


def build_style_transfer(key: Array, base: int = 32, n_res: int = 5) -> Graph:
    """conv7-IN-relu, 2x downsample conv3 s2, n_res residual blocks (1x1
    entry conv + 3x3 body, MSG-Net bottleneck style -- the 1x1 lowers through
    the direct-GEMM conv fast path), 2x upsample, conv7-out (mobile-sized
    7x7 stem: the stem convs stay f32 under the quantize pass, so their
    weight mass bounds the plan's INT8 compression ratio).
    Input [N, 3, H, W]."""
    keys = iter(jax.random.split(key, 64))
    b = GraphBuilder(["x"])
    h = b.add("conv2d", "x", name="conv_in",
              params=_conv_params(next(keys), base, 3, 7), stride=1)
    h = b.add("norm", h, name="in_in", params=_in_params(base), kind="instance")
    h = b.add("activation", h, name="act_in", fn="relu")
    c = base
    for i in range(2):  # downsample
        h = b.add("conv2d", h, name=f"down{i}",
                  params=_conv_params(next(keys), c * 2, c, 3), stride=2)
        h = b.add("norm", h, name=f"down{i}_in", params=_in_params(c * 2), kind="instance")
        h = b.add("activation", h, name=f"down{i}_act", fn="relu")
        c *= 2
    for i in range(n_res):  # residual blocks
        r = b.add("conv2d", h, name=f"res{i}_c1",
                  params=_conv_params(next(keys), c, c, 1))
        r = b.add("norm", r, name=f"res{i}_n1", params=_in_params(c), kind="instance")
        r = b.add("activation", r, name=f"res{i}_a1", fn="relu")
        r = b.add("conv2d", r, name=f"res{i}_c2",
                  params=_conv_params(next(keys), c, c, 3))
        r = b.add("norm", r, name=f"res{i}_n2", params=_in_params(c), kind="instance")
        h = b.add("add", (h, r), name=f"res{i}_add")
    for i in range(2):  # upsample
        h = b.add("upsample", h, name=f"up{i}_u", factor=2)
        h = b.add("conv2d", h, name=f"up{i}",
                  params=_conv_params(next(keys), c // 2, c, 3))
        h = b.add("norm", h, name=f"up{i}_in", params=_in_params(c // 2), kind="instance")
        h = b.add("activation", h, name=f"up{i}_act", fn="relu")
        c //= 2
    out = b.add("conv2d", h, name="conv_out", params=_conv_params(next(keys), 3, c, 7))
    return b.build(out)


# --------------------------------------------------------------------------- #
# coloring                                                                     #
# --------------------------------------------------------------------------- #


def build_coloring(key: Array, base: int = 32) -> Graph:
    """Iizuka-style: low-level stack -> (mid branch, global branch) -> fusion
    -> decoder.  Input [N, 1, H, W] grayscale; output [N, 2, H, W] chroma."""
    keys = iter(jax.random.split(key, 64))
    b = GraphBuilder(["x"])

    def conv_bn_relu(h, c_out, c_in, name, stride=1, k=3):
        h = b.add("conv2d", h, name=name,
                  params=_conv_params(next(keys), c_out, c_in, k), stride=stride)
        h = b.add("norm", h, name=name + "_bn", params=_bn_params(c_out), kind="batch")
        return b.add("activation", h, name=name + "_act", fn="relu")

    # low-level features (strided)
    h = conv_bn_relu("x", base, 1, "low1", stride=2)
    h = conv_bn_relu(h, base * 2, base, "low2")
    h = conv_bn_relu(h, base * 2, base * 2, "low3", stride=2)
    h = conv_bn_relu(h, base * 4, base * 2, "low4")
    # mid-level branch
    mid = conv_bn_relu(h, base * 4, base * 4, "mid1")
    mid = conv_bn_relu(mid, base * 2, base * 4, "mid2")
    # global branch: strided convs -> global pool -> fc
    g = conv_bn_relu(h, base * 4, base * 4, "glob1", stride=2)
    g = conv_bn_relu(g, base * 4, base * 4, "glob2", stride=2)
    g = b.add("global_avg_pool", g, name="glob_pool")
    g = b.add("linear", g, name="glob_fc1",
              params={"w": jax.random.normal(next(keys), (base * 4, base * 2), jnp.float32) * 0.05,
                      "b": jnp.zeros((base * 2,), jnp.float32)})
    g = b.add("activation", g, name="glob_fc1_act", fn="relu")
    # fusion: broadcast global feature over mid map, concat, 1x1 conv
    gb = b.add("broadcast_spatial", (g, mid), name="glob_bcast")
    fused = b.add("concat", (mid, gb), name="fusion_cat", axis=1)
    h = conv_bn_relu(fused, base * 2, base * 4, "fuse1", k=1)
    # decoder
    h = conv_bn_relu(h, base, base * 2, "dec1")
    h = b.add("upsample", h, name="dec_up1", factor=2)
    h = conv_bn_relu(h, base, base, "dec2")
    h = b.add("upsample", h, name="dec_up2", factor=2)
    h = conv_bn_relu(h, base // 2, base, "dec3")
    out = b.add("conv2d", h, name="dec_out", params=_conv_params(next(keys), 2, base // 2, 3))
    out = b.add("activation", out, name="dec_tanh", fn="tanh")
    return b.build(out)


# --------------------------------------------------------------------------- #
# super resolution (WDSR-style)                                                #
# --------------------------------------------------------------------------- #


def build_super_resolution(
    key: Array, base: int = 32, n_res: int = 8, expand: int = 6, scale: int = 2
) -> Graph:
    """Wide-activation residual body + pixel shuffle.  Input [N, 3, H, W].
    Blocks are WDSR-B style: 1x1 expand (direct-GEMM conv fast path) ->
    relu -> 3x3 project, with the wider x6 expansion the 1x1 makes cheap."""
    keys = iter(jax.random.split(key, 64))
    b = GraphBuilder(["x"])
    h = b.add("conv2d", "x", name="head", params=_conv_params(next(keys), base, 3, 3))
    body_in = h
    for i in range(n_res):
        r = b.add("conv2d", h, name=f"res{i}_expand",
                  params=_conv_params(next(keys), base * expand, base, 1))
        r = b.add("activation", r, name=f"res{i}_act", fn="relu")
        r = b.add("conv2d", r, name=f"res{i}_project",
                  params=_conv_params(next(keys), base, base * expand, 3))
        h = b.add("add", (h, r), name=f"res{i}_add")
    h = b.add("add", (h, body_in), name="global_skip")
    h = b.add("conv2d", h, name="tail",
              params=_conv_params(next(keys), 3 * scale * scale, base, 3))
    out = b.add("pixel_shuffle", h, name="shuffle", factor=scale)
    return b.build(out)


APPS = {
    "style_transfer": build_style_transfer,
    "coloring": build_coloring,
    "super_resolution": build_super_resolution,
}

#: the paper's pruning recipe per app (section 2: "column pruning for style
#: transfer and kernel pruning for coloring and super resolution")
PAPER_RECIPE = {
    "style_transfer": "column",
    "coloring": "pattern",
    "super_resolution": "pattern",
}

#: first/last layers kept at f32 by the ``quantize`` pass -- the standard
#: mobile INT8 practice (PatDNN et al.): the stem conv sees raw image
#: statistics and the output conv's weight noise lands directly on the
#: output pixels, while noise in the body is washed by the following norms.
#: Names that do not occur in a graph are ignored.  (fuse_epilogue renames a
#: fused GEMM/conv to its follower, so both the builder name and the
#: post-fusion name are listed where they differ.)
APP_QUANT_SKIP = {
    "style_transfer": ("conv_in", "act_in", "conv_out"),
    "coloring": ("low1", "low1_act", "dec_out", "dec_tanh"),
    "super_resolution": ("head", "tail"),
}

#: nodes whose *activations* stay f32 under the ``quantize`` pass (weights
#: still pack to int8; scheme pinned to W8 -- the conv kernel dequantizes
#: filter tiles in VMEM).  Static per-tensor activation quantization noise
#: accumulates along residual trunks: measured at the canonical 5e-2 parity
#: probe, all-W8A8 lands style transfer at 0.127 and super resolution at
#: 0.153 (weight-only: 0.046 / 0.017), so both residual apps keep f32
#: activations end to end, while coloring's BN-normalized feedforward stack
#: holds 4e-4 with *every* conv at W8A8 -- the standard mixed-precision
#: W8A8 deployment recipe.  Names that do not occur in a graph are ignored.
APP_ACT_SKIP = {
    "style_transfer": tuple(
        [f"down{i}{s}" for i in range(2) for s in ("", "_act")]
        + [f"res{i}{s}" for i in range(8) for s in ("_c1", "_c2", "_a1", "_add")]
        + [f"up{i}{s}" for i in range(2) for s in ("", "_act")]
    ),
    "coloring": (),
    "super_resolution": tuple(
        [f"res{i}{s}" for i in range(8) for s in ("_expand", "_project", "_act", "_add")]
        + ["global_skip"]
    ),
}

#: Table 1 of the paper (ms on Samsung Galaxy S10, Adreno 640)
PAPER_TABLE1 = {
    "style_transfer": {"unpruned": 283.0, "pruned": 178.0, "pruned_compiler": 67.0},
    "coloring": {"unpruned": 137.0, "pruned": 85.0, "pruned_compiler": 38.0},
    "super_resolution": {"unpruned": 269.0, "pruned": 192.0, "pruned_compiler": 73.0},
}


# --------------------------------------------------------------------------- #
# the paper's pruning recipes on conv graphs (shared by benchmarks + serving)  #
# --------------------------------------------------------------------------- #


def _channel_mask(w, keep_frac: float):
    """Kill the lowest-energy input channels entirely.  [Co, Ci, kh, kw]."""
    energy = jnp.sum(w.astype(jnp.float32) ** 2, axis=(0, 2, 3))  # [Ci]
    ci = w.shape[1]
    n_keep = max(1, int(round(ci * keep_frac)))
    thresh = jnp.sort(energy)[ci - n_keep]
    return (energy >= thresh).astype(w.dtype)[None, :, None, None] * jnp.ones_like(w)


def _pattern_mask(w, connectivity_channels: float):
    """Per-kernel best pattern + channel-granular connectivity pruning."""
    from ..core.pruning import PatternKernel, project

    st = PatternKernel()
    _, mask = project(w, st)
    if connectivity_channels > 0:
        mask = mask * _channel_mask(w, 1.0 - connectivity_channels)
    return mask


def app_masks(g: Graph, app: str, sparsity: float = 0.5):
    """Masks + structure metadata per the paper's recipe for ``app``."""
    from ..core.pruning import Column, PatternKernel, project

    recipe = PAPER_RECIPE[app]
    masks, structures = {}, {}
    for node in g.nodes:
        p = g.params.get(node.name, {})
        w = p.get("w")
        if w is None:
            continue
        if node.op == "conv2d":
            if w.shape[1] <= 4:  # never prune the image-input conv
                continue
            if recipe == "column":
                # column pruning at channel granularity (TPU-exploitable)
                masks[node.name] = _channel_mask(w, 1.0 - sparsity)
                structures[node.name] = Column(sparsity)
            else:
                if w.shape[2] != 3:
                    continue  # patterns are defined for 3x3 kernels
                masks[node.name] = _pattern_mask(w, sparsity)
                structures[node.name] = PatternKernel(connectivity=sparsity)
        elif node.op == "linear" and w.shape[0] >= 64:
            wp, m = project(w, Column(sparsity))
            masks[node.name] = m
            structures[node.name] = Column(sparsity)
    return masks, structures
