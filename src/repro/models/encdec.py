"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings ``[B, T_enc, D]`` (the output of Whisper's two
strided convs + sinusoidal positions).  The transformer backbone is real:

* encoder: bidirectional self-attention + GELU MLP, pre-LN;
* decoder: causal self-attention + cross-attention + GELU MLP, pre-LN.

Decode caches the decoder self-KV and the *precomputed* cross-KV per layer
(cross K/V depend only on encoder output -- computed once at prefill).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from .ffn import init_mlp, mlp
from .layers import embed, init_embedding, init_layernorm, init_linear, layernorm, linear

Array = jax.Array
Params = Dict[str, Any]


def init_encoder_layer(key: Array, cfg: ArchConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_layernorm(cfg.d_model, dtype),
        "attn": attn_mod.init_gqa(k1, cfg, dtype),
        "norm2": init_layernorm(cfg.d_model, dtype),
        "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_decoder_layer(key: Array, cfg: ArchConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_layernorm(cfg.d_model, dtype),
        "attn": attn_mod.init_gqa(k1, cfg, dtype),
        "norm_x": init_layernorm(cfg.d_model, dtype),
        "cross": attn_mod.init_cross_attention(k2, cfg, dtype),
        "norm2": init_layernorm(cfg.d_model, dtype),
        "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key: Array, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    n_enc = cfg.encoder_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 4)
    return {
        "embed": init_embedding(keys[0], cfg.vocab_padded, cfg.d_model, dtype),
        "enc_pos": (jax.random.normal(keys[1], (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "encoder": [init_encoder_layer(keys[2 + i], cfg, dtype) for i in range(n_enc)],
        "enc_norm": init_layernorm(cfg.d_model, dtype),
        "decoder": [
            init_decoder_layer(keys[2 + n_enc + i], cfg, dtype)
            for i in range(cfg.n_layers)
        ],
        "dec_norm": init_layernorm(cfg.d_model, dtype),
        "lm_head": init_linear(keys[-1], cfg.d_model, cfg.vocab_padded, dtype=dtype),
    }


def _run_stack(layers, apply_one, x, *, remat: bool, layout_scan: bool):
    """Apply homogeneous layers unrolled or as a scan over stacked params."""
    fn = jax.checkpoint(apply_one) if remat else apply_one
    if not layout_scan or len(layers) < 2:
        for p in layers:
            x = fn(p, x)
        return x
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    def body(h, lp):
        return fn(lp, h), None

    x, _ = jax.lax.scan(body, x, stacked)
    return x


def encode(
    params: Params, cfg: ArchConfig, frames: Array, *, attn_impl="auto",
    remat: bool = False, layout_scan: bool = False,
) -> Array:
    """frames: [B, T_enc, D] stub-frontend output."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def one(p, x):
        h = layernorm(p["norm1"], x, cfg.norm_eps)
        x = x + attn_mod.gqa_attention(
            p["attn"], cfg, h, positions, causal=False, impl=attn_impl
        )
        h = layernorm(p["norm2"], x, cfg.norm_eps)
        return x + mlp(p["ffn"], h, activation="gelu")

    x = _run_stack(params["encoder"], one, x, remat=remat, layout_scan=layout_scan)
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(
    params: Params, cfg: ArchConfig, tokens: Array, enc_out: Array, *, attn_impl="auto",
    remat: bool = False, layout_scan: bool = False,
) -> Array:
    """Teacher-forced decoder pass.  Returns logits [B, S, V]."""
    x = embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def one(p, x):
        h = layernorm(p["norm1"], x, cfg.norm_eps)
        x = x + attn_mod.gqa_attention(p["attn"], cfg, h, positions, impl=attn_impl)
        h = layernorm(p["norm_x"], x, cfg.norm_eps)
        ck, cv = attn_mod.cross_attention_kv(p["cross"], cfg, enc_out)
        x = x + attn_mod.cross_attention(p["cross"], cfg, h, ck, cv)
        h = layernorm(p["norm2"], x, cfg.norm_eps)
        return x + mlp(p["ffn"], h, activation="gelu")

    x = _run_stack(params["decoder"], one, x, remat=remat, layout_scan=layout_scan)
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    return _mask_pad_logits(cfg, linear(params["lm_head"], x))


def _mask_pad_logits(cfg: ArchConfig, logits: Array) -> Array:
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def loss_fn(
    params: Params, cfg: ArchConfig, batch: Dict[str, Array],
    *, remat: bool = False, layout_scan: bool = False,
) -> Tuple[Array, Dict]:
    enc_out = encode(params, cfg, batch["frames"], remat=remat, layout_scan=layout_scan)
    logits = decode_train(
        params, cfg, batch["tokens"], enc_out, remat=remat, layout_scan=layout_scan
    )
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    ce = nll.mean()
    return ce, {"ce": ce}


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, enc_out: Optional[Array] = None,
    dtype=jnp.bfloat16,
) -> List[Params]:
    """Per-decoder-layer cache: self-KV ring + precomputed cross-KV."""
    del enc_out  # cross-KV is precomputed separately (precompute_cross_kv)
    return [
        attn_mod.init_kv_cache(cfg, batch, max_len, dtype=dtype)
        for _ in range(cfg.n_layers)
    ]


def precompute_cross_kv(params: Params, cfg: ArchConfig, enc_out: Array):
    return [
        attn_mod.cross_attention_kv(p["cross"], cfg, enc_out)
        for p in params["decoder"]
    ]


def decode_step(
    params: Params,
    cfg: ArchConfig,
    tokens_t: Array,  # [B, 1]
    caches: List[Params],
    cross_kv: List[Tuple[Array, Array]],
) -> Tuple[Array, List[Params]]:
    x = embed(params["embed"], tokens_t)
    new_caches = []
    for p, cache, (ck, cv) in zip(params["decoder"], caches, cross_kv):
        h = layernorm(p["norm1"], x, cfg.norm_eps)
        mixed, cache = attn_mod.gqa_decode_step(p["attn"], cfg, h, cache)
        x = x + mixed
        h = layernorm(p["norm_x"], x, cfg.norm_eps)
        x = x + attn_mod.cross_attention(p["cross"], cfg, h, ck, cv)
        h = layernorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["ffn"], h, activation="gelu")
        new_caches.append(cache)
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    return _mask_pad_logits(cfg, linear(params["lm_head"], x)), new_caches
