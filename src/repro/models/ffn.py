"""FFN blocks: gated MLP (SwiGLU/GeGLU) and MoE (DeepSeek-style shared +
routed experts, top-k, gather-based dispatch).

The gated MLP is where the paper's technique bites hardest in transformers
(the d_ff GEMMs dominate FLOPs): ``mode`` routes through the PrunedLinear
execution engines, and ``fused=True`` uses the Pallas fused gate*up kernel.

MoE dispatch is gather-based (sort tokens by expert, capacity-clamped): no
[T, E, C] one-hot einsum, so dry-run HLO FLOPs reflect real expert compute.
Expert weight stacks are [E, D, F] -- sharded over the ``model`` axis (EP).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from ..kernels import ops as kops
from .layers import init_linear, linear

Array = jax.Array
Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# dense gated FFN                                                              #
# --------------------------------------------------------------------------- #


def init_mlp(
    key: Array, d_model: int, d_ff: int, dtype=jnp.bfloat16,
    prune: Optional[Tuple[str, float]] = None,
) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if prune is not None:
        # the paper's FFN recipe: column pruning -> packed smaller GEMMs
        mode, sp = prune
        from .layers import init_pruned_linear

        return {
            "w_gate": init_pruned_linear(k1, d_model, d_ff, exec_mode=mode, sparsity=sp, dtype=dtype),
            "w_up": init_pruned_linear(k2, d_model, d_ff, exec_mode=mode, sparsity=sp, dtype=dtype),
            "w_down": init_pruned_linear(k3, d_ff, d_model, exec_mode=mode, sparsity=sp, dtype=dtype),
        }
    return {
        "w_gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "w_up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "w_down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(
    p: Params,
    x: Array,
    *,
    activation: str = "silu",
    mode: str = "dense",
    fused: bool = False,
) -> Array:
    if fused and mode in ("dense", "masked") and "w" in p["w_gate"]:
        wg, wu = p["w_gate"]["w"], p["w_up"]["w"]
        if mode == "masked":
            wg = wg * p["w_gate"]["mask"].astype(wg.dtype)
            wu = wu * p["w_up"]["mask"].astype(wu.dtype)
        h = kops.ffn_gateup(x, wg, wu, activation=activation)
    else:
        g = _linear_auto(p["w_gate"], x, mode, activation=activation)
        u = _linear_auto(p["w_up"], x, mode)
        h = g * u
    return _linear_auto(p["w_down"], h, mode)


def _linear_auto(p: Params, x: Array, mode: str = "dense", activation=None) -> Array:
    if "values" in p:
        mode = "bsr_xla" if "block_rows" in p else "colpack_xla"
    return linear(p, x, mode=mode, activation=activation)


# --------------------------------------------------------------------------- #
# MoE                                                                          #
# --------------------------------------------------------------------------- #


def init_moe(key: Array, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    mc = cfg.moe
    assert mc is not None
    d, f = cfg.d_model, mc.d_expert
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)

    def stack(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p: Params = {
        "router": init_linear(k_r, d, mc.n_routed, dtype=jnp.float32),
        "experts": {
            "w_gate": stack(k_g, (mc.n_routed, d, f)),
            "w_up": stack(k_u, (mc.n_routed, d, f)),
            "w_down": stack(k_d, (mc.n_routed, f, d)),
        },
    }
    if mc.n_shared:
        p["shared"] = init_mlp(k_s, d, f * mc.n_shared, dtype)
    return p


def _dispatch_indices(
    expert_idx: Array, n_experts: int, capacity: int
) -> Tuple[Array, Array, Array]:
    """Per-group gather-based dispatch bookkeeping (GShard-style groups).

    Args: expert_idx [G, Tk] expert choice per (group, token-slot).  The group
    axis is the data-sharded batch axis, so the cumsum below never crosses
    devices -- the dispatch stays local and only the expert gather/scatter
    (the intended all-to-all) communicates.

    Returns:
      gather_idx [G, E, C]  token-slot index filling each expert's slots,
      slot_valid [G, E, C]  bool,
      kept       [G, Tk]    this (token, slot) made it under capacity.
    """
    g_, tk = expert_idx.shape
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # [G, Tk, E]
    pos = (jnp.cumsum(onehot, axis=1) - 1) * onehot  # slot within expert
    pos = pos.sum(axis=-1)  # [G, Tk]
    kept = pos < capacity
    flat_slot = expert_idx * capacity + jnp.minimum(pos, capacity - 1)  # [G, Tk]
    arange_tk = jnp.broadcast_to(jnp.arange(tk, dtype=jnp.int32), (g_, tk))
    gather_idx = jnp.zeros((g_, n_experts * capacity), jnp.int32)
    gather_idx = jax.vmap(lambda gi, fs, at, kp: gi.at[fs].set(jnp.where(kp, at, 0)))(
        gather_idx, flat_slot, arange_tk, kept
    )
    slot_valid = jax.vmap(lambda sv, fs, kp: sv.at[fs].set(kp))(
        jnp.zeros((g_, n_experts * capacity), bool), flat_slot, kept
    )
    return (
        gather_idx.reshape(g_, n_experts, capacity),
        slot_valid.reshape(g_, n_experts, capacity),
        kept,
    )


def moe(
    p: Params,
    cfg: ArchConfig,
    x: Array,
    *,
    activation: str = "silu",
) -> Tuple[Array, Array]:
    """Returns (output, router_aux_loss).  x: [B, S, D].

    Dispatch groups = batch rows (B is the data-sharded axis): routing
    bookkeeping is device-local; the token gather to the expert-sharded
    [B, E, C, D] tensor is where GSPMD inserts the all-to-all.
    Capacity is per group: ``C = S * top_k / E * capacity_factor``.
    """
    mc: MoEConfig = cfg.moe
    b, s, d = x.shape
    logits = linear(p["router"], x.astype(jnp.float32))  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, mc.top_k)  # [B, S, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    capacity = max(int(s * mc.top_k / mc.n_routed * mc.capacity_factor), 4)
    expert_idx = top_i.reshape(b, s * mc.top_k)  # [B, Tk]
    gather_idx, slot_valid, kept = _dispatch_indices(expert_idx, mc.n_routed, capacity)

    token_of_slot = gather_idx // mc.top_k  # [B, E, C] token position in row
    xe = jnp.take_along_axis(
        x[:, :, None, :],  # [B, S, 1, D]
        token_of_slot.reshape(b, -1, 1, 1).astype(jnp.int32),
        axis=1,
    ).reshape(b, mc.n_routed, capacity, d)
    xe = xe * slot_valid[..., None].astype(xe.dtype)

    we = p["experts"]
    gt = jnp.einsum("becd,edf->becf", xe, we["w_gate"])
    ut = jnp.einsum("becd,edf->becf", xe, we["w_up"])
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    h = act(gt.astype(jnp.float32)).astype(gt.dtype) * ut
    ye = jnp.einsum("becf,efd->becd", h, we["w_down"])  # [B, E, C, D]

    # combine: scatter-add expert outputs back to (token, slot), weight, sum
    flat_tk = gather_idx.reshape(b, -1)  # [B, E*C] -> token-slot index
    contrib = ye.reshape(b, -1, d) * slot_valid.reshape(b, -1, 1).astype(ye.dtype)
    y_slots = jax.vmap(
        lambda ft, ct: jnp.zeros((s * mc.top_k, d), ct.dtype).at[ft].add(ct)
    )(flat_tk, contrib)
    w_slots = (top_p.reshape(b, -1, 1) * kept.reshape(b, -1, 1)).astype(ye.dtype)
    y = (y_slots * w_slots).reshape(b, s, mc.top_k, d).sum(axis=2)

    if "shared" in p:
        y = y + mlp(p["shared"], x, activation=activation)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))  # [E] mean router prob
    ce = jax.nn.one_hot(top_i[..., 0], mc.n_routed).mean(axis=(0, 1))  # top-1 load
    aux = mc.n_routed * jnp.sum(me * ce)
    return y, aux.astype(jnp.float32)
