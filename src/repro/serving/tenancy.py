"""Multi-tenant admission control: quotas, fair share, and the SLO ladder.

One :class:`~repro.serving.scheduler.AsyncPlanServer` process hosts every
demo app; this module makes it host every *customer* too.  Three pieces,
all deterministic (every time source is the server's injectable clock):

* :class:`TokenBucket` -- per-tenant admission quota.  ``rate`` tokens/s
  refill up to ``burst``; a submit that finds the bucket empty is
  *throttled* (``QuotaExceededError``, a transient ``QueueFullError``
  subclass, so ``submit_with_retry`` rides it out).  Quotas bound what a
  tenant may *offer*; fair share (below) bounds what it may *consume*.
* :class:`DeficitRoundRobin` -- weighted fair-share selection of batch
  members across tenant queues.  Each round a tenant's deficit grows by
  its weight and it may take one slot per whole unit of deficit, so over
  any backlogged window tenant ``i`` completes ``w_i / sum(w)`` of the
  slots (+/- one round's granularity) and **no tenant starves**: a
  positive weight earns a slot every ``ceil(1/w)`` rounds no matter how
  hot its neighbours run.  Deficits reset when a tenant's queue empties
  (idle tenants must not bank credit) and persist across batches
  otherwise.
* :class:`Tenant` + :class:`TenantSLO` + :class:`LadderConfig` -- the
  graceful-degradation ladder.  Each tenant's SLO (p99 latency and/or
  deadline-miss-rate targets) is evaluated from its *own* completion
  window every ``LadderConfig.interval`` seconds of engine clock; a
  breach streak escalates that tenant one rung, an in-SLO streak (longer:
  hysteresis) recovers one rung::

      0 normal        -> full service
      1 shrink_flush  -> the tenant's queued requests release partial
                         batches after flush_after * shrink_factor
                         (latency beats batching efficiency)
      2 demote_plan   -> the tenant's NEW admissions route to the plan's
                         registered cheaper variant (quantized / guarded
                         reference); in-flight work is untouched
      3 shed          -> the tenant's lowest-priority admissions are
                         turned away at submit (LadderShedError)

  Every transition is counted (``serving_ladder_transitions_total``),
  gauged (``serving_ladder_level``), traced as an instant, and visible in
  ``AsyncPlanServer.health()`` -- overload is absorbed by an explicit,
  observable policy instead of the watchdog.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, TypeVar

import numpy as np

__all__ = [
    "DeficitRoundRobin",
    "LADDER_LEVELS",
    "LadderConfig",
    "Tenant",
    "TenantSLO",
    "TokenBucket",
]

#: rung names, indexed by ladder level
LADDER_LEVELS = ("normal", "shrink_flush", "demote_plan", "shed")

#: per-tenant latency reservoir (window observations between SLO evals)
TENANT_LATENCY_RESERVOIR = 4096


class TokenBucket:
    """Classic token bucket on an injectable clock.  ``rate`` tokens/s
    refill up to ``burst``; ``take(now)`` consumes one token or reports
    exhaustion.  ``rate=None`` means unlimited (every take succeeds)."""

    def __init__(self, rate: Optional[float], burst: Optional[float] = None):
        if rate is not None and rate <= 0:
            raise ValueError(f"quota rate must be > 0 tokens/s, got {rate}")
        self.rate = rate
        self.burst = float(burst if burst is not None else (rate or 0) * 1.0) \
            if rate is not None else None
        if rate is not None and self.burst < 1.0:
            # a burst below one token could never admit anything
            self.burst = 1.0
        self.tokens = self.burst
        self._last: Optional[float] = None

    def take(self, now: float) -> bool:
        """Consume one token (refilled to ``now``); False when exhausted."""
        if self.rate is None:
            return True
        if self._last is not None and now > self._last:
            self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
        self._last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclasses.dataclass
class TenantSLO:
    """Per-tenant service-level objective, evaluated over the completions
    since the previous evaluation.  ``None`` targets are not checked; an
    evaluation with fewer than ``min_samples`` completions is skipped
    (streaks hold) so a quiet tenant neither escalates nor recovers on
    noise."""

    p99_latency: Optional[float] = None  # seconds
    max_miss_rate: Optional[float] = None  # deadline misses / completions
    min_samples: int = 8

    def breached(self, p99: float, miss_rate: float) -> bool:
        if self.p99_latency is not None and p99 > self.p99_latency:
            return True
        if self.max_miss_rate is not None and miss_rate > self.max_miss_rate:
            return True
        return False


@dataclasses.dataclass
class LadderConfig:
    """Degradation-ladder tuning.  Escalation needs ``breach_evals``
    consecutive breached evaluations; recovery needs ``recover_evals``
    consecutive in-SLO evaluations -- strictly more by default, so the
    ladder is hysteretic and cannot flap once per evaluation."""

    interval: float = 0.05  # engine-clock seconds between evaluations
    breach_evals: int = 2
    recover_evals: int = 4
    shrink_factor: float = 0.25  # rung-1 flush_after multiplier
    shed_below_priority: int = 1  # rung 3 sheds admissions with prio < this

    def __post_init__(self):
        if not 0 < self.shrink_factor <= 1:
            raise ValueError(
                f"shrink_factor must be in (0, 1], got {self.shrink_factor}"
            )
        if self.breach_evals < 1 or self.recover_evals < 1:
            raise ValueError("breach_evals/recover_evals must be >= 1")


@dataclasses.dataclass(eq=False)
class Tenant:
    """One tenant's admission/fair-share/SLO state inside a server.  All
    mutation happens under the owning server's lock."""

    name: str
    weight: float = 1.0
    bucket: TokenBucket = dataclasses.field(
        default_factory=lambda: TokenBucket(None)
    )
    slo: Optional[TenantSLO] = None
    ladder: LadderConfig = dataclasses.field(default_factory=LadderConfig)
    #: current rung (index into LADDER_LEVELS)
    level: int = 0
    breach_streak: int = 0
    ok_streak: int = 0
    #: engine-clock time of the next SLO evaluation (None until first tick)
    next_eval: Optional[float] = None
    #: completions / deadline misses since the last SLO evaluation
    window_completed: int = 0
    window_misses: int = 0
    window_latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=TENANT_LATENCY_RESERVOIR)
    )
    stats: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {
            "submitted": 0, "completed": 0, "throttled": 0, "ladder_shed": 0,
            "demoted_admissions": 0, "deadline_misses": 0,
            "ladder_up": 0, "ladder_down": 0,
        }
    )

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(
                f"tenant {self.name!r}: weight must be > 0, got {self.weight}"
            )

    @property
    def level_name(self) -> str:
        return LADDER_LEVELS[self.level]

    def observe(self, latency: float, missed: bool) -> None:
        """Record one completion into the current SLO window."""
        self.window_completed += 1
        self.window_misses += int(missed)
        self.window_latencies.append(latency)

    def evaluate(self) -> Optional[Tuple[int, int]]:
        """One SLO evaluation over the window since the last call.  Returns
        ``(from_level, to_level)`` when the ladder moved, else None.  The
        window resets whenever it was large enough to judge; undersized
        windows carry over (streaks hold)."""
        if self.slo is None:
            return None
        if self.window_completed < self.slo.min_samples:
            return None
        lats = np.asarray(self.window_latencies)
        p99 = float(np.percentile(lats, 99)) if lats.size else 0.0
        miss_rate = self.window_misses / self.window_completed
        breached = self.slo.breached(p99, miss_rate)
        self.window_completed = 0
        self.window_misses = 0
        self.window_latencies.clear()
        if breached:
            self.breach_streak += 1
            self.ok_streak = 0
            if (
                self.breach_streak >= self.ladder.breach_evals
                and self.level < len(LADDER_LEVELS) - 1
            ):
                self.breach_streak = 0
                frm, self.level = self.level, self.level + 1
                self.stats["ladder_up"] += 1
                return (frm, self.level)
        else:
            self.ok_streak += 1
            self.breach_streak = 0
            if self.ok_streak >= self.ladder.recover_evals and self.level > 0:
                self.ok_streak = 0
                frm, self.level = self.level, self.level - 1
                self.stats["ladder_down"] += 1
                return (frm, self.level)
        return None


T = TypeVar("T")


class DeficitRoundRobin:
    """Weighted deficit round-robin over named queues (one instance per
    plan queue).  ``select`` fills up to ``slots`` from per-tenant
    candidate lists: the rotation visits tenants in registration order
    starting one past last call's starting tenant, each visited tenant's
    deficit grows by its weight once per round, and every whole unit of
    deficit buys one slot.  Long-run share is weight-proportional with at
    most one round of slack; a tenant whose candidate list is empty has
    its deficit reset (no banking credit while idle)."""

    def __init__(self):
        self.deficits: Dict[str, float] = {}
        self._start = 0

    def select(
        self,
        candidates: Dict[str, List[T]],
        weights: Dict[str, float],
        slots: int,
    ) -> List[T]:
        """Destructively pop up to ``slots`` items across the candidate
        lists (each list already in that tenant's preferred order)."""
        out: List[T] = []
        names = list(candidates)
        if not names or slots <= 0:
            return out
        order = names[self._start % len(names):] + names[: self._start % len(names)]
        self._start += 1
        for name in names:
            if not candidates[name]:
                self.deficits[name] = 0.0
        while slots > 0 and any(candidates[n] for n in order):
            for name in order:
                q = candidates[name]
                if not q:
                    self.deficits[name] = 0.0
                    continue
                self.deficits[name] = self.deficits.get(name, 0.0) + weights.get(name, 1.0)
                while q and slots > 0 and self.deficits[name] >= 1.0:
                    out.append(q.pop(0))
                    self.deficits[name] -= 1.0
                    slots -= 1
                if slots == 0:
                    break
        return out

    def forget(self, names: Sequence[str]) -> None:
        for n in names:
            self.deficits.pop(n, None)
