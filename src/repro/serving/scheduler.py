"""PlanServer v2: an async continuous-batching engine over execution plans.

:class:`PlanServer` (v1, ``serving/engine.py``) blocks on batch fill: frames
queue up and nothing runs until the caller flushes.  This module decouples
admission from execution the way a real serving frontend must:

* :meth:`AsyncPlanServer.submit` returns a :class:`RequestHandle`
  (future-like) immediately; the caller blocks on ``handle.result()`` only
  when it actually needs the output.
* a tick-driven scheduler forms macro-batches *continuously* from the
  admission queues -- a batch launches as soon as it is full, or as soon as
  latency pressure (the engine-level ``flush_after`` or a request-level
  ``deadline``) says a partial batch beats waiting.  Ticks come from a
  background thread (:meth:`start`) or from explicit synchronous
  :meth:`step` calls, which is what deterministic tests drive (the clock is
  injectable for the same reason).
* one server hosts **many plans** (the three demo apps share a process):
  each plan gets its own admission queue + :class:`BatchedPlan`, and each
  tick round-robins over the ready queues so a flood on one plan cannot
  starve the others.
* admission is **bounded**: a full queue either rejects the new request
  (``overload="reject"``, raises :class:`QueueFullError`) or sheds
  whichever of queue + {incoming} would be scheduled last -- lowest
  priority class, newest arrival (``overload="shed"``: an evicted queued
  handle fails with :class:`QueueFullError`; an incoming request that is
  itself the victim raises at ``submit``, so it can never displace a
  higher-priority queued request); both are counted, so overload is
  visible in the stats instead of an unbounded memory ramp.

Request lifecycle::

    submit() -> queued -> [scheduler tick picks it] -> executing -> done
        |                                                  handle.result()
        +-> rejected/shed (handle raises QueueFullError)

Scheduling policy per tick, per plan (highest first within a plan):

1. full batch ready (``len(queue) >= batch_size``);
2. latency release: oldest queued request older than ``flush_after``, or
   any queued request's absolute deadline within ``deadline_margin``;
3. otherwise the queue waits (batch fill beats padding overhead).

Within a plan, requests are picked by ``(-priority, arrival)`` -- a higher
``priority`` class jumps the queue but never preempts a running batch.
Completion latency and per-request deadline misses are recorded per plan;
:meth:`latency_stats` reduces them to p50/p95/p99.

Observability (``repro.obs``): every per-plan counter bump is **mirrored**
into the metrics registry (``serving_events_total{plan, event}``,
``serving_latency_seconds{plan}``, ``serving_queue_depth_peak{plan}``) --
the per-instance ``stats`` dicts stay authoritative so two servers in one
process read their own numbers, while the registry aggregates across them
for export.  Under tracing, each request is one Chrome-trace *async* span
(``ph b/n/e``, id = rid) from admission to verdict, with a ``batched``
milestone naming the macro-batch that served it; each macro-batch is a
duration span carrying the rids it served -- so a trace links every
completed request to exactly one batch.

Multi-tenancy (PR 9, ``serving/tenancy.py`` + ``serving/rollout.py``): the
server is also fleet-shaped across *customers*.  ``submit(tenant=...)``
routes through that tenant's token-bucket quota
(:class:`QuotaExceededError` when exhausted -- transient, retried by
:func:`submit_with_retry`); batch membership is chosen by **weighted
deficit round-robin across tenant queues** so one hot tenant cannot starve
the others; every plan is a stack of :class:`~repro.serving.rollout.PlanVersion`
runnables so :meth:`AsyncPlanServer.swap_plan` hot-swaps a re-pruned /
re-quantized plan with zero request loss (admitted requests finish on their
admitted version, old versions retire when drained, a failed probe rolls
back); and each tenant's SLO drives the graceful-degradation **ladder**
(shrink flush_after -> demote to the registered cheaper variant -> shed
lowest-priority admissions, with hysteresis -- see ``tenancy.py``).  All of
it lands in ``health()``, the metrics registry
(``serving_tenant_events_total``, ``serving_ladder_level``,
``serving_ladder_transitions_total``, ``serving_swap_total``) and the
trace.

Autoregressive serving (PR 10, ``serving/kvcache.py`` +
``models/transformer_graph.py``): :meth:`AsyncPlanServer.add_llm`
registers a prefill/decode plan pair sharing a :class:`PagedKVCache`,
and :meth:`AsyncPlanServer.submit_llm` admits prompts into **token-level
continuous batching** -- every tick co-schedules one prefill batch (new
prompts) and one decode step (all active sequences), so a short prompt
starts decoding the tick it arrives instead of waiting for a long
neighbour to finish generating.  :class:`SequenceHandle` streams tokens
per tick; tenancy quotas/ladders, guarded execution and tracing compose
unchanged.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics
from ..obs import trace as _otrace
from ..utils.retry import retry_call
from .kvcache import CacheFullError, PagedKVCache
from .rollout import PlanVersion, SwapError, probe_version, version_health
from .tenancy import (
    LADDER_LEVELS,
    DeficitRoundRobin,
    LadderConfig,
    Tenant,
    TenantSLO,
    TokenBucket,
)

__all__ = [
    "AsyncPlanServer",
    "FrameSpecError",
    "LadderShedError",
    "QueueFullError",
    "QuotaExceededError",
    "RequestHandle",
    "SequenceHandle",
    "SwapError",
    "WatchdogTimeout",
    "submit_with_retry",
]


class QueueFullError(RuntimeError):
    """Raised by ``submit`` under the reject policy; stored on the shed
    handle under the shed policy."""


class QuotaExceededError(QueueFullError):
    """Raised by ``submit`` when the tenant's token bucket is exhausted.
    A ``QueueFullError`` subclass on purpose: quota throttling is
    transient (the bucket refills), so :func:`submit_with_retry` rides it
    out exactly like queue backpressure."""


class LadderShedError(QueueFullError):
    """Raised by ``submit`` when the tenant sits on the ladder's shed rung
    and the request's priority class is below the shed threshold -- the
    explicit overload response of last resort, counted per tenant."""


class FrameSpecError(ValueError):
    """Raised by ``submit`` when a frame's shape/dtype disagrees with the
    plan's input spec -- the malformed request fails *at admission*, so it
    can never poison the macro-batch it would have joined."""


class WatchdogTimeout(RuntimeError):
    """Stored on every handle of a batch whose execution exceeded the
    server's per-batch watchdog deadline (a hung kernel/compile).  Only
    that batch fails; the scheduler thread keeps ticking."""


@dataclasses.dataclass(eq=False)
class RequestHandle:
    """Per-request future.  ``result()`` blocks until the scheduler (or a
    synchronous :meth:`AsyncPlanServer.step`) completes the request, then
    returns the plan output for this single frame (batch dim stripped) or
    raises the stored error (shed under backpressure, execution failure)."""

    rid: int
    plan: str
    priority: int = 0
    #: admitting tenant (fair-share / quota / SLO accounting key)
    tenant: str = "default"
    #: absolute deadline (engine clock); None = best effort
    deadline_at: Optional[float] = None
    submitted_at: float = 0.0
    completed_at: Optional[float] = None
    deadline_missed: bool = False

    def __post_init__(self):
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._inputs: Optional[Tuple[Any, ...]] = None  # cleared at dispatch
        #: PlanVersion this request was admitted to; it executes there no
        #: matter what swap_plan installs afterwards
        self._runner: Optional[PlanVersion] = None

    # -- caller side --------------------------------------------------------- #
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} ({self.plan}) not done within {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self) -> Optional[BaseException]:
        return self._error if self._event.is_set() else None

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-completion seconds (None while in flight)."""
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    # -- scheduler side ------------------------------------------------------ #
    # _resolve/_fail are idempotent (first verdict wins): a batch the
    # watchdog abandoned must never have its handles re-resolved if the
    # hung worker eventually limps home.
    def _resolve(self, value, now: float) -> None:
        if self._event.is_set():
            return
        self.completed_at = now
        self.deadline_missed = (
            self.deadline_at is not None and now > self.deadline_at
        )
        self._value = value
        self._event.set()

    def _fail(self, err: BaseException, now: float) -> None:
        if self._event.is_set():
            return
        self.completed_at = now
        self._error = err
        self._event.set()


@dataclasses.dataclass(eq=False)
class SequenceHandle(RequestHandle):
    """Per-sequence future for autoregressive requests (``submit_llm``).

    Where a :class:`RequestHandle` resolves after one macro-batch, a
    sequence lives across many scheduler ticks: one prefill batch caches
    its prompt and emits the first token, then every tick it sits in the
    decode batch emits one more -- until EOS or ``max_new_tokens``.
    ``result()`` returns the generated token ids as an int32 array;
    :meth:`tokens_so_far` streams them while the sequence is live."""

    #: prompt token ids (set at submit; immutable)
    prompt: Tuple[int, ...] = ()
    max_new_tokens: int = 16
    #: stop token (None = run to max_new_tokens)
    eos_id: Optional[int] = None

    def __post_init__(self):
        super().__post_init__()
        self._generated: List[int] = []
        self._seq_id: Optional[int] = None  # KV-cache sequence id once admitted
        self._phase = "waiting"  # waiting -> decode -> (resolved)

    def tokens_so_far(self) -> Tuple[int, ...]:
        """Snapshot of the tokens generated so far (streaming view; grows
        by one per decode tick, plus the first token at prefill)."""
        return tuple(self._generated)


#: bounded completion-side buffers: a server nobody drains must plateau,
#: not ramp -- the admission queue bounds the inflow, these bound the wake
RETAINED_COMPLETIONS = 4096
LATENCY_RESERVOIR = 4096


@dataclasses.dataclass(eq=False)
class _PlanEntry:
    name: str
    #: the active PlanVersion new admissions route to (swap_plan replaces)
    primary: PlanVersion
    queue: List[RequestHandle] = dataclasses.field(default_factory=list)
    seq: int = 0  # FIFO tiebreak within a priority class
    #: high-water mark of the admission queue (never resets; the sizing
    #: signal ``health()`` exposes per plan)
    queue_peak: int = 0
    #: per-input (shape, dtype) submit() validates against; given at
    #: add_plan or latched from the first accepted frame
    input_spec: Optional[Tuple[Tuple[Tuple[int, ...], Any], ...]] = None
    #: registered degradation variants (the ladder's demotion targets)
    variants: Dict[str, PlanVersion] = dataclasses.field(default_factory=dict)
    #: the variant name rung-2 demotions route to (last registered with
    #: ladder_target=True)
    ladder_variant: Optional[str] = None
    #: swapped-out versions still owed verdicts; retired when drained
    draining: List[PlanVersion] = dataclasses.field(default_factory=list)
    version_seq: int = 0
    #: weighted fair-share selector over this plan's tenant sub-queues
    drr: DeficitRoundRobin = dataclasses.field(default_factory=DeficitRoundRobin)
    latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_RESERVOIR)
    )
    stats: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {
            "submitted": 0, "completed": 0, "batches": 0, "padded_frames": 0,
            "rejected": 0, "shed": 0, "deadline_flushes": 0,
            "deadline_misses": 0, "bad_frames": 0, "watchdog_timeouts": 0,
            "swaps": 0, "swap_rollbacks": 0, "versions_retired": 0,
            "demoted_admissions": 0,
        }
    )

    # back-compat views: pre-tenancy code (and tests) address the plan's
    # single runnable directly; that runnable is now the active version
    @property
    def plan(self):
        return self.primary.plan

    @property
    def params(self):
        return self.primary.params

    @property
    def batched(self):
        return self.primary.batched


@dataclasses.dataclass(eq=False)
class _LLMEntry:
    """One registered autoregressive model: a prefill plan, a decode plan,
    and the paged KV-cache they share.  Sequences wait in ``waiting`` in
    strict ``(-priority, arrival)`` order (no skip-ahead: a big prompt at
    the head must not starve behind smaller latecomers), move to ``active``
    when the batch has a slot AND the cache has pages for the prompt, and
    leave on EOS / ``max_new_tokens`` / failure -- always releasing their
    pages."""

    name: str
    prefill: Any  # ExecutionPlan, phase="prefill" graph
    decode: Any  # ExecutionPlan, phase="decode" graph
    cache: PagedKVCache
    max_batch: int = 4
    eos_id: Optional[int] = None
    waiting: List[SequenceHandle] = dataclasses.field(default_factory=list)
    active: List[SequenceHandle] = dataclasses.field(default_factory=list)
    seq: int = 0  # arrival order AND KV-cache sequence ids
    queue_peak: int = 0
    busy: bool = False  # one tick works an entry at a time
    latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_RESERVOIR)
    )
    stats: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {
            "submitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "prefill_batches": 0, "decode_batches": 0, "decode_tokens": 0,
            "cache_full": 0, "deadline_misses": 0,
        }
    )


class AsyncPlanServer:
    """Async continuous-batching server over one or more compiled plans.

    Deterministic use (tests; no thread)::

        server = AsyncPlanServer(clock=fake_clock)
        server.add_plan("style", plan, params, batch_size=4)
        h = server.submit("style", frame)
        server.step()          # one scheduler tick
        y = h.result(0)

    Production use::

        with AsyncPlanServer(flush_after=0.01) as server:
            server.add_plan(...); server.start()
            handles = [server.submit(app, f) for app, f in traffic]
            outs = [h.result() for h in handles]
    """

    def __init__(
        self,
        *,
        flush_after: Optional[float] = None,
        deadline_margin: float = 0.0,
        max_queue: int = 1024,
        overload: str = "reject",
        tick_interval: float = 0.002,
        watchdog: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if overload not in ("reject", "shed"):
            raise ValueError(f"overload policy {overload!r}: want reject|shed")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if watchdog is not None and watchdog <= 0:
            raise ValueError(f"watchdog must be > 0 seconds, got {watchdog}")
        self.flush_after = flush_after
        self.deadline_margin = deadline_margin
        self.max_queue = max_queue
        self.overload = overload
        self.tick_interval = tick_interval
        #: per-batch execution deadline (wall seconds); a batch that blows it
        #: fails its own handles with WatchdogTimeout and is abandoned to a
        #: daemon thread -- the scheduler moves on
        self.watchdog = watchdog
        self.closed = False
        self._tick_errors = 0  # scheduler-tick exceptions survived by _loop
        self._clock = clock
        self._plans: Dict[str, _PlanEntry] = {}
        self._llms: Dict[str, _LLMEntry] = {}
        #: tenants by name; "default" always exists (unit weight, no quota,
        #: no SLO) so single-tenant callers never see the machinery
        self._tenants: Dict[str, Tenant] = {"default": Tenant("default")}
        self._rr = 0  # round-robin start index over plan names
        self._rid = 0
        self._batch_seq = 0  # trace-facing macro-batch ids
        self._lock = threading.RLock()
        self._work = threading.Event()  # submit -> wake the scheduler thread
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._inflight = 0
        self._idle = threading.Condition(self._lock)
        #: completed handles not yet handed over via drain_completed(),
        #: bounded: only the most recent RETAINED_COMPLETIONS are kept, so a
        #: server whose caller works purely through handles (never drains)
        #: plateaus instead of retaining every output array forever
        self._completed: Deque[RequestHandle] = deque(maxlen=RETAINED_COMPLETIONS)

    @staticmethod
    def _bump(entry: _PlanEntry, event: str, amount: int = 1) -> None:
        """One stat increment, mirrored into the registry family
        ``serving_events_total{plan, event}``."""
        entry.stats[event] += amount
        if amount:
            _metrics.registry().counter(
                "serving_events_total", plan=entry.name, event=event
            ).inc(amount)

    @staticmethod
    def _bump_tenant(t: Tenant, event: str, amount: int = 1) -> None:
        """Per-tenant sibling of :meth:`_bump`, mirrored into
        ``serving_tenant_events_total{tenant, event}``."""
        t.stats[event] += amount
        if amount:
            _metrics.registry().counter(
                "serving_tenant_events_total", tenant=t.name, event=event
            ).inc(amount)

    # -- configuration ------------------------------------------------------- #
    def add_plan(
        self,
        name: str,
        plan,
        params,
        batch_size: int,
        *,
        via_vmap: bool = False,
        input_spec: Optional[Sequence[Tuple[Sequence[int], Any]]] = None,
    ) -> None:
        """Register a plan under ``name`` with its own admission queue and
        fixed compiled batch size.  All registered plans share the scheduler
        (and its fairness rotation).  ``input_spec`` -- one ``(shape, dtype)``
        per graph input (frame form, no batch dim) -- makes :meth:`submit`
        reject malformed frames immediately; without it the spec is latched
        from the first accepted frame."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed")
            if name in self._plans:
                raise ValueError(f"plan {name!r} already registered")
            spec = None
            if input_spec is not None:
                spec = tuple(
                    (tuple(int(d) for d in shape), np.dtype(dtype))
                    for shape, dtype in input_spec
                )
                if len(spec) != len(plan.graph.inputs):
                    raise ValueError(
                        f"input_spec has {len(spec)} entries; plan has "
                        f"{len(plan.graph.inputs)} inputs"
                    )
            self._plans[name] = _PlanEntry(
                name=name,
                primary=PlanVersion(
                    plan=plan, params=params,
                    batched=plan.batched(batch_size, via_vmap=via_vmap),
                    version=0,
                ),
                input_spec=spec,
            )

    def add_llm(
        self,
        name: str,
        *,
        prefill,
        decode,
        cache: PagedKVCache,
        max_batch: int = 4,
        eos_id: Optional[int] = None,
    ) -> None:
        """Register an autoregressive model: ``prefill``/``decode`` are the
        two compiled decoder plans (``build_decoder_graph`` phases, any
        backend) and ``cache`` the :class:`PagedKVCache` that holds its
        sequences' KV.  ``submit_llm`` then streams tokens through
        token-level continuous batching: each scheduler tick co-schedules
        one prefill batch (newly admitted prompts) and one decode step
        (every active sequence) on this model, so new prompts join the
        decode batch the tick after they arrive -- no generation-length
        head-of-line blocking.  ``max_batch`` bounds concurrently active
        sequences; ``eos_id`` is the default stop token."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed")
            if name in self._llms or name in self._plans:
                raise ValueError(f"{name!r} already registered")
            if max_batch < 1:
                raise ValueError(f"max_batch must be >= 1, got {max_batch}")
            n_pre = len(prefill.graph.inputs)
            n_dec = len(decode.graph.inputs)
            if n_pre != 3 or n_dec != 5:
                raise ValueError(
                    f"expected prefill(tokens, positions, lengths) and "
                    f"decode(tokens, positions, k_ctx, v_ctx, lengths) "
                    f"graphs; got {n_pre}/{n_dec} inputs"
                )
            self._llms[name] = _LLMEntry(
                name=name, prefill=prefill, decode=decode, cache=cache,
                max_batch=max_batch, eos_id=eos_id,
            )

    def add_tenant(
        self,
        name: str,
        *,
        weight: float = 1.0,
        rate: Optional[float] = None,
        burst: Optional[float] = None,
        slo: Optional[TenantSLO] = None,
        ladder: Optional[LadderConfig] = None,
    ) -> None:
        """Register a tenant: ``weight`` sets its fair share of batch slots
        (deficit round-robin), ``rate``/``burst`` its token-bucket admission
        quota (tokens/s; None = unlimited), ``slo`` + ``ladder`` its
        degradation policy.  ``submit(tenant=...)`` requires the name to be
        registered (typos must not silently fork accounting); re-registering
        "default" re-configures the built-in tenant."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed")
            if name in self._tenants and name != "default":
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = Tenant(
                name=name, weight=weight, bucket=TokenBucket(rate, burst),
                slo=slo, ladder=ladder or LadderConfig(),
            )
            _metrics.registry().gauge(
                "serving_ladder_level", tenant=name
            ).set(0)

    def register_variant(
        self,
        plan_name: str,
        variant: str,
        plan,
        params,
        *,
        batch_size: Optional[int] = None,
        via_vmap: bool = False,
        ladder_target: bool = True,
    ) -> None:
        """Register a cheaper runnable of ``plan_name`` (re-quantized,
        guarded-reference, smaller) under the label ``variant``.  With
        ``ladder_target=True`` (default) it becomes the rung-2 demotion
        target: a tenant escalated to ``demote_plan`` has its *new*
        admissions routed here until it recovers."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed")
            entry = self._plans.get(plan_name)
            if entry is None:
                raise KeyError(f"unknown plan {plan_name!r}")
            if variant in entry.variants or variant == "primary":
                raise ValueError(
                    f"variant {variant!r} already registered for {plan_name!r}"
                )
            entry.variants[variant] = PlanVersion(
                plan=plan, params=params,
                batched=plan.batched(
                    batch_size or entry.primary.batch_size, via_vmap=via_vmap
                ),
                version=0, variant=variant,
            )
            if ladder_target:
                entry.ladder_variant = variant

    def swap_plan(
        self,
        name: str,
        plan,
        params,
        *,
        batch_size: Optional[int] = None,
        via_vmap: bool = False,
        probe_frames: Optional[Sequence[Any]] = None,
        parity_tol: Optional[float] = None,
    ) -> int:
        """Atomically install a new version of plan ``name`` with **zero
        request loss**: requests admitted before the swap finish on the
        version that admitted them, new admissions route to the new
        version, and the old version retires once its outstanding count
        drains to zero (counted + traced).  The incoming version is probed
        first -- one batch must execute with finite outputs (and, when
        ``parity_tol`` is given, stay within it of the live version on the
        same frames); a failed probe raises :class:`SwapError` and **rolls
        back** (the live version never stops serving).  Returns the new
        version id."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed")
            entry = self._plans.get(name)
            if entry is None:
                raise KeyError(f"unknown plan {name!r}")
            old = entry.primary
            entry.version_seq += 1
            incoming = PlanVersion(
                plan=plan, params=params,
                batched=plan.batched(
                    batch_size or old.batch_size, via_vmap=via_vmap
                ),
                version=entry.version_seq,
            )
            spec = entry.input_spec
        # probe outside the lock: it executes a real batch (possibly a jit
        # compile) and admission must keep flowing to the live version
        try:
            probe_version(
                incoming, spec, probe_frames,
                reference=old, parity_tol=parity_tol,
            )
        except SwapError:
            with self._lock:
                self._bump(entry, "swap_rollbacks")
                _metrics.registry().counter(
                    "serving_swap_total", plan=name, event="rolled_back"
                ).inc()
            _otrace.instant(
                "plan_swap", cat="serving", plan=name,
                version=incoming.version, event="rolled_back",
            )
            raise
        with self._lock:
            if entry.primary is not old:
                # a concurrent swap won while we probed: treat ours as a
                # rollback rather than silently clobbering the winner
                self._bump(entry, "swap_rollbacks")
                _metrics.registry().counter(
                    "serving_swap_total", plan=name, event="rolled_back"
                ).inc()
                raise SwapError(
                    f"plan {name!r} was swapped concurrently; version "
                    f"{incoming.version} not installed"
                )
            entry.primary = incoming
            self._bump(entry, "swaps")
            _metrics.registry().counter(
                "serving_swap_total", plan=name, event="installed"
            ).inc()
            entry.draining.append(old)
            self._maybe_retire(entry)
        _otrace.instant(
            "plan_swap", cat="serving", plan=name,
            version=incoming.version, event="installed",
        )
        self._work.set()
        return incoming.version

    def _maybe_retire(self, entry: _PlanEntry) -> None:
        """Retire drained old versions (call with the lock held)."""
        still: List[PlanVersion] = []
        for v in entry.draining:
            if v.outstanding <= 0:
                self._bump(entry, "versions_retired")
                _metrics.registry().counter(
                    "serving_swap_total", plan=entry.name, event="retired"
                ).inc()
                _otrace.instant(
                    "plan_swap", cat="serving", plan=entry.name,
                    version=v.version, event="retired",
                )
            else:
                still.append(v)
        entry.draining = still

    @property
    def plans(self) -> Tuple[str, ...]:
        return tuple(self._plans)

    @property
    def llms(self) -> Tuple[str, ...]:
        return tuple(self._llms)

    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._tenants)

    # -- admission ----------------------------------------------------------- #
    def submit(
        self,
        plan_name: str,
        *frame_inputs,
        priority: int = 0,
        deadline: Optional[float] = None,
        tenant: Optional[str] = None,
    ) -> RequestHandle:
        """Queue one frame for ``plan_name`` (one array per graph input, no
        batch dim) and return its :class:`RequestHandle` immediately.
        ``deadline`` is a per-request latency budget in seconds (relative to
        now); a near deadline releases a partial batch early, and a late
        completion is counted in ``deadline_misses``.  A full queue follows
        the overload policy: ``reject`` raises :class:`QueueFullError`;
        ``shed`` drops whichever of queue + {this request} would be
        scheduled last (lowest priority class, newest arrival) -- an
        evicted queued handle fails with :class:`QueueFullError`, while an
        incoming request that is itself the victim raises here (at equal
        priority the newcomer is always the victim; only a strictly
        higher-priority submit evicts queued work).

        ``tenant`` names a registered tenant (None = the built-in
        "default"): its token bucket gates admission
        (:class:`QuotaExceededError`), its ladder rung may shed a
        low-priority request outright (:class:`LadderShedError`) or route
        it to the plan's registered cheaper variant, and its weight sets
        the fair share of batch slots the request competes under."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed; no further requests")
            entry = self._plans.get(plan_name)
            if entry is None:
                raise KeyError(
                    f"unknown plan {plan_name!r}; registered: {sorted(self._plans)}"
                )
            tname = tenant if tenant is not None else "default"
            t = self._tenants.get(tname)
            if t is None:
                raise KeyError(
                    f"unknown tenant {tname!r}; registered: "
                    f"{sorted(self._tenants)}"
                )
            n_in = len(entry.plan.graph.inputs)
            if len(frame_inputs) != n_in:
                raise TypeError(
                    f"plan {plan_name!r} expects {n_in} inputs per frame, "
                    f"got {len(frame_inputs)}"
                )
            frames = tuple(jnp.asarray(f) for f in frame_inputs)
            # shape/dtype gate: one malformed request fails HERE (its own
            # "handle"), never inside the macro-batch it would have joined
            if entry.input_spec is None:
                entry.input_spec = tuple(
                    (tuple(f.shape), np.dtype(f.dtype)) for f in frames
                )
            else:
                for i, (f, (shape, dtype)) in enumerate(
                    zip(frames, entry.input_spec)
                ):
                    if tuple(f.shape) != shape or np.dtype(f.dtype) != dtype:
                        self._bump(entry, "bad_frames")
                        raise FrameSpecError(
                            f"plan {plan_name!r} input {i}: frame is "
                            f"{tuple(f.shape)}/{np.dtype(f.dtype)}, spec is "
                            f"{shape}/{dtype}"
                        )
            now = self._clock()
            # ladder rung 3: the tenant's lowest priority classes are turned
            # away before they can consume a token or a queue slot
            if (
                t.level >= LADDER_LEVELS.index("shed")
                and priority < t.ladder.shed_below_priority
            ):
                self._bump_tenant(t, "ladder_shed")
                raise LadderShedError(
                    f"tenant {t.name!r} is on the {t.level_name!r} rung; "
                    f"priority {priority} admissions "
                    f"(< {t.ladder.shed_below_priority}) are shed"
                )
            if not t.bucket.take(now):
                self._bump_tenant(t, "throttled")
                raise QuotaExceededError(
                    f"tenant {t.name!r} quota exhausted "
                    f"({t.bucket.rate}/s, burst {t.bucket.burst})"
                )
            # pin the runnable at admission: primary, or -- when the
            # tenant sits on the demote_plan rung and a ladder variant is
            # registered -- the cheaper variant
            runner = entry.primary
            if (
                t.level >= LADDER_LEVELS.index("demote_plan")
                and entry.ladder_variant is not None
            ):
                runner = entry.variants[entry.ladder_variant]
                self._bump(entry, "demoted_admissions")
                self._bump_tenant(t, "demoted_admissions")
            shed: Optional[RequestHandle] = None
            if len(entry.queue) >= self.max_queue:
                if self.overload == "reject":
                    self._bump(entry, "rejected")
                    raise QueueFullError(
                        f"plan {plan_name!r} queue full "
                        f"({len(entry.queue)}/{self.max_queue}); request rejected"
                    )
                # shed: evict whichever of queue + {incoming} would be
                # scheduled *last* (max (-priority, seq) = lowest class,
                # newest arrival).  The incoming request competes too: at
                # equal-or-lower priority it IS scheduled last, and turning
                # it away must never evict a higher-priority queued request.
                victim = max(entry.queue, key=lambda h: (-h.priority, h._seq))
                if (-priority, entry.seq) >= (-victim.priority, victim._seq):
                    self._bump(entry, "shed")
                    raise QueueFullError(
                        f"plan {plan_name!r} queue full "
                        f"({len(entry.queue)}/{self.max_queue}) of equal-or-"
                        f"higher-priority requests; new request shed"
                    )
                entry.queue.remove(victim)
                victim._inputs = None  # evicted: release its frame arrays
                if victim._runner is not None:
                    victim._runner.outstanding -= 1
                    self._maybe_retire(entry)
                self._bump(entry, "shed")
                shed = victim
            handle = RequestHandle(
                rid=self._rid, plan=plan_name, priority=priority,
                tenant=t.name,
                deadline_at=None if deadline is None else now + deadline,
                submitted_at=now,
            )
            self._rid += 1
            handle._inputs = frames
            handle._seq = entry.seq
            entry.seq += 1
            handle._runner = runner
            runner.admitted += 1
            runner.outstanding += 1
            entry.queue.append(handle)
            self._bump(entry, "submitted")
            self._bump_tenant(t, "submitted")
            if len(entry.queue) > entry.queue_peak:
                entry.queue_peak = len(entry.queue)
                _metrics.registry().gauge(
                    "serving_queue_depth_peak", plan=plan_name
                ).set_max(entry.queue_peak)
            if _otrace.enabled():
                _otrace.async_begin(
                    "request", handle.rid, cat="serving", plan=plan_name,
                    priority=priority, tenant=t.name,
                )
        if shed is not None:
            shed._fail(
                QueueFullError(
                    f"request {shed.rid} shed from full {plan_name!r} queue"
                ),
                now,
            )
            if _otrace.enabled():
                _otrace.async_end("request", shed.rid, cat="serving",
                                  phase="shed")
        self._work.set()
        return handle

    def submit_llm(
        self,
        name: str,
        prompt_tokens,
        *,
        max_new_tokens: int = 16,
        eos_id: Optional[int] = None,
        priority: int = 0,
        deadline: Optional[float] = None,
        tenant: Optional[str] = None,
    ) -> SequenceHandle:
        """Queue one prompt for autoregressive generation on LLM ``name``
        and return its :class:`SequenceHandle` immediately.  The sequence
        is admitted to the running decode batch as soon as a slot and cache
        pages free up; ``handle.tokens_so_far()`` streams tokens per tick
        and ``handle.result()`` returns the full generation (int32 array,
        EOS included when hit).  Tenancy composes exactly as for
        :meth:`submit`: the tenant's token bucket gates admission, its
        ladder shed rung turns away low-priority prompts, and overload is
        reject-only (a queued sequence is a future cache reservation;
        eviction semantics would be release-and-retry, so backpressure is
        surfaced to the client instead)."""
        prompt = tuple(
            int(x) for x in np.asarray(prompt_tokens).reshape(-1).tolist()
        )
        if not prompt:
            raise ValueError("prompt_tokens must be non-empty")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed; no further requests")
            entry = self._llms.get(name)
            if entry is None:
                raise KeyError(
                    f"unknown llm {name!r}; registered: {sorted(self._llms)}"
                )
            tname = tenant if tenant is not None else "default"
            t = self._tenants.get(tname)
            if t is None:
                raise KeyError(
                    f"unknown tenant {tname!r}; registered: "
                    f"{sorted(self._tenants)}"
                )
            cache = entry.cache
            if cache.pages_for(len(prompt) + 1) > cache.num_pages:
                raise ValueError(
                    f"prompt of {len(prompt)} tokens can never fit the "
                    f"{cache.num_pages}x{cache.page_size}-token cache"
                )
            now = self._clock()
            if (
                t.level >= LADDER_LEVELS.index("shed")
                and priority < t.ladder.shed_below_priority
            ):
                self._bump_tenant(t, "ladder_shed")
                raise LadderShedError(
                    f"tenant {t.name!r} is on the {t.level_name!r} rung; "
                    f"priority {priority} admissions "
                    f"(< {t.ladder.shed_below_priority}) are shed"
                )
            if not t.bucket.take(now):
                self._bump_tenant(t, "throttled")
                raise QuotaExceededError(
                    f"tenant {t.name!r} quota exhausted "
                    f"({t.bucket.rate}/s, burst {t.bucket.burst})"
                )
            depth = len(entry.waiting) + len(entry.active)
            if depth >= self.max_queue:
                self._bump(entry, "rejected")
                raise QueueFullError(
                    f"llm {name!r} queue full ({depth}/{self.max_queue}); "
                    f"sequence rejected"
                )
            handle = SequenceHandle(
                rid=self._rid, plan=name, priority=priority, tenant=t.name,
                deadline_at=None if deadline is None else now + deadline,
                submitted_at=now,
                prompt=prompt, max_new_tokens=max_new_tokens,
                eos_id=eos_id if eos_id is not None else entry.eos_id,
            )
            self._rid += 1
            handle._seq = entry.seq
            entry.seq += 1
            entry.waiting.append(handle)
            entry.waiting.sort(key=lambda h: (-h.priority, h._seq))
            self._bump(entry, "submitted")
            self._bump_tenant(t, "submitted")
            if depth + 1 > entry.queue_peak:
                entry.queue_peak = depth + 1
                _metrics.registry().gauge(
                    "serving_queue_depth_peak", plan=name
                ).set_max(entry.queue_peak)
            if _otrace.enabled():
                _otrace.async_begin(
                    "request", handle.rid, cat="serving", plan=name,
                    priority=priority, tenant=t.name, kind="sequence",
                )
        self._work.set()
        return handle

    def pending(self, plan_name: Optional[str] = None) -> int:
        with self._lock:
            if plan_name is not None:
                if plan_name in self._llms:
                    e = self._llms[plan_name]
                    return len(e.waiting) + len(e.active)
                return len(self._plans[plan_name].queue)
            return sum(len(e.queue) for e in self._plans.values()) + sum(
                len(e.waiting) + len(e.active) for e in self._llms.values()
            )

    # -- scheduling ---------------------------------------------------------- #
    def _ready(self, entry: _PlanEntry, now: float, force: bool) -> Optional[str]:
        """Why this queue should release a batch now (None = keep filling).
        Fill is judged per runnable (a batch serves exactly one PlanVersion,
        so queued requests pinned to different versions/variants cannot fill
        one batch together); a tenant on the ``shrink_flush`` rung has its
        requests' flush_after scaled down by the ladder's shrink factor."""
        if not entry.queue:
            return None
        fill: Dict[int, int] = {}
        for h in entry.queue:
            r = h._runner
            n = fill.get(id(r), 0) + 1
            if n >= r.batch_size:
                return "full"
            fill[id(r)] = n
        if force:
            return "force"
        if self.flush_after is not None:
            for h in entry.queue:
                t = self._tenants.get(h.tenant)
                fa = self.flush_after
                if t is not None and t.level >= LADDER_LEVELS.index(
                    "shrink_flush"
                ):
                    fa *= t.ladder.shrink_factor
                if now - h.submitted_at >= fa:
                    return "flush_after"
        margin = self.deadline_margin
        if any(
            h.deadline_at is not None and h.deadline_at - now <= margin
            for h in entry.queue
        ):
            return "deadline"
        return None

    def _take_batch(
        self, entry: _PlanEntry, now: float
    ) -> Tuple[List[RequestHandle], PlanVersion]:
        """Pop up to one runnable's batch_size requests and return
        ``(batch, runner)``.  The target runner is whichever PlanVersion the
        overall most-urgent request (due-deadline, then -priority, then
        arrival) is pinned to -- a batch serves exactly one runnable, so the
        rest of the queue (other versions/variants) waits for its own turn.

        Membership within the target runner: *due* requests join first --
        deadline urgency outranks priority class for batch MEMBERSHIP (not
        just release timing): under sustained full-batch pressure from a
        higher priority class, a due request must join the released batch
        rather than starve while its deadline keeps triggering releases that
        exclude it.  Remaining slots are filled by weighted deficit
        round-robin across tenant sub-queues (each sub-queue in
        ``(-priority, arrival)`` order), so a hot tenant's backlog cannot
        monopolize the batch.  With only the default tenant this reduces
        exactly to the historical ``(due, -priority, arrival)`` order."""
        margin = self.deadline_margin

        def key(h: RequestHandle):
            due = h.deadline_at is not None and h.deadline_at - now <= margin
            return (not due, -h.priority, h._seq)

        runner = min(entry.queue, key=key)._runner
        pool = [h for h in entry.queue if h._runner is runner]
        size = runner.batch_size
        batch = sorted(
            (
                h for h in pool
                if h.deadline_at is not None and h.deadline_at - now <= margin
            ),
            key=lambda h: (-h.priority, h._seq),
        )[:size]
        taken = set(id(h) for h in batch)
        slots = size - len(batch)
        if slots > 0:
            by_tenant: Dict[str, List[RequestHandle]] = {}
            for h in pool:
                if id(h) not in taken:
                    by_tenant.setdefault(h.tenant, []).append(h)
            for q in by_tenant.values():
                q.sort(key=lambda h: (-h.priority, h._seq))
            weights = {
                n: self._tenants[n].weight
                for n in by_tenant if n in self._tenants
            }
            batch.extend(entry.drr.select(by_tenant, weights, slots))
            taken = set(id(h) for h in batch)
        entry.queue = [h for h in entry.queue if id(h) not in taken]
        return batch, runner

    def _execute(
        self, entry: _PlanEntry, runner: PlanVersion,
        batch: List[RequestHandle], reason: str = "full",
    ) -> None:
        """Run one macro-batch through the plan's compiled chunk and resolve
        every handle.  Called with the admission lock *released* so submits
        keep landing while the device works.

        With a ``watchdog`` deadline the compute runs in a disposable daemon
        thread: if it has not produced a verdict within the deadline the
        batch's handles fail with :class:`WatchdogTimeout` and the thread is
        abandoned (the handles' first-verdict-wins guard makes a late finish
        harmless) -- a hung kernel costs one batch, never the scheduler.

        Under tracing the whole call is one ``cat="serving"`` batch span
        (carrying the served rids and release ``reason``); each member
        request gets a ``batched`` milestone naming this batch and its
        terminal ``e`` event at the verdict."""
        box: Dict[str, Any] = {}

        def compute() -> None:
            try:
                # stacking stays inside the guard: a failing frame must fail
                # its batch's handles, never kill the scheduler thread
                inputs = tuple(
                    jnp.stack([h._inputs[i] for h in batch])
                    for i in range(len(batch[0]._inputs))
                )
                box["out"] = runner.batched.run_chunk(runner.params, *inputs)
            except Exception as e:  # resolve handles; callers see the error
                box["err"] = e

        with self._lock:
            bid = self._batch_seq
            self._batch_seq += 1
        with _otrace.span(
            "batch", cat="serving", plan=entry.name, batch=bid, reason=reason,
            version=runner.label(), rids=[h.rid for h in batch],
        ) as bsp:
            if _otrace.enabled():
                for h in batch:
                    _otrace.async_instant(
                        "request", h.rid, cat="serving", phase="batched",
                        batch=bid,
                    )
            timed_out = False
            if self.watchdog is None:
                compute()
            else:
                worker = threading.Thread(
                    target=compute, name=f"batch-{entry.name}", daemon=True
                )
                worker.start()
                worker.join(self.watchdog)
                timed_out = worker.is_alive()
            now = self._clock()
            with self._lock:
                out = box.get("out")
                err = box.get("err")
                if timed_out:
                    out = None
                    err = WatchdogTimeout(
                        f"batch of {len(batch)} on plan {entry.name!r} "
                        f"exceeded the {self.watchdog}s watchdog deadline"
                    )
                    self._bump(entry, "watchdog_timeouts")
                    bsp.set("timed_out", True)
                    _otrace.instant(
                        "watchdog_timeout", cat="serving", plan=entry.name,
                        batch=bid,
                    )
                traced = _otrace.enabled()
                for i, h in enumerate(batch):
                    h._inputs = None  # executed: release the frame arrays
                    if err is not None:
                        h._fail(err, now)
                    else:
                        h._resolve(
                            tuple(o[i] for o in out) if isinstance(out, tuple)
                            else out[i],
                            now,
                        )
                    t = self._tenants.get(h.tenant)
                    if h.deadline_missed:
                        self._bump(entry, "deadline_misses")
                        if t is not None:
                            self._bump_tenant(t, "deadline_misses")
                        _otrace.instant(
                            "deadline_miss", cat="serving", plan=entry.name,
                            rid=h.rid, batch=bid,
                        )
                    self._bump(entry, "completed")
                    if t is not None:
                        self._bump_tenant(t, "completed")
                    if h.latency is not None:
                        entry.latencies.append(h.latency)
                        _metrics.registry().histogram(
                            "serving_latency_seconds", plan=entry.name
                        ).observe(h.latency)
                        if t is not None:
                            t.observe(h.latency, h.deadline_missed)
                            _metrics.registry().histogram(
                                "serving_tenant_latency_seconds",
                                tenant=t.name,
                            ).observe(h.latency)
                    self._completed.append(h)
                    if traced:
                        _otrace.async_end(
                            "request", h.rid, cat="serving",
                            phase="failed" if err is not None else "completed",
                            batch=bid, deadline_missed=h.deadline_missed,
                        )
                self._bump(entry, "batches")
                self._bump(
                    entry, "padded_frames",
                    runner.batch_size - len(batch),
                )
                runner.outstanding -= len(batch)
                self._maybe_retire(entry)
                self._inflight -= 1
                self._idle.notify_all()

    def step(self, *, force: bool = False) -> int:
        """One synchronous scheduler tick: visit every plan queue in fair
        rotation and execute at most ONE macro-batch per ready queue.
        Returns the number of batches executed.  ``force=True`` releases
        every non-empty queue regardless of fill or deadlines (the drain
        path of :meth:`close`).  Deterministic tests call this directly with
        a clock injected at construction (there is deliberately no ``now``
        parameter: submit/complete timestamps come from that same clock, and
        a second time source here would silently skew flush_after/deadline
        accounting against them); the background thread calls it in a
        loop."""
        executed = 0
        with self._lock:
            self._evaluate_slos(self._clock())
            names = list(self._plans)
            if names:
                rotation = names[self._rr % len(names):] + names[: self._rr % len(names)]
                self._rr += 1
            else:
                rotation = []
        for name in rotation:
            with self._lock:
                entry = self._plans[name]
                t = self._clock()
                reason = self._ready(entry, t, force)
                if reason is None:
                    continue
                batch, runner = self._take_batch(entry, t)
                if reason in ("flush_after", "deadline"):
                    self._bump(entry, "deadline_flushes")
                self._inflight += 1
            self._execute(entry, runner, batch, reason)
            executed += 1
        for name in list(self._llms):
            executed += self._llm_tick(name)
        return executed

    def _evaluate_slos(self, now: float) -> None:
        """Walk every tenant's SLO ladder (call with the lock held).  Each
        tenant is judged at most once per ``ladder.interval`` of engine
        clock; a transition moves the ``serving_ladder_level`` gauge, counts
        into ``serving_ladder_transitions_total{tenant, direction,
        to_level}`` and emits a trace instant -- the overload response is an
        explicit, observable policy, never a silent mode flip."""
        for t in self._tenants.values():
            if t.slo is None:
                continue
            if t.next_eval is None:
                t.next_eval = now + t.ladder.interval
                continue
            if now < t.next_eval:
                continue
            t.next_eval = now + t.ladder.interval
            moved = t.evaluate()
            if moved is None:
                continue
            frm, to = moved
            direction = "up" if to > frm else "down"
            _metrics.registry().gauge(
                "serving_ladder_level", tenant=t.name
            ).set(to)
            _metrics.registry().counter(
                "serving_ladder_transitions_total",
                tenant=t.name, direction=direction,
                to_level=LADDER_LEVELS[to],
            ).inc()
            _otrace.instant(
                f"ladder_{direction}", cat="serving", tenant=t.name,
                from_level=LADDER_LEVELS[frm], to_level=LADDER_LEVELS[to],
            )

    # -- autoregressive (LLM) scheduling -------------------------------------- #
    def _llm_tick(self, name: str) -> int:
        """One continuous-batching tick for LLM ``name``: admit waiting
        prompts while the batch has slots and the cache has pages, run ONE
        prefill batch over the newly admitted, and ONE decode step over
        every already-active sequence.  Returns the number of batches run
        (so the scheduler thread keeps ticking while sequences are live
        instead of sleeping on the work event).  Compute runs with the
        admission lock released, exactly like :meth:`_execute`."""
        with self._lock:
            entry = self._llms.get(name)
            if entry is None or entry.busy:
                return 0
            admitted: List[SequenceHandle] = []
            while entry.waiting and len(entry.active) < entry.max_batch:
                h = entry.waiting[0]
                need = entry.cache.pages_for(len(h.prompt) + 1)
                if need > entry.cache.free_pages:
                    break  # strict order: no skip-ahead past a big prompt
                entry.waiting.pop(0)
                h._seq_id = h._seq
                entry.cache.allocate(h._seq_id)
                # reserve the prompt's pages now so the prefill append
                # cannot race another admission for them
                entry.cache.ensure_capacity(h._seq_id, len(h.prompt))
                entry.active.append(h)
                admitted.append(h)
            decoding = [h for h in entry.active if h._phase == "decode"]
            if not admitted and not decoding:
                return 0
            entry.busy = True
            self._inflight += 1
        executed = 0
        try:
            if admitted:
                self._llm_prefill(entry, admitted)
                executed += 1
            if decoding:
                self._llm_decode(entry, decoding)
                executed += 1
        finally:
            with self._lock:
                entry.busy = False
                self._inflight -= 1
                self._idle.notify_all()
        return executed

    def _llm_prefill(self, entry: _LLMEntry, batch: List[SequenceHandle]) -> None:
        """Run the prefill plan over the newly admitted prompts (padded to
        the longest, masked by per-row lengths), cache each sequence's
        per-layer KV, and emit each first greedy token."""
        cache = entry.cache
        lens = np.array([len(h.prompt) for h in batch], np.int32)
        s = int(lens.max())
        tokens = np.zeros((len(batch), s), np.int32)
        for j, h in enumerate(batch):
            tokens[j, : len(h.prompt)] = h.prompt
        positions = np.broadcast_to(
            np.arange(s, dtype=np.int32), tokens.shape
        )
        with self._lock:
            bid = self._batch_seq
            self._batch_seq += 1
        with _otrace.span(
            "llm_prefill", cat="serving", plan=entry.name, batch=bid,
            rids=[h.rid for h in batch], tokens=int(lens.sum()),
        ):
            try:
                outs = entry.prefill(
                    entry.prefill.graph.params, jnp.asarray(tokens),
                    jnp.asarray(positions), jnp.asarray(lens),
                )
                logits = np.asarray(outs[0])
                kvs = [np.asarray(o) for o in outs[1:]]
            except Exception as e:
                now = self._clock()
                with self._lock:
                    for h in batch:
                        self._llm_fail(entry, h, e, now)
                return
        now = self._clock()
        g, dh = cache.n_kv_heads, cache.head_dim
        with self._lock:
            self._bump(entry, "prefill_batches")
            for j, h in enumerate(batch):
                n = int(lens[j])
                k_new = np.stack(
                    [kv[j, :n].reshape(n, g, dh) for kv in kvs[0::2]], axis=1
                )
                v_new = np.stack(
                    [kv[j, :n].reshape(n, g, dh) for kv in kvs[1::2]], axis=1
                )
                cache.append(h._seq_id, k_new, v_new)
                self._llm_emit(entry, h, int(np.argmax(logits[j, n - 1])), now)

    def _llm_decode(self, entry: _LLMEntry, batch: List[SequenceHandle]) -> None:
        """One decode step for every active sequence: gather the batch's
        paged KV spans, run the decode plan on each sequence's last emitted
        token, append the fresh KV, emit the next greedy token."""
        cache = entry.cache
        ok: List[SequenceHandle] = []
        now = self._clock()
        with self._lock:
            for h in batch:
                if h.done():  # finished in this tick's prefill pass
                    continue
                try:
                    cache.ensure_capacity(h._seq_id, cache.length(h._seq_id) + 1)
                    ok.append(h)
                except CacheFullError as e:
                    self._bump(entry, "cache_full")
                    self._llm_fail(entry, h, e, now)
        if not ok:
            return
        sids = [h._seq_id for h in ok]
        lengths = np.array([cache.length(sid) for sid in sids], np.int32)
        k_ctx, v_ctx, lens = cache.gather(
            sids, min_tokens=int(lengths.max()) + 1
        )
        tokens = np.array([[h._generated[-1]] for h in ok], np.int32)
        positions = lengths[:, None]
        with self._lock:
            bid = self._batch_seq
            self._batch_seq += 1
        with _otrace.span(
            "llm_decode", cat="serving", plan=entry.name, batch=bid,
            rids=[h.rid for h in ok],
        ):
            try:
                outs = entry.decode(
                    entry.decode.graph.params, jnp.asarray(tokens),
                    jnp.asarray(positions), jnp.asarray(k_ctx),
                    jnp.asarray(v_ctx), jnp.asarray(lens),
                )
                logits = np.asarray(outs[0])
                kvs = [np.asarray(o) for o in outs[1:]]
            except Exception as e:
                now = self._clock()
                with self._lock:
                    for h in ok:
                        self._llm_fail(entry, h, e, now)
                return
        now = self._clock()
        g, dh = cache.n_kv_heads, cache.head_dim
        with self._lock:
            self._bump(entry, "decode_batches")
            self._bump(entry, "decode_tokens", len(ok))
            for j, h in enumerate(ok):
                k_new = np.stack(
                    [kv[j].reshape(1, g, dh) for kv in kvs[0::2]], axis=1
                )
                v_new = np.stack(
                    [kv[j].reshape(1, g, dh) for kv in kvs[1::2]], axis=1
                )
                cache.append(h._seq_id, k_new, v_new)
                self._llm_emit(entry, h, int(np.argmax(logits[j, -1])), now)

    def _llm_emit(self, entry: _LLMEntry, h: SequenceHandle, tok: int,
                  now: float) -> None:
        """Record one generated token and retire the sequence on EOS or
        length (call with the lock held)."""
        h._generated.append(tok)
        h._phase = "decode"
        if (h.eos_id is not None and tok == h.eos_id) or len(
            h._generated
        ) >= h.max_new_tokens:
            entry.active.remove(h)
            entry.cache.release(h._seq_id)
            h._resolve(np.asarray(h._generated, np.int32), now)
            self._bump(entry, "completed")
            t = self._tenants.get(h.tenant)
            if t is not None:
                self._bump_tenant(t, "completed")
            if h.deadline_missed:
                self._bump(entry, "deadline_misses")
                if t is not None:
                    self._bump_tenant(t, "deadline_misses")
            if h.latency is not None:
                entry.latencies.append(h.latency)
                _metrics.registry().histogram(
                    "serving_latency_seconds", plan=entry.name
                ).observe(h.latency)
                if t is not None:
                    t.observe(h.latency, h.deadline_missed)
            self._completed.append(h)
            if _otrace.enabled():
                _otrace.async_end(
                    "request", h.rid, cat="serving", phase="completed",
                    tokens=len(h._generated),
                )

    def _llm_fail(self, entry: _LLMEntry, h: SequenceHandle,
                  err: BaseException, now: float) -> None:
        """Fail one sequence and release its pages (call with the lock
        held).  Scheduler-side faults cost the affected sequences, never
        the engine -- the guarded backend absorbs kernel faults before
        they ever reach here."""
        if h in entry.active:
            entry.active.remove(h)
        if h._seq_id is not None and h._seq_id in entry.cache.sequences():
            entry.cache.release(h._seq_id)
        h._fail(err, now)
        self._bump(entry, "completed")
        self._bump(entry, "failed")
        t = self._tenants.get(h.tenant)
        if t is not None:
            self._bump_tenant(t, "completed")
        self._completed.append(h)
        if _otrace.enabled():
            _otrace.async_end(
                "request", h.rid, cat="serving", phase="failed",
            )

    # -- background thread --------------------------------------------------- #
    def start(self) -> "AsyncPlanServer":
        """Launch the scheduler thread (idempotent).  It ticks whenever work
        arrives and at least every ``tick_interval`` seconds, so deadline
        releases fire even when no submits are landing."""
        with self._lock:
            if self.closed:
                raise RuntimeError("AsyncPlanServer is closed")
            if self._thread is not None:
                return self
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="AsyncPlanServer", daemon=True
            )
            self._thread.start()
        return self

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                executed = self.step()
            except Exception:  # a bad tick is counted, never fatal
                with self._lock:
                    self._tick_errors += 1
                executed = 0
            if executed == 0:
                self._work.wait(self.tick_interval)
                self._work.clear()

    # -- completion / teardown ----------------------------------------------- #
    def drain_completed(self) -> List[RequestHandle]:
        """Hand over (and clear) the handles completed since the last drain,
        in completion order -- the bulk-consumer mirror of per-handle
        ``result()`` (the v1 ``PlanServer.drain_completed`` contract lifted
        to handles).  Drain regularly if completion order matters: the
        buffer keeps only the most recent ``RETAINED_COMPLETIONS`` handles
        (callers working purely through handles can ignore it -- results
        live on the handles either way, and the bound stops an undrained
        server from retaining every output array for its lifetime)."""
        with self._lock:
            done = list(self._completed)
            self._completed.clear()
        return done

    def close(self) -> int:
        """Stop the scheduler thread, drain every queue (partial batches
        force-flush -- queued requests are never dropped), and refuse
        further submits.  In-flight batches complete before close returns,
        so every handle ever accepted is resolved.  Returns the number of
        requests drained by close itself.  Idempotent; also runs on
        ``with`` exit."""
        with self._lock:
            if self.closed:
                return 0
            self.closed = True  # admission off first: the drain is bounded
            thread = self._thread
        if thread is not None:
            self._stop.set()
            self._work.set()
            thread.join()
            self._thread = None
        drained = 0
        llm_drained = set()
        while True:  # synchronous force-drain of whatever is still queued
            with self._lock:
                queued = sum(len(e.queue) for e in self._plans.values())
                for e in self._llms.values():
                    for h in list(e.waiting) + list(e.active):
                        if id(h) not in llm_drained:
                            llm_drained.add(id(h))
                            queued += 1
            if queued == 0:
                break
            drained += queued
            while self.step(force=True):
                pass
        with self._lock:  # wait out any batch the thread left in flight
            while self._inflight:
                self._idle.wait()
        return drained

    def __enter__(self) -> "AsyncPlanServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- stats ---------------------------------------------------------------- #
    @property
    def stats(self) -> Dict[str, Any]:
        """Aggregate counters plus ``per_plan`` / ``per_tenant`` breakdowns
        (copies).  The aggregate sums the per-plan counters only -- tenant
        counters are a second axis over the same requests, not additional
        traffic."""
        with self._lock:
            per_plan = {n: dict(e.stats) for n, e in self._plans.items()}
            per_tenant = {
                n: dict(t.stats) for n, t in self._tenants.items()
            }
            per_llm = {n: dict(e.stats) for n, e in self._llms.items()}
        total: Dict[str, int] = {}
        for s in per_plan.values():
            for k, v in s.items():
                total[k] = total.get(k, 0) + v
        total["per_plan"] = per_plan
        total["per_tenant"] = per_tenant
        if per_llm:
            total["per_llm"] = per_llm
        return total

    def health(self) -> Dict[str, Any]:
        """One liveness/degradation snapshot: scheduler state (running,
        in-flight batches, survived tick errors), per-plan queue depths and
        counters (bad frames, watchdog timeouts, overload), and -- for
        guarded plans -- the executor's guard stats (demotion counters plus
        every circuit breaker's state).  This is what ``launch/serve.py
        --async`` prints and what an external monitor should scrape."""
        with self._lock:
            plans: Dict[str, Any] = {}
            for n, e in self._plans.items():
                d: Dict[str, Any] = {
                    "queue_depth": len(e.queue),
                    "queue_peak": e.queue_peak,
                    "version": e.primary.version,
                    "stats": dict(e.stats),
                }
                if e.draining:
                    d["draining"] = [
                        {"version": v.version, "outstanding": v.outstanding}
                        for v in e.draining
                    ]
                if e.variants:
                    d["variants"] = version_health(e.variants)
                    d["ladder_variant"] = e.ladder_variant
                guard_stats = getattr(e.plan, "guard_stats", None)
                if callable(guard_stats):
                    gs = guard_stats()
                    if gs:
                        d["guard"] = gs
                plans[n] = d
            llms: Dict[str, Any] = {}
            for n, e in self._llms.items():
                ld: Dict[str, Any] = {
                    "waiting": len(e.waiting),
                    "active": len(e.active),
                    "queue_peak": e.queue_peak,
                    "cache": e.cache.occupancy(),
                    "stats": dict(e.stats),
                }
                for p in (e.prefill, e.decode):
                    guard_stats = getattr(p, "guard_stats", None)
                    if callable(guard_stats):
                        gs = guard_stats()
                        if gs:
                            ld.setdefault("guard", {})[
                                "prefill" if p is e.prefill else "decode"
                            ] = gs
                llms[n] = ld
            tenants = {
                n: {
                    "level": t.level,
                    "level_name": t.level_name,
                    "weight": t.weight,
                    "tokens": t.bucket.tokens,
                    "stats": dict(t.stats),
                }
                for n, t in self._tenants.items()
            }
            out = {
                "closed": self.closed,
                "running": self.running,
                "inflight": self._inflight,
                "tick_errors": self._tick_errors,
                "watchdog": self.watchdog,
                "pending": sum(p["queue_depth"] for p in plans.values())
                + sum(l["waiting"] + l["active"] for l in llms.values()),
                "plans": plans,
                "tenants": tenants,
            }
            if llms:
                out["llms"] = llms
            return out

    def latency_stats(
        self, plan_name: Optional[str] = None
    ) -> Dict[str, float]:
        """p50/p95/p99/mean completion latency (seconds) over the completed
        requests of one plan (or all plans)."""
        with self._lock:
            if plan_name is not None:
                src = (
                    self._llms[plan_name] if plan_name in self._llms
                    else self._plans[plan_name]
                )
                lats: Sequence[float] = list(src.latencies)
            else:
                lats = [
                    v for e in list(self._plans.values())
                    + list(self._llms.values()) for v in e.latencies
                ]
        if not lats:
            return {"count": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
        arr = np.asarray(lats)
        return {
            "count": int(arr.size),
            "p50": float(np.percentile(arr, 50)),
            "p95": float(np.percentile(arr, 95)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()),
        }


def submit_with_retry(
    server: AsyncPlanServer,
    plan_name: str,
    *frame_inputs,
    priority: int = 0,
    deadline: Optional[float] = None,
    tenant: Optional[str] = None,
    retries: int = 5,
    backoff: float = 0.005,
    backoff_factor: float = 2.0,
    jitter: float = 0.5,
    sleep: Callable[[float], None] = time.sleep,
) -> RequestHandle:
    """``server.submit`` wrapped in jittered exponential backoff on
    :class:`QueueFullError` -- the client-side companion to the bounded
    admission queue.  Backpressure bursts (queue momentarily full while the
    scheduler drains) retry with decorrelated delays instead of failing or
    stampeding; a queue that stays full through every retry still raises,
    so overload remains visible.  Only ``QueueFullError`` retries -- which
    includes its transient subclasses :class:`QuotaExceededError` (bucket
    refills) and :class:`LadderShedError` (tenant may recover) --
    ``FrameSpecError`` and closed-server errors are permanent."""
    return retry_call(
        lambda: server.submit(
            plan_name, *frame_inputs, priority=priority, deadline=deadline,
            tenant=tenant,
        ),
        retries=retries, backoff=backoff, backoff_factor=backoff_factor,
        jitter=jitter, retry_on=(QueueFullError,), sleep=sleep,
    )
