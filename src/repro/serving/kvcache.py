"""Block-table paged KV-cache for autoregressive serving.

The vLLM/pie-style memory layout: the cache is a fixed pool of fixed-size
pages (``[num_pages, n_layers, page_size, n_kv_heads, head_dim]`` for each
of k and v), and every live sequence owns an ordered *block table* of page
ids.  Appending tokens fills the tail page and pulls fresh pages from a
LIFO freelist; releasing a finished sequence returns its pages -- no
compaction, no per-sequence max-length reservation, so B sequences of
wildly different lengths share the pool densely.

The executor side stays dense: :meth:`gather` materializes each sequence's
pages as one contiguous ``[B, L, S_pad, G, dh]`` span (token axis = the
block table walked in order, zero-filled past each sequence's capacity) and
the ``attention`` op masks with ``lengths`` -- slots past the live length
never attract probability mass, so gather-then-mask equals contiguous-cache
attention exactly (the invariant ``tests/test_kvcache.py`` locks in).

Pools are host numpy on purpose: appends are in-place writes (no jnp
``.at[]`` copy of the whole pool per token), and the gather ships exactly
the pages the batch needs to the device each tick.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["CacheFullError", "PagedKVCache"]


class CacheFullError(RuntimeError):
    """The freelist cannot cover a requested allocation."""


class PagedKVCache:
    """Fixed-pool paged KV storage with per-sequence block tables.

    Thread-safe: the serving loop appends/gathers while submit/health
    threads read occupancy.
    """

    def __init__(
        self,
        *,
        num_pages: int,
        page_size: int,
        n_layers: int,
        n_kv_heads: int,
        head_dim: int,
        dtype=np.float32,
    ):
        if num_pages < 1 or page_size < 1:
            raise ValueError("num_pages and page_size must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.n_layers = int(n_layers)
        self.n_kv_heads = int(n_kv_heads)
        self.head_dim = int(head_dim)
        shape = (num_pages, n_layers, page_size, n_kv_heads, head_dim)
        self.k_pool = np.zeros(shape, dtype)
        self.v_pool = np.zeros(shape, dtype)
        #: LIFO freelist: released pages are reused hottest-first
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._lengths: Dict[int, int] = {}
        self._lock = threading.Lock()
        self.stats = {"allocs": 0, "releases": 0, "peak_used": 0}

    # -- occupancy ----------------------------------------------------------- #
    @property
    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def used_pages(self) -> int:
        with self._lock:
            return self.num_pages - len(self._free)

    def sequences(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(self._tables)

    def length(self, seq_id: int) -> int:
        with self._lock:
            return self._lengths[seq_id]

    def capacity(self, seq_id: int) -> int:
        with self._lock:
            return len(self._tables[seq_id]) * self.page_size

    def block_table(self, seq_id: int) -> Tuple[int, ...]:
        with self._lock:
            return tuple(self._tables[seq_id])

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    # -- lifecycle ----------------------------------------------------------- #
    def allocate(self, seq_id: int) -> None:
        """Register an empty sequence (no pages yet)."""
        with self._lock:
            if seq_id in self._tables:
                raise ValueError(f"sequence {seq_id} already allocated")
            self._tables[seq_id] = []
            self._lengths[seq_id] = 0

    def ensure_capacity(self, seq_id: int, n_tokens: int) -> None:
        """Grow ``seq_id``'s block table to hold ``n_tokens``.  All-or-
        nothing: a :class:`CacheFullError` leaves the table unchanged."""
        with self._lock:
            table = self._tables[seq_id]
            need = self.pages_for(n_tokens) - len(table)
            if need <= 0:
                return
            if need > len(self._free):
                raise CacheFullError(
                    f"need {need} pages for seq {seq_id}, "
                    f"{len(self._free)} free of {self.num_pages}"
                )
            for _ in range(need):
                table.append(self._free.pop())
            self.stats["allocs"] += need
            used = self.num_pages - len(self._free)
            self.stats["peak_used"] = max(self.stats["peak_used"], used)

    def append(self, seq_id: int, k_new: np.ndarray, v_new: np.ndarray) -> None:
        """Append ``T`` tokens of per-layer KV (``[T, L, G, dh]`` each),
        allocating pages on demand."""
        k_new = np.asarray(k_new)
        v_new = np.asarray(v_new)
        t = k_new.shape[0]
        if k_new.shape != v_new.shape or k_new.shape[1:] != (
            self.n_layers, self.n_kv_heads, self.head_dim
        ):
            raise ValueError(
                f"expected [T, {self.n_layers}, {self.n_kv_heads}, "
                f"{self.head_dim}], got k {k_new.shape} v {v_new.shape}"
            )
        self.ensure_capacity(seq_id, self.length(seq_id) + t)
        with self._lock:
            table = self._tables[seq_id]
            pos = self._lengths[seq_id]
            ps = self.page_size
            written = 0
            while written < t:
                page = table[(pos + written) // ps]
                slot = (pos + written) % ps
                run = min(t - written, ps - slot)
                src = slice(written, written + run)
                # pool layout is [page, L, slot, G, dh]; the new tokens come
                # in token-major [T, L, G, dh] -> swap to [L, T, G, dh]
                self.k_pool[page, :, slot : slot + run] = k_new[src].swapaxes(0, 1)
                self.v_pool[page, :, slot : slot + run] = v_new[src].swapaxes(0, 1)
                written += run
            self._lengths[seq_id] = pos + t

    def release(self, seq_id: int) -> int:
        """Return a finished sequence's pages to the freelist."""
        with self._lock:
            pages = self._tables.pop(seq_id)
            del self._lengths[seq_id]
            self._free.extend(reversed(pages))
            self.stats["releases"] += len(pages)
            return len(pages)

    # -- executor-facing gather ---------------------------------------------- #
    def gather(
        self,
        seq_ids: Sequence[int],
        *,
        min_tokens: int = 0,
        pad_to: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Materialize the batch's cache spans: ``(k_ctx, v_ctx, lengths)``
        with k/v ``[B, L, S_pad, G, dh]`` and lengths ``[B] int32``.

        ``S_pad`` is the largest per-sequence capacity (every owned page),
        raised to at least ``min_tokens`` rounded up to a page multiple --
        the decode step needs ``length + 1`` slots for the incoming token.
        """
        ps = self.page_size
        with self._lock:
            tables = [list(self._tables[s]) for s in seq_ids]
            lengths = np.array(
                [self._lengths[s] for s in seq_ids], np.int32
            )
        span = max(
            [len(tb) * ps for tb in tables] + [self.pages_for(min_tokens) * ps]
        )
        if pad_to is not None:
            span = max(span, pad_to)
            if span % ps:
                raise ValueError(f"pad_to {pad_to} not a page multiple")
        b = len(seq_ids)
        shape = (b, self.n_layers, span, self.n_kv_heads, self.head_dim)
        k_ctx = np.zeros(shape, self.k_pool.dtype)
        v_ctx = np.zeros(shape, self.v_pool.dtype)
        for j, tb in enumerate(tables):
            if not tb:
                continue
            n = len(tb) * ps
            # [n_pages, L, ps, G, dh] -> [L, n_pages*ps, G, dh]
            k_ctx[j, :, :n] = self.k_pool[tb].swapaxes(0, 1).reshape(
                self.n_layers, n, self.n_kv_heads, self.head_dim
            )
            v_ctx[j, :, :n] = self.v_pool[tb].swapaxes(0, 1).reshape(
                self.n_layers, n, self.n_kv_heads, self.head_dim
            )
        return k_ctx, v_ctx, lengths

    # -- invariants (the property-test surface) ------------------------------ #
    def check_invariants(self) -> None:
        """Every page is either free or owned by exactly one sequence, and
        every table covers its sequence's length."""
        with self._lock:
            owned: List[int] = []
            for sid, tb in self._tables.items():
                owned.extend(tb)
                if len(tb) * self.page_size < self._lengths[sid]:
                    raise AssertionError(
                        f"seq {sid}: length {self._lengths[sid]} exceeds "
                        f"capacity {len(tb) * self.page_size}"
                    )
            if len(set(owned)) != len(owned):
                raise AssertionError("page double-assigned across sequences")
            all_pages = set(owned) | set(self._free)
            if len(self._free) != len(set(self._free)):
                raise AssertionError("freelist contains duplicates")
            if all_pages != set(range(self.num_pages)) or len(owned) + len(
                self._free
            ) != self.num_pages:
                raise AssertionError("page leak: owned + free != pool")

    def occupancy(self) -> Dict[str, int]:
        with self._lock:
            return {
                "num_pages": self.num_pages,
                "page_size": self.page_size,
                "free_pages": len(self._free),
                "used_pages": self.num_pages - len(self._free),
                "sequences": len(self._tables),
                **self.stats,
            }


def _round_up(n: int, m: int) -> int:  # small helper shared by tests
    return int(math.ceil(n / m) * m)
