"""Batched serving engine: prefill + decode with jit'd steps, greedy/temperature
sampling, and a slot-based continuous-batching scheduler.

The engine wraps the uniform model API (models/registry.py):

* ``prefill(prompts)``   -- one jitted call filling every layer cache;
* ``decode(n)``          -- jitted single-token steps appended to outputs;
* :class:`RequestScheduler` -- fixed-slot continuous batching: finished
  sequences release their slot, queued requests are spliced into the batch
  (per-slot cache reset), the decode step never re-compiles.

Pruned serving: pass a model whose params were processed by the compiler
layer (``exec_mode='bsr'|'colpack'``) -- the engine is agnostic.

Plan serving: :class:`PlanServer` runs the vision apps' execution plans
(``core/graph/executor.py``) at throughput -- frames queue up and execute in
fixed-size compiled batches via :meth:`ExecutionPlan.batched`, padding only
the tail batch.  Its async successor lives in ``serving/scheduler.py``:
:class:`~repro.serving.scheduler.AsyncPlanServer` decouples admission from
execution (per-request handles, tick-driven continuous batching, multi-plan
routing, bounded queues with backpressure); v1 stays as the synchronous
building block and the deterministic baseline it is differential-tested
against.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import transformer as lm_mod
from ..models.registry import Model
from ..obs import metrics as _metrics

Array = jax.Array


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # [B, n_steps]
    logprobs: Optional[np.ndarray] = None


class Engine:
    def __init__(
        self,
        model: Model,
        params: Any,
        *,
        batch_size: int,
        max_len: int,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        if model.cfg.is_encdec:
            raise NotImplementedError("use EncDecEngine for whisper-family")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)

        cfg = self.cfg

        @jax.jit
        def _prefill(params, tokens, patch_embeds=None):
            logits, caches = lm_mod.prefill(
                params, cfg, tokens, max_len, patch_embeds=patch_embeds
            )
            return logits[:, -1], caches

        @jax.jit
        def _decode(params, tok_t, caches):
            logits, caches = lm_mod.decode_step(params, cfg, tok_t, caches)
            return logits[:, -1], caches

        self._prefill = _prefill
        self._decode = _decode

    # ------------------------------------------------------------------ #
    def _sample(self, logits: Array) -> Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.temperature).astype(jnp.int32)

    def generate(
        self,
        prompts: Array,  # [B, S] int32
        n_steps: int,
        patch_embeds: Optional[Array] = None,
    ) -> GenerationResult:
        assert prompts.shape[0] == self.batch_size
        logits, caches = self._prefill(self.params, prompts, patch_embeds) if (
            patch_embeds is not None
        ) else self._prefill(self.params, prompts)
        out = []
        tok = self._sample(logits)
        out.append(tok)
        for _ in range(n_steps - 1):
            logits, caches = self._decode(self.params, tok[:, None], caches)
            tok = self._sample(logits)
            out.append(tok)
        return GenerationResult(tokens=np.stack([np.asarray(t) for t in out], axis=1))


# --------------------------------------------------------------------------- #
# plan serving (vision apps through the graph compiler)                        #
# --------------------------------------------------------------------------- #


class PlanServer:
    """Throughput serving of a compiled :class:`ExecutionPlan`.

    Submitted frames (single samples, no batch dim) accumulate in a queue;
    :meth:`flush` stacks them into one macro-batch and pushes it through
    ``plan.batched(batch_size)`` -- every chunk runs at the fixed compiled
    batch shape, only the tail chunk carries padding.  Stats record the
    padding overhead, the serving cost of never re-compiling.

    ``flush_after`` (seconds) is the latency deadline for low-traffic
    serving: once the *oldest* queued frame has waited that long, the next
    :meth:`submit` or :meth:`poll` auto-flushes the partial batch instead of
    blocking on batch fill.  :meth:`poll` hands its flush output straight
    back; only *submit-triggered* flushes (whose caller receives a frame
    index, not outputs) buffer into ``completed`` -- drain it with
    :meth:`drain_completed` regularly, or the retained device arrays grow
    with server lifetime.  Manual :meth:`flush`/:meth:`close` return their
    outputs directly.  ``clock`` is injectable for tests.
    """

    def __init__(
        self,
        plan,
        params,
        batch_size: int,
        *,
        via_vmap: bool = False,
        flush_after: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        name: str = "default",
    ):
        self.plan = plan
        self.params = params
        self.batch_size = batch_size
        #: label for this server's registry mirror (``plan=<name>`` on the
        #: ``serving_v1_events_total`` family)
        self.name = name
        self.batched = plan.batched(batch_size, via_vmap=via_vmap)
        self._pending: List[Tuple[Array, ...]] = []
        self.closed = False
        self.flush_after = flush_after
        self._clock = clock
        self._oldest: Optional[float] = None
        #: outputs of *submit*-triggered deadline flushes, in flush order
        #: (poll-triggered flushes return their output to the caller
        #: instead); drain via :meth:`drain_completed`
        self.completed: List[Any] = []
        self.stats: Dict[str, int] = {
            "frames": 0, "batches": 0, "padded_frames": 0, "deadline_flushes": 0,
        }

    def submit(self, *frame_inputs: Array) -> int:
        """Queue one frame (one array per graph input, sans batch dim).
        Returns its index within the next flush.  With a ``flush_after``
        deadline, a queue whose oldest frame has exceeded it is flushed
        (output appended to ``completed``) right after this frame joins."""
        if self.closed:
            raise RuntimeError("PlanServer is closed; no further frames accepted")
        if len(frame_inputs) != len(self.plan.graph.inputs):
            raise TypeError(
                f"plan expects {len(self.plan.graph.inputs)} inputs per frame, "
                f"got {len(frame_inputs)}"
            )
        if not self._pending:
            self._oldest = self._clock()
        self._pending.append(tuple(jnp.asarray(f) for f in frame_inputs))
        idx = len(self._pending) - 1
        out = self._deadline_flush()
        if out is not None:
            # submit's caller only sees a frame index: buffer the outputs
            self.completed.append(out)
        return idx

    @property
    def pending(self) -> int:
        return len(self._pending)

    def _deadline_flush(self):
        if (
            self.closed
            or self.flush_after is None
            or self._oldest is None
            or not self._pending
            or self._clock() - self._oldest < self.flush_after
        ):
            return None
        out = self.flush()
        self.stats["deadline_flushes"] += 1
        _metrics.registry().counter(
            "serving_v1_events_total", plan=self.name, event="deadline_flushes"
        ).inc()
        return out

    def poll(self):
        """Deadline check: flush iff the oldest queued frame has waited at
        least ``flush_after`` seconds, returning the flushed outputs (or
        None).  No-op without a deadline, an empty queue, or a closed server
        -- call this from a serving loop's idle ticks so a lone frame is
        never stranded behind batch fill."""
        return self._deadline_flush()

    def drain_completed(self) -> List[Any]:
        """Hand over (and clear) the buffered submit-triggered flush
        outputs, oldest first."""
        done, self.completed = self.completed, []
        return done

    def flush(self):
        """Run all queued frames -- *including* a partial tail batch (the
        batched plan pads it to the compiled shape; no frame is ever
        dropped).  Returns outputs stacked over the frame axis (a tuple when
        the plan has multiple outputs), or None when the queue is empty."""
        if not self._pending:
            return None
        frames, self._pending = self._pending, []
        self._oldest = None
        inputs = tuple(
            jnp.stack([f[i] for f in frames]) for i in range(len(frames[0]))
        )
        out = self.batched(self.params, *inputs)
        reg = _metrics.registry()
        for k, v in self.batched.last_stats.items():
            self.stats[k] = self.stats.get(k, 0) + v
            if v:  # mirror: the v1 sibling of serving_events_total
                reg.counter(
                    "serving_v1_events_total", plan=self.name, event=k
                ).inc(v)
        return out

    def close(self):
        """Drain the queue (flushing any partial batch -- queued frames must
        never be dropped) and refuse further submits.  Returns the final
        flush's outputs (None if nothing was queued).  Idempotent; also runs
        on ``with PlanServer(...) as server:`` exit.  The server is marked
        closed even when the final flush raises, so a failing frame can
        never leave a half-closed server accepting new work."""
        try:
            return self.flush()
        finally:
            self.closed = True

    def __enter__(self) -> "PlanServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


# --------------------------------------------------------------------------- #
# continuous batching                                                          #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class RequestScheduler:
    """Fixed-slot continuous batching over the decode step.

    Each slot owns one row of the batched cache.  When a request finishes
    (max_new or eos), the slot's cache row is reset and the next queued
    request is prefilled into it (single-row prefill) while other slots keep
    decoding -- the standard orca/vLLM-style loop at toy scale.
    """

    def __init__(self, engine: Engine, eos_id: Optional[int] = None):
        self.engine = engine
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * engine.batch_size
        self._caches = None
        self._last_tok = np.zeros((engine.batch_size,), np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if (slot is None or slot.done) and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # single-row prefill: run the row through prefill and splice
                logits, caches = self.engine._prefill(
                    self.engine.params, jnp.asarray(req.prompt[None, :])
                )
                tok = int(np.asarray(jnp.argmax(logits, -1))[0])
                req.generated.append(tok)
                self._last_tok[i] = tok
                if self._caches is None:
                    # first admission: broadcast row cache to full batch
                    self._caches = jax.tree.map(
                        lambda c: jnp.concatenate(
                            [c] * self.engine.batch_size, axis=0
                        ) if hasattr(c, "ndim") and c.ndim > 0 and c.shape[0] == 1 else c,
                        caches,
                    )
                else:
                    self._caches = _splice_row(self._caches, caches, i)

    def step(self) -> bool:
        """One decode tick over all active slots.  Returns False when idle."""
        self._admit()
        active = [s for s in self.slots if s is not None and not s.done]
        if not active:
            return False
        logits, self._caches = self.engine._decode(
            self.engine.params, jnp.asarray(self._last_tok[:, None]), self._caches
        )
        toks = np.asarray(jnp.argmax(logits, -1))
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            t = int(toks[i])
            req.generated.append(t)
            self._last_tok[i] = t
            if len(req.generated) >= req.max_new or (
                self.eos_id is not None and t == self.eos_id
            ):
                req.done = True
        return True

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return [s for s in self.slots if s is not None]


def _splice_row(caches, row_caches, i: int):
    """Write row 0 of ``row_caches`` into row i of the batched ``caches``
    (leaves whose leading dim is the batch)."""

    def splice(full, row):
        if not hasattr(full, "ndim") or full.ndim == 0:
            return full  # scalars (pos counters) stay global
        return full.at[i].set(row[0])

    return jax.tree.map(splice, caches, row_caches)
