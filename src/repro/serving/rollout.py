"""Versioned plan rollout: zero-loss hot-swap of a served plan.

A re-pruned / re-quantized / re-tuned plan must be installable into a live
:class:`~repro.serving.scheduler.AsyncPlanServer` without dropping a single
request.  The unit of bookkeeping is :class:`PlanVersion` -- one concrete
runnable (plan + params + :class:`BatchedPlan`) with an outstanding-request
ledger.  Both rollout *versions* (v0, v1, ... of the primary) and
degradation *variants* (the ladder's registered cheaper fallback) are
PlanVersions, which is what lets the scheduler form every macro-batch over
requests that share one exact runnable:

* every request is pinned to its PlanVersion **at admission** and executes
  on it no matter what is installed afterwards;
* :meth:`AsyncPlanServer.swap_plan` probes the incoming version first
  (execute a probe batch, require finite outputs, optionally bound the
  parity drift vs the live version) -- a failed probe **rolls back**: the
  incoming version is discarded, the live version keeps serving, and the
  rollback is counted (``serving_swap_total{plan, event="rolled_back"}``);
* a successful swap atomically routes *new* admissions to the new version
  while the old version keeps draining its admitted work; when its
  outstanding count hits zero it is **retired** (counted + traced), so a
  long-running server holds exactly one live version per plan at rest.

State machine of one version::

    install -> probing -> active -> draining -> retired
                  |
                  +-> rolled_back (probe failed; never served traffic)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["PlanVersion", "SwapError", "probe_version", "version_health"]


class SwapError(RuntimeError):
    """Raised by ``swap_plan`` when the incoming version fails its probe;
    the previously active version is still serving (rollback)."""


@dataclasses.dataclass(eq=False)
class PlanVersion:
    """One runnable version of a served plan.  ``outstanding`` counts the
    requests admitted to this version that have not yet reached a terminal
    verdict (resolved / failed / shed) -- the drain signal for retirement.
    Mutated only under the owning server's lock."""

    plan: Any
    params: Any
    batched: Any  # BatchedPlan at this version's batch size
    version: int
    variant: str = "primary"
    admitted: int = 0
    outstanding: int = 0

    @property
    def batch_size(self) -> int:
        return self.batched.batch_size

    def label(self) -> str:
        """Stable id for stats/trace: ``v<version>`` for primaries,
        ``<variant>`` for registered degradation variants."""
        return f"v{self.version}" if self.variant == "primary" else self.variant


def probe_version(
    version: PlanVersion,
    input_spec: Optional[Sequence[Tuple[Tuple[int, ...], Any]]],
    probe_frames: Optional[Sequence[Any]] = None,
    *,
    reference: Optional[PlanVersion] = None,
    parity_tol: Optional[float] = None,
) -> None:
    """Execute one probe batch through ``version`` and raise
    :class:`SwapError` if it cannot serve: the chunk raises, an output is
    non-finite, or (when ``parity_tol`` is given) it drifts more than the
    tolerance from the live ``reference`` version on the same frames.

    ``probe_frames`` beats the synthesized zeros probe; with neither probe
    frames nor an input spec there is nothing to run, which is itself a
    refusal -- a swap must never install an unprobed version."""
    if probe_frames is None:
        if input_spec is None:
            raise SwapError(
                "cannot probe: no probe_frames given and no input_spec "
                "known -- refusing to install an unprobed version"
            )
        probe_frames = [
            jnp.zeros(shape, dtype) for shape, dtype in input_spec
        ]
    frames = tuple(jnp.asarray(f)[None] for f in probe_frames)
    try:
        out = version.batched.run_chunk(version.params, *frames)
    except Exception as e:
        raise SwapError(
            f"probe batch failed on incoming version "
            f"{version.label()}: {type(e).__name__}: {e}"
        ) from e
    outs = out if isinstance(out, tuple) else (out,)
    for i, o in enumerate(outs):
        arr = np.asarray(o)
        if np.issubdtype(arr.dtype, np.floating) and not np.all(np.isfinite(arr)):
            raise SwapError(
                f"probe output {i} of incoming version {version.label()} "
                f"is non-finite"
            )
    if parity_tol is not None and reference is not None:
        want = reference.batched.run_chunk(reference.params, *frames)
        wants = want if isinstance(want, tuple) else (want,)
        for i, (o, w) in enumerate(zip(outs, wants)):
            err = float(np.max(np.abs(np.asarray(o) - np.asarray(w))))
            if err > parity_tol:
                raise SwapError(
                    f"probe output {i} of incoming version "
                    f"{version.label()} drifts {err:.3e} from the live "
                    f"version (tolerance {parity_tol:.3e})"
                )


def version_health(versions: Dict[str, "PlanVersion"]) -> Dict[str, Any]:
    """``health()`` fragment for a plan's non-active versions/variants."""
    return {
        label: {
            "version": v.version,
            "variant": v.variant,
            "admitted": v.admitted,
            "outstanding": v.outstanding,
        }
        for label, v in versions.items()
    }
