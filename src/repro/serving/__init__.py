from .engine import Engine, GenerationResult, PlanServer, Request, RequestScheduler
from .scheduler import (
    AsyncPlanServer,
    FrameSpecError,
    QueueFullError,
    RequestHandle,
    WatchdogTimeout,
    submit_with_retry,
)

__all__ = [
    "AsyncPlanServer",
    "Engine",
    "FrameSpecError",
    "GenerationResult",
    "PlanServer",
    "QueueFullError",
    "Request",
    "RequestHandle",
    "RequestScheduler",
    "WatchdogTimeout",
    "submit_with_retry",
]
