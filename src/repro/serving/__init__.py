from .engine import Engine, GenerationResult, PlanServer, Request, RequestScheduler
from .rollout import PlanVersion, SwapError
from .scheduler import (
    AsyncPlanServer,
    FrameSpecError,
    LadderShedError,
    QueueFullError,
    QuotaExceededError,
    RequestHandle,
    WatchdogTimeout,
    submit_with_retry,
)
from .tenancy import (
    LADDER_LEVELS,
    DeficitRoundRobin,
    LadderConfig,
    Tenant,
    TenantSLO,
    TokenBucket,
)

__all__ = [
    "AsyncPlanServer",
    "DeficitRoundRobin",
    "Engine",
    "FrameSpecError",
    "GenerationResult",
    "LADDER_LEVELS",
    "LadderConfig",
    "LadderShedError",
    "PlanServer",
    "PlanVersion",
    "QueueFullError",
    "QuotaExceededError",
    "Request",
    "RequestHandle",
    "RequestScheduler",
    "SwapError",
    "Tenant",
    "TenantSLO",
    "TokenBucket",
    "WatchdogTimeout",
    "submit_with_retry",
]
