from .engine import Engine, GenerationResult, Request, RequestScheduler
