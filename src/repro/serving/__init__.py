from .engine import Engine, GenerationResult, PlanServer, Request, RequestScheduler
from .scheduler import AsyncPlanServer, QueueFullError, RequestHandle

__all__ = [
    "AsyncPlanServer",
    "Engine",
    "GenerationResult",
    "PlanServer",
    "QueueFullError",
    "Request",
    "RequestHandle",
    "RequestScheduler",
]
