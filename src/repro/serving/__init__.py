from .engine import Engine, GenerationResult, PlanServer, Request, RequestScheduler
from .kvcache import CacheFullError, PagedKVCache
from .rollout import PlanVersion, SwapError
from .scheduler import (
    AsyncPlanServer,
    FrameSpecError,
    LadderShedError,
    QueueFullError,
    QuotaExceededError,
    RequestHandle,
    SequenceHandle,
    WatchdogTimeout,
    submit_with_retry,
)
from .tenancy import (
    LADDER_LEVELS,
    DeficitRoundRobin,
    LadderConfig,
    Tenant,
    TenantSLO,
    TokenBucket,
)

__all__ = [
    "AsyncPlanServer",
    "CacheFullError",
    "DeficitRoundRobin",
    "Engine",
    "FrameSpecError",
    "GenerationResult",
    "LADDER_LEVELS",
    "LadderConfig",
    "LadderShedError",
    "PagedKVCache",
    "PlanServer",
    "PlanVersion",
    "QueueFullError",
    "QuotaExceededError",
    "Request",
    "RequestHandle",
    "RequestScheduler",
    "SequenceHandle",
    "SwapError",
    "Tenant",
    "TenantSLO",
    "TokenBucket",
    "WatchdogTimeout",
    "submit_with_retry",
]
