"""Tuning-cache pre-warm CLI (the ROADMAP's "tuning sweeps on real TPU
hardware" follow-on).

Builds a demo app's graph, runs it through the full pass pipeline, and
executes the resulting plans *eagerly* with tuning enabled, so every kernel
block-size key reachable from the plan -- the ``matmul`` / ``qmatmul`` /
``fused_elementwise`` / ``conv2d`` families -- triggers one candidate sweep
and lands its winner in a JSON :class:`~repro.kernels.ops.TuningCache`.
Ship the JSON to serving via ``REPRO_TUNE_CACHE=path`` and every plan starts
on measured winners instead of seeded defaults.

On real TPU hardware the sweeps time compiled kernels (keys land under
``|hw``); in a CPU container they time interpret-mode Python (``|interpret``)
-- still useful for exercising the full path in CI via ``--smoke``.

Examples::

  PYTHONPATH=src python -m repro.launch.tune --graph-app style_transfer \
      --out results/tuning_style.json
  PYTHONPATH=src python -m repro.launch.tune --graph-app all --quantize \
      --smoke                                   # CI-sized, CPU-safe
  PYTHONPATH=src python -m repro.launch.tune --graph-app coloring \
      --ops conv2d,qmatmul --smoke              # sweep only two key families
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops


def _sweep_app(app: str, args) -> None:
    """Compile ``app`` and execute its plan(s) eagerly so every reachable
    kernel call resolves -- and therefore sweeps -- its tuning key."""
    from ..core.graph import PassContext, PassManager, compile_plan
    from ..models.cnn import APP_ACT_SKIP, APP_QUANT_SKIP, APPS, app_masks
    from ..quant import calibrate_plan

    g = APPS[app](jax.random.PRNGKey(args.seed), base=args.base)
    masks, structures = app_masks(g, app, sparsity=args.sparsity)
    go = PassManager().run(g, PassContext(masks=masks, structures=structures))
    c_in = 1 if app == "coloring" else 3
    shape = (args.batch, c_in, args.size, args.size)
    rng = np.random.default_rng(args.seed)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    plan = compile_plan(go, backend="kernel")
    jax.block_until_ready(plan(go.params, x))  # f32 matmul/conv/ew keys
    n_keys = len(kops.tuning_cache().entries)
    print(f"{app}: kernel plan swept ({len(plan.steps)} steps, "
          f"{n_keys} cache keys so far)")

    if args.quantize:
        plan_ref = compile_plan(go, backend="reference")
        table = calibrate_plan(plan_ref, go.params, [x])
        gq = PassManager(("quantize",)).run(
            go,
            PassContext(
                calibration=table, quant_skip=APP_QUANT_SKIP[app],
                act_quant_skip=APP_ACT_SKIP[app],
            ),
        )
        plan_q = compile_plan(gq, backend="quant")
        jax.block_until_ready(plan_q(gq.params, x))  # qmatmul/int8-conv keys
        print(f"{app}: quant plan swept "
              f"({len(kops.tuning_cache().entries)} cache keys so far)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--graph-app",
                    choices=["style_transfer", "coloring", "super_resolution", "all"],
                    default="all", help="demo app whose plan keys to pre-warm")
    ap.add_argument("--size", type=int, default=64, help="frame size")
    ap.add_argument("--base", type=int, default=16, help="channel width")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quantize", action="store_true",
                    help="also sweep the INT8 plan (qmatmul / int8 conv keys)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CPU/CI (sweeps interpret-mode keys)")
    ap.add_argument("--ops", default=None,
                    help="comma-separated key families to sweep (e.g. "
                         "'conv2d,qmatmul'); other families resolve to "
                         "defaults without sweeping")
    ap.add_argument("--out", default=None,
                    help="cache JSON path (default: REPRO_TUNE_CACHE or "
                         "results/tuning_cache.json)")
    args = ap.parse_args()
    if args.smoke:
        args.size, args.base = min(args.size, 16), min(args.base, 8)

    cache = kops.tuning_cache()
    cache.enabled = True
    if args.ops:
        cache.ops_filter = frozenset(
            op.strip() for op in args.ops.split(",") if op.strip()
        )
    apps = (
        ["style_transfer", "coloring", "super_resolution"]
        if args.graph_app == "all" else [args.graph_app]
    )
    for app in apps:
        _sweep_app(app, args)

    print(cache.report())
    print(cache.stats_report())
    out = args.out or os.environ.get("REPRO_TUNE_CACHE") or os.path.join(
        "results", "tuning_cache.json"
    )
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    print(f"tune: {cache.sweeps} sweeps, {len(cache.entries)} keys -> {cache.save(out)}")


if __name__ == "__main__":
    main()
