from .mesh import HW, make_mesh, make_production_mesh
