"""End-to-end training launcher (runs REAL steps on whatever devices exist).

On this CPU container it trains reduced configs (``--smoke``); on a real
pod the same script takes the full config -- all distribution goes through
the same pjit path the dry-run validates.  Features wired in:

* ADMM structured pruning phases: dense warmup -> ADMM -> hard prune ->
  masked fine-tune (the paper's full pipeline, --prune);
* checkpoint/resume (atomic, keep-N), preemption-safe exit, straggler log;
* gradient accumulation, remat, deterministic data with checkpointed cursor.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 60 --batch 8 --seq 128 --prune --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, smoke_config
from ..core.pruning import AdmmConfig, Block, Column, PrunePlan, hard_prune
from ..data.pipeline import PipelineState, SyntheticPipeline
from ..models import get_model
from ..training.checkpoint import CheckpointManager
from ..training.fault_tolerance import PreemptionHandler, StragglerMonitor
from ..training.optimizer import AdamWConfig
from ..training.train_loop import TrainState, init_train_state, make_train_step


def default_prune_plan(sparsity: float = 0.5) -> PrunePlan:
    """The paper's recipe mapped to transformer weights (DESIGN.md section 7):
    column pruning for FFN in-projections (style-transfer recipe), MXU-block
    pruning for attention projections."""
    return PrunePlan.from_rules(
        [
            ("*ffn*w_gate*['w']", Column(sparsity)),
            ("*ffn*w_up*['w']", Column(sparsity)),
            ("*attn*w_q*['w']", Block(sparsity, bm=64, bn=64)),
            ("*attn*w_o*['w']", Block(sparsity, bm=64, bn=64)),
        ],
        min_size=16384,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--prune", action="store_true")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--admm-every", type=int, default=10)
    ap.add_argument("--hard-prune-at", type=float, default=0.6,
                    help="fraction of steps before hard prune + masked tune")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    pipe = SyntheticPipeline(cfg, batch=args.batch, seq=args.seq + 1, seed=args.seed)

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    admm_cfg = AdmmConfig(rho=1e-2, rho_ramp=1.2, rho_max=1.0, update_every=args.admm_every) if args.prune else None
    plan = default_prune_plan(args.sparsity) if args.prune else None

    params = model.init(jax.random.PRNGKey(args.seed))
    state = init_train_state(params, opt_cfg, admm_cfg=admm_cfg, prune_plan=plan)
    step_fn = jax.jit(make_train_step(model.loss, opt_cfg, admm_cfg=admm_cfg, accum=args.accum))

    mgr = CheckpointManager(args.ckpt, save_every=args.save_every) if args.ckpt else None
    start_step = 0
    if mgr:
        restored = mgr.restore_latest((state, pipe.state.to_dict()))
        if restored:
            (state, data_state), start_step = restored
            pipe.state = PipelineState.from_dict(
                {k: int(v) for k, v in data_state.items()}
            )
            print(f"resumed from step {start_step}")

    hard_at = int(args.steps * args.hard_prune_at) if args.prune else -1
    mon = StragglerMonitor(
        on_straggler=lambda s, dt, med: print(f"  [straggler] step {s}: {dt:.2f}s vs median {med:.2f}s")
    )
    with PreemptionHandler() as pre:
        for step in range(start_step, args.steps):
            mon.start_step()
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            state, metrics = step_fn(state, batch)
            dt = mon.end_step()
            if step % 10 == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0}
                print(
                    f"step {step:5d} loss={m.get('loss', 0):.4f} ce={m.get('ce', 0):.4f} "
                    + (f"residual={m.get('primal_residual', 0):.3f} " if args.prune else "")
                    + f"({dt:.2f}s)"
                )
            if args.prune and step == hard_at:
                pruned, masks = hard_prune(state.params, state.admm)
                state = TrainState(params=pruned, opt=state.opt, admm=None, masks=masks)
                step_fn = jax.jit(make_train_step(model.loss, opt_cfg, accum=args.accum))
                from ..core.pruning import tree_sparsity_report

                rep = tree_sparsity_report(pruned, masks)
                print(f"  [hard prune] global sparsity over pruned leaves: "
                      f"{rep['pruned_global']:.3f}; masked fine-tune begins")
            if mgr:
                mgr.maybe_save(step + 1, (state, pipe.state.to_dict()),
                               force=pre.should_stop)
            if pre.should_stop:
                print(f"preempted at step {step}; checkpoint saved; exiting cleanly")
                return
    print(f"done; median step {mon.median:.2f}s, stragglers: {len(mon.straggler_steps)}")


if __name__ == "__main__":
    main()
