"""Roofline analysis (deliverable g): three terms per (arch x shape) cell
from the dry-run JSONs, dominant bottleneck, MODEL_FLOPS ratio, and a
markdown table for EXPERIMENTS.md section Roofline.

  compute    = HLO_FLOPs_per_device / 197e12           (bf16 peak / chip)
  memory     = HLO_bytes_per_device / 819e9            (HBM bw / chip)
  collective = collective_bytes_per_device / 50e9      (ICI link bw)

Numerators use the probe-corrected counts (dryrun.py); the table is
single-pod (256 chips) per the assignment.  ``roofline_fraction`` =
ideal_compute_time / max(all three) -- how close the step is to the
compute roof if perfectly overlapped.

Usage: python -m repro.launch.roofline [--dir results/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

from .mesh import HW

__all__ = ["analyze_record", "build_table", "main"]


def analyze_record(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "run" or not rec.get("ok"):
        return None
    chips = rec["chips"]
    cost = rec.get("cost_corrected") or rec["cost"]
    coll = rec.get("collectives_corrected") or rec["collectives"]
    flops_dev = cost["flops"]
    bytes_dev = cost["bytes_accessed"]
    coll_dev = coll["total_bytes"]
    t_compute = flops_dev / HW.PEAK_FLOPS
    t_memory = bytes_dev / HW.HBM_BW
    t_collective = coll_dev / HW.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    model_fl = rec["model_flops"]
    hlo_total = flops_dev * chips
    useful = model_fl / hlo_total if hlo_total else 0.0
    # ideal step time = max(model FLOPs at peak, every argument byte read
    # once at HBM bw) -- decode is *legitimately* memory-bound (weights + KV
    # must stream), so a compute-only ideal would be meaningless there.
    t_ideal_c = model_fl / (chips * HW.PEAK_FLOPS)
    t_ideal_m = rec["memory"]["argument_bytes"] / HW.HBM_BW
    t_ideal = max(t_ideal_c, t_ideal_m)
    bound = max(terms.values())
    frac = t_ideal / bound if bound > 0 else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "step", "chips")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "model_flops": model_fl,
        "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "t_ideal_s": t_ideal,
        "roofline_fraction": frac,
        "fits_hbm": rec["memory"]["fits_hbm"],
        "live_gib": rec["memory"]["live_bytes"] / 2**30,
    }


_SUGGEST = {
    "compute": "cut HLO FLOPs: less remat recompute, fuse epilogues, or prune (BSR) the big GEMMs",
    "memory": "cut HBM traffic: fuse producers/consumers, bf16 intermediates, smaller logits dtype",
    "collective": "cut ICI bytes: reduce-scatter instead of all-reduce, bf16 grads, remat policy that saves TP-boundary activations, sequence parallelism",
}


def build_table(records: List[Dict[str, Any]]) -> str:
    rows = [
        "| arch | shape | step | compute s | memory s | collective s | dominant | useful (6ND/HLO) | roofline frac | live GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | "
            f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['live_gib']:.1f} | {'y' if r['fits_hbm'] else 'N'} |"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
    )
    ap.add_argument("--dir", default=default_dir)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()

    records = []
    skips = []
    for path in sorted(glob.glob(os.path.join(args.dir, f"*__{args.mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "run":
            skips.append(f"{rec['arch']} {rec['shape']}: {rec['status']}")
            continue
        a = analyze_record(rec)
        if a:
            records.append(a)
        else:
            skips.append(f"{rec['arch']} {rec['shape']}: FAILED {rec.get('error','')}")
    table = build_table(records)
    print(table)
    print("\nSkipped/failed cells:")
    for s in skips:
        print("  ", s)
    print("\nPer-cell dominant-term advice:")
    for r in records:
        print(f"  {r['arch']:22s} {r['shape']:12s} -> {r['dominant']}: {_SUGGEST[r['dominant']]}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(table + "\n")


if __name__ == "__main__":
    main()
