"""Serving launcher: batched generation + continuous-batching demo, plus
plan-based serving of the paper's three vision apps.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 12
  PYTHONPATH=src python -m repro.launch.serve --llm --smoke --frames 6 \
      --new-tokens 8          # decoder plans + paged KV continuous batching
  PYTHONPATH=src python -m repro.launch.serve --graph-app style_transfer \
      --size 64 --frames 3
  PYTHONPATH=src python -m repro.launch.serve --graph-app coloring \
      --size 64 --frames 10 --batch-size 4   # throughput mode (PlanServer)
  PYTHONPATH=src python -m repro.launch.serve --graph-app style_transfer \
      --quantize                             # INT8 weights + parity stats
  PYTHONPATH=src python -m repro.launch.serve --async --frames 8 \
      --batch-size 4 --flush-after 0.01      # all three apps, one process
"""

from __future__ import annotations

import argparse
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, smoke_config
from ..models import get_model
from ..serving.engine import Engine, Request, RequestScheduler
from ..utils.fileio import atomic_write_json


class _MetricsDump:
    """``--metrics-dump`` session: arms tracing for the duration, snapshots
    the metrics registry every ``interval`` seconds on a daemon thread, and
    on exit writes the snapshot series (plus a final one) to ``path`` and
    the session's Chrome trace next to it (``<path>.trace.json``)."""

    def __init__(self, path: str, interval: float):
        self.path = path
        self.interval = interval
        self._snaps: list = []
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _loop(self) -> None:
        from ..obs import metrics

        while not self._stop.wait(self.interval):
            self._snaps.append(
                {"t": time.time(), "metrics": metrics.registry().snapshot()}
            )

    def __enter__(self) -> "_MetricsDump":
        from ..obs import trace

        trace.start_tracing()
        self._thread = threading.Thread(
            target=self._loop, name="metrics-dump", daemon=True
        )
        self._thread.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        from ..obs import metrics, trace

        self._stop.set()
        self._thread.join()
        self._snaps.append(
            {"t": time.time(), "metrics": metrics.registry().snapshot()}
        )
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        # crash-safe (utils.fileio): a killed server never leaves a
        # truncated snapshot JSON -- same recipe as TuningCache.save
        atomic_write_json(
            self.path,
            {"interval_s": self.interval, "snapshots": self._snaps},
            indent=1, prefix=".metrics-",
        )
        buf = trace.stop_tracing()
        trace_path = buf.save(self.path + ".trace.json")
        print(f"metrics: {len(self._snaps)} snapshots -> "
              f"{os.path.abspath(self.path)}")
        print(f"trace: {len(buf.events)} events -> {trace_path} "
              f"(load in Perfetto / chrome://tracing)")


def _serve_graph_app(args) -> None:
    """Compile one of the paper's demo apps through the full pipeline
    (PassManager -> execution plan) and serve frames through the plan."""
    from ..core.graph import PassContext, PassManager, compile_plan
    from ..models.cnn import APP_ACT_SKIP, APP_QUANT_SKIP, APPS, app_masks

    build = APPS[args.graph_app]
    g = build(jax.random.PRNGKey(args.seed), base=args.base)
    masks, structures = app_masks(g, args.graph_app, sparsity=args.sparsity)
    ctx = PassContext(masks=masks, structures=structures)
    pm = PassManager()
    go = pm.run(g, ctx)
    print(pm.summary(ctx))

    # kernel backend on real TPUs; jnp reference elsewhere (interpret-mode
    # Pallas on CPU would measure Python, not the model)
    on_tpu = jax.default_backend() == "tpu"
    backend = "kernel" if on_tpu else "reference"
    c_in = 1 if args.graph_app == "coloring" else 3
    shape = (args.batch, c_in, args.size, args.size)
    rng = np.random.default_rng(args.seed)

    if args.quantize:
        # calibrate on the fp32 reference plan, run the quantize pass, and
        # serve the INT8 plan (the quant backend executes qlinear through the
        # INT8 Pallas kernels; on CPU the jnp dequant reference serves)
        from ..quant import calibrate_plan

        plan_f32 = compile_plan(go, backend="reference")
        batches = [
            jnp.asarray(rng.standard_normal(shape), jnp.float32)
            for _ in range(args.calib_batches)
        ]
        table = calibrate_plan(plan_f32, go.params, batches)
        qctx = PassContext(
            calibration=table, quant_skip=APP_QUANT_SKIP[args.graph_app],
            act_quant_skip=APP_ACT_SKIP[args.graph_app],
        )
        gq = PassManager(("quantize",)).run(go, qctx)
        backend = "quant" if on_tpu else "reference"
        plan = compile_plan(gq, backend=backend)
        # plan-level parity + storage stats vs the fp32 reference plan
        probe = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        err = jnp.max(jnp.abs(jnp.asarray(plan(gq.params, probe))
                              - jnp.asarray(plan_f32(go.params, probe))))
        mem_f = plan_f32.memory_estimate(jax.ShapeDtypeStruct(shape, jnp.float32))
        mem_q = plan.memory_estimate(jax.ShapeDtypeStruct(shape, jnp.float32))
        print(
            f"quantize: calibrated {table.batches} batches over "
            f"{len(table.ranges)} values; max_abs_err={float(err):.2e} "
            f"weights {mem_f['param_bytes'] / 1e6:.2f}MB -> "
            f"{mem_q['param_bytes'] / 1e6:.2f}MB "
            f"({mem_f['param_bytes'] / mem_q['param_bytes']:.2f}x, "
            f"{mem_q['weight_bytes_saved'] / 1e6:.2f}MB saved)"
        )
        go = gq
    else:
        plan = compile_plan(go, backend=backend)

    mem = plan.memory_estimate(jax.ShapeDtypeStruct(shape, jnp.float32))
    print(
        f"plan: backend={backend} steps={len(plan.steps)} "
        f"peak_act={mem['peak_activation_bytes'] / 1e6:.2f}MB "
        f"params={mem['param_bytes'] / 1e6:.2f}MB"
    )

    if args.batch_size is not None:
        # throughput mode: a queue of single frames served in fixed-size
        # compiled batches (tail batch padded, never re-compiled)
        from ..serving.engine import PlanServer

        server = PlanServer(plan, go.params, args.batch_size)
        n_frames = args.frames * args.batch
        # warm the chunk compilation before timing
        server.submit(jnp.zeros((c_in, args.size, args.size), jnp.float32))
        jax.block_until_ready(server.flush())
        server.stats = {k: 0 for k in server.stats}
        for _ in range(n_frames):
            server.submit(
                jnp.asarray(
                    rng.standard_normal((c_in, args.size, args.size)), jnp.float32
                )
            )
        t0 = time.time()
        jax.block_until_ready(server.flush())
        dt = time.time() - t0
        s = server.stats
        print(
            f"{args.graph_app}: {s['frames']} frames in {dt:.3f}s "
            f"({s['frames'] / dt:.1f} frames/s) over {s['batches']} batches "
            f"of {args.batch_size} ({s['padded_frames']} padded)"
        )
        return

    f = jax.jit(plan)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    jax.block_until_ready(f(go.params, x))  # compile
    times = []
    for _ in range(args.frames):
        t0 = time.time()
        jax.block_until_ready(f(go.params, x))
        times.append(time.time() - t0)
    ms = float(np.median(times)) * 1e3
    print(f"{args.graph_app}: {ms:.2f} ms/frame over {args.frames} frames "
          f"({shape[0]}x{shape[2]}x{shape[3]}, sparsity {args.sparsity})")


def _parse_tenants(spec: str):
    """Parse ``--tenants`` specs: comma-separated
    ``name[:weight[:rate[:burst]]]`` (weight = fair share of batch slots,
    rate/burst = token-bucket quota in requests/s)."""
    out = []
    for part in spec.split(","):
        bits = [b.strip() for b in part.strip().split(":")]
        if not bits or not bits[0]:
            raise SystemExit(f"--tenants: empty tenant name in {spec!r}")
        out.append((
            bits[0],
            float(bits[1]) if len(bits) > 1 else 1.0,
            float(bits[2]) if len(bits) > 2 else None,
            float(bits[3]) if len(bits) > 3 else None,
        ))
    return out


def _serve_async(args) -> None:
    """One AsyncPlanServer process hosting every demo app (or just
    ``--graph-app``): compile each app's plan, start the tick-driven
    scheduler thread, drive mixed traffic with per-request deadlines, and
    report throughput, p50/p95 latency, deadline-miss and padding stats --
    with a per-app parity probe vs direct plan execution.  With
    ``--tenants`` the traffic is spread round-robin over the registered
    tenants (weighted fair share + quotas) and the report breaks latency,
    throttling, and ladder state out per tenant."""
    from ..core.graph import PassContext, PassManager, compile_plan
    from ..models.cnn import APPS, app_masks
    from ..serving import AsyncPlanServer, submit_with_retry

    if args.quantize:
        raise SystemExit(
            "--async serves f32 plans only (for INT8 serving use "
            "--graph-app <app> --quantize); refusing to silently ignore "
            "--quantize"
        )
    apps = [args.graph_app] if args.graph_app else list(APPS)
    on_tpu = jax.default_backend() == "tpu"
    # --guarded serves degradation-tolerant plans: each step tries the
    # kernel/quant handler and demotes failures to the jnp reference (with
    # circuit breakers + numeric guards); stats land in server.health()
    backend = "guarded" if args.guarded else ("kernel" if on_tpu else "reference")
    batch_size = args.batch_size or 4
    rng = np.random.default_rng(args.seed)

    server = AsyncPlanServer(
        flush_after=args.flush_after, max_queue=args.max_queue,
        overload=args.overload, watchdog=args.watchdog,
    )
    tenant_specs = _parse_tenants(args.tenants) if args.tenants else []
    tnames = [t[0] for t in tenant_specs]
    for name, weight, rate, burst in tenant_specs:
        server.add_tenant(name, weight=weight, rate=rate, burst=burst)
        quota = f"{rate}/s" if rate is not None else "unlimited"
        print(f"async: tenant {name}: weight={weight} quota={quota}")
    plans, shapes = {}, {}
    for app in apps:
        g = APPS[app](jax.random.PRNGKey(args.seed), base=args.base)
        masks, structures = app_masks(g, app, sparsity=args.sparsity)
        go = PassManager().run(g, PassContext(masks=masks, structures=structures))
        plan = compile_plan(go, backend=backend)
        plans[app] = (plan, go.params)
        c_in = 1 if app == "coloring" else 3
        shapes[app] = (c_in, args.size, args.size)
        # explicit input spec: a malformed frame fails at submit(), never
        # inside the macro-batch it would have joined
        server.add_plan(
            app, plan, go.params, batch_size,
            input_spec=[(shapes[app], jnp.float32)],
        )
        print(f"async: {app}: backend={backend} steps={len(plan.steps)} "
              f"batch_size={batch_size}")

    with server:
        server.start()
        # warm each app's chunk compilation before timing; snapshot the
        # counters after it so the report covers the traffic window only
        for app in apps:
            server.submit(app, jnp.zeros(shapes[app], jnp.float32)).result()
        warm = server.stats
        n = args.frames * args.batch
        handles, probes = [], {}
        t0 = time.time()
        for i in range(n):
            app = apps[i % len(apps)]
            x = jnp.asarray(rng.standard_normal(shapes[app]), jnp.float32)
            tenant = tnames[i % len(tnames)] if tnames else None
            # with quotas in play, ride out QuotaExceededError via the
            # shared jittered backoff instead of failing the demo
            h = submit_with_retry(
                server, app, x, priority=i % 2, deadline=args.deadline,
                tenant=tenant,
            )
            handles.append(h)
            probes.setdefault(app, (x, h))  # first frame per app: parity probe
        for h in handles:
            h.result()
        dt = time.time() - t0
        for app, (x, h) in probes.items():
            plan, params = plans[app]
            err = float(jnp.max(jnp.abs(jnp.asarray(h.result())
                                        - jnp.asarray(plan(params, x[None]))[0])))
            assert err <= 1e-5, (app, err)  # async path == direct execution
        s = server.stats
        print(f"async: {len(handles)} requests over {len(apps)} plans in "
              f"{dt:.3f}s ({len(handles) / dt:.1f} req/s), "
              f"{s['batches'] - warm['batches']} batches "
              f"({s['padded_frames'] - warm['padded_frames']} padded frames, "
              f"{s['deadline_flushes'] - warm['deadline_flushes']} deadline "
              f"flushes, {s['deadline_misses'] - warm['deadline_misses']} "
              f"deadline misses, parity ok)")
        for app in apps:
            # percentiles over the traffic handles only: the per-plan
            # reservoirs also hold the warmup request, whose latency is the
            # jit compile, not serving
            lats = np.asarray([h.latency for h in handles if h.plan == app])
            if not lats.size:  # fewer requests than apps: no traffic here
                print(f"async: {app}: no traffic")
                continue
            print(f"async: {app}: p50={np.percentile(lats, 50) * 1e3:.2f}ms "
                  f"p95={np.percentile(lats, 95) * 1e3:.2f}ms "
                  f"p99={np.percentile(lats, 99) * 1e3:.2f}ms "
                  f"over {lats.size} requests")
        if tnames:
            per_tenant = s["per_tenant"]
            for name in tnames:
                lats = np.asarray(
                    [h.latency for h in handles if h.tenant == name]
                )
                st = per_tenant[name]
                if lats.size:
                    pct = (f"p50={np.percentile(lats, 50) * 1e3:.2f}ms "
                           f"p95={np.percentile(lats, 95) * 1e3:.2f}ms "
                           f"p99={np.percentile(lats, 99) * 1e3:.2f}ms "
                           f"over {lats.size} requests, ")
                else:
                    pct = "no traffic, "
                print(f"async: tenant {name}: {pct}"
                      f"throttled={st['throttled']} "
                      f"ladder_shed={st['ladder_shed']} "
                      f"deadline_misses={st['deadline_misses']}")
        # liveness/degradation snapshot: what an external monitor scrapes
        health = server.health()
        print(f"health: running={health['running']} "
              f"inflight={health['inflight']} pending={health['pending']} "
              f"tick_errors={health['tick_errors']} "
              f"watchdog={health['watchdog']}")
        for app, p in health["plans"].items():
            s = p["stats"]
            line = (f"health: {app}: queue_depth={p['queue_depth']} "
                    f"queue_peak={p['queue_peak']} "
                    f"bad_frames={s['bad_frames']} "
                    f"watchdog_timeouts={s['watchdog_timeouts']} "
                    f"rejected={s['rejected']} shed={s['shed']}")
            if "guard" in p:
                gc = p["guard"]["counters"]
                brs = ", ".join(
                    f"{k}={b['state']}" for k, b in p["guard"]["breakers"].items()
                )
                line += (f" | guard: primary_ok={gc['primary_ok']} "
                         f"fallbacks={gc['fallbacks']} "
                         f"breakers=[{brs or 'none yet'}]")
            print(line)
        for name in tnames:
            th = health["tenants"][name]
            print(f"health: tenant {name}: level={th['level_name']} "
                  f"weight={th['weight']} tokens={th['tokens']}")


def _serve_llm(args) -> None:
    """Serve an autoregressive decoder through the plan compiler: lower the
    model to prefill/decode graphs (``build_decoder_graph``), run the
    PassManager pipeline, compile both plans, and stream prompts through
    :meth:`AsyncPlanServer.submit_llm` -- token-level continuous batching
    over a paged KV-cache, with a greedy-parity probe vs the plain jnp
    forward loop."""
    from ..core.graph import compile_plan
    from ..core.graph.passes import optimize
    from ..models.transformer import forward, init_lm
    from ..models.transformer_graph import build_decoder_graph, decoder_cache_spec
    from ..serving import AsyncPlanServer, PagedKVCache

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    on_tpu = jax.default_backend() == "tpu"
    backend = "guarded" if args.guarded else ("kernel" if on_tpu else "reference")
    interpret = backend != "reference" and not on_tpu

    go_pre = optimize(build_decoder_graph(params, cfg, phase="prefill"))
    go_dec = optimize(build_decoder_graph(params, cfg, phase="decode"))
    plan_pre = compile_plan(go_pre, backend=backend, interpret=interpret)
    plan_dec = compile_plan(go_dec, backend=backend, interpret=interpret)
    print(f"llm: {args.arch}{' (smoke)' if args.smoke else ''}: "
          f"backend={backend} prefill_steps={len(plan_pre.steps)} "
          f"decode_steps={len(plan_dec.steps)}")

    cache = PagedKVCache(
        num_pages=args.kv_pages, page_size=args.kv_page_size,
        **decoder_cache_spec(cfg),
    )
    rng = np.random.default_rng(args.seed)
    n_seq = max(1, args.frames)
    prompts = [
        rng.integers(0, cfg.vocab, size=int(rng.integers(4, args.prompt_len + 1)))
        .astype(np.int32)
        for _ in range(n_seq)
    ]

    server = AsyncPlanServer(max_queue=args.max_queue)
    server.add_llm(
        "lm", prefill=plan_pre, decode=plan_dec, cache=cache,
        max_batch=args.batch,
    )
    with server:
        server.start()
        t0 = time.time()
        handles = [
            server.submit_llm("lm", p, max_new_tokens=args.new_tokens)
            for p in prompts
        ]
        for h in handles:
            h.result()
        dt = time.time() - t0
    st = server.stats["per_llm"]["lm"]
    toks = sum(len(h.result()) for h in handles)
    print(f"llm: {len(handles)} sequences, {toks} tokens in {dt:.3f}s "
          f"({toks / dt:.1f} tok/s) -- {st['prefill_batches']} prefill + "
          f"{st['decode_batches']} decode batches, "
          f"{st['decode_tokens']} batched decode tokens, "
          f"failed={st['failed']}")
    occ = cache.occupancy()
    print(f"llm: cache {occ['num_pages']}x{occ['page_size']} pages: "
          f"peak_used={occ['peak_used']} leaked={occ['used_pages']}")
    cache.check_invariants()

    # greedy-parity probe: the served tokens == a plain jnp forward loop
    seq = list(int(t) for t in prompts[0])
    for _ in range(args.new_tokens):
        logits, _ = forward(params, cfg, jnp.asarray([seq], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        seq.append(nxt)
    want = seq[len(prompts[0]):]
    got = [int(t) for t in handles[0].result()]
    assert got == want, (got, want)
    print(f"llm: greedy parity ok ({len(got)} tokens match the jnp loop)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--scheduler", action="store_true", help="continuous batching demo")
    ap.add_argument("--seed", type=int, default=0)
    # decoder-plan serving: prefill/decode graphs + paged KV continuous batching
    ap.add_argument("--llm", action="store_true",
                    help="serve --arch through the plan compiler: decoder "
                         "graphs (prefill + decode) with a paged KV-cache "
                         "and token-level continuous batching "
                         "(AsyncPlanServer.submit_llm)")
    ap.add_argument("--kv-pages", type=int, default=64,
                    help="llm: total pages in the paged KV-cache pool")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="llm: tokens per KV-cache page")
    # plan-based vision-app serving (the paper's three demos)
    ap.add_argument("--graph-app",
                    choices=["style_transfer", "coloring", "super_resolution"],
                    default=None, help="serve a demo app through an execution plan")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--size", type=int, default=64, help="graph-app frame size")
    ap.add_argument("--base", type=int, default=16, help="graph-app channel width")
    ap.add_argument("--frames", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="graph-app throughput mode: serve frames*batch single "
                         "frames through plan.batched(batch_size) (PlanServer)")
    ap.add_argument("--async", dest="async_serve", action="store_true",
                    help="continuous-batching mode: one AsyncPlanServer hosts "
                         "every demo app (or just --graph-app), a background "
                         "scheduler forms macro-batches from the admission "
                         "queues, per-request latency + deadline stats")
    ap.add_argument("--flush-after", type=float, default=0.02,
                    help="async: partial-batch release deadline (seconds the "
                         "oldest queued request may wait for batch fill)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="async: per-request latency budget in seconds "
                         "(late completions count as deadline misses)")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="async: bounded admission queue per plan")
    ap.add_argument("--overload", choices=["reject", "shed"], default="reject",
                    help="async: backpressure policy when a queue is full")
    ap.add_argument("--tenants", nargs="?", default=None,
                    const="gold:3:200,free:1:50",
                    help="async: serve traffic as multiple tenants -- comma-"
                         "separated name[:weight[:rate[:burst]]] specs "
                         "(weight = fair share of batch slots, rate/burst = "
                         "token-bucket quota in req/s); bare --tenants uses "
                         "a demo 3:1 gold/free split with quotas; the report "
                         "adds per-tenant latency/throttle/ladder lines")
    ap.add_argument("--guarded", action="store_true",
                    help="async: serve guarded plans (per-step kernel ->"
                         " reference demotion with circuit breakers and"
                         " NaN/Inf guards; guard stats in health())")
    ap.add_argument("--watchdog", type=float, default=None,
                    help="async: per-batch execution deadline in seconds; a "
                         "batch that blows it fails only its own handles "
                         "(WatchdogTimeout) and the scheduler keeps ticking")
    ap.add_argument("--quantize", action="store_true",
                    help="graph-app: calibrate + quantize the plan to INT8 "
                         "weights (backend='quant' on TPU) and report parity "
                         "vs the fp32 reference plan")
    ap.add_argument("--calib-batches", type=int, default=2,
                    help="sample batches for activation calibration")
    ap.add_argument("--metrics-dump", default=None,
                    help="write periodic metrics-registry snapshots to this "
                         "JSON path and the session's Chrome trace to "
                         "<path>.trace.json (tracing is armed for the run)")
    ap.add_argument("--metrics-interval", type=float, default=0.5,
                    help="seconds between --metrics-dump registry snapshots")
    args = ap.parse_args()

    if args.metrics_dump and (args.async_serve or args.graph_app or args.llm):
        with _MetricsDump(args.metrics_dump, args.metrics_interval):
            if args.async_serve:
                _serve_async(args)
            elif args.llm:
                _serve_llm(args)
            else:
                _serve_graph_app(args)
        return
    if args.async_serve:
        _serve_async(args)
        return
    if args.llm:
        _serve_llm(args)
        return
    if args.graph_app:
        _serve_graph_app(args)
        return

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encdec:
        raise SystemExit("whisper-family serving demo: see examples/serve_pruned_lm.py")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, batch_size=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.time()
    result = engine.generate(prompts, args.new_tokens)
    dt = time.time() - t0
    print(f"generated {result.tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first row:", result.tokens[0].tolist())

    if args.scheduler:
        sched = RequestScheduler(engine)
        for rid in range(args.batch * 2):  # 2x oversubscribed queue
            plen = int(rng.integers(4, args.prompt_len))
            sched.submit(Request(rid=rid, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                                 max_new=int(rng.integers(3, args.new_tokens))))
        done = sched.run()
        print(f"scheduler: completed {sum(r.done for r in done)} requests "
              f"(continuous batching over {args.batch} slots)")


if __name__ == "__main__":
    main()
