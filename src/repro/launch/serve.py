"""Serving launcher: batched generation + continuous-batching demo.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 12
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, smoke_config
from ..models import get_model
from ..serving.engine import Engine, Request, RequestScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--scheduler", action="store_true", help="continuous batching demo")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encdec:
        raise SystemExit("whisper-family serving demo: see examples/serve_pruned_lm.py")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, batch_size=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.time()
    result = engine.generate(prompts, args.new_tokens)
    dt = time.time() - t0
    print(f"generated {result.tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first row:", result.tokens[0].tolist())

    if args.scheduler:
        sched = RequestScheduler(engine)
        for rid in range(args.batch * 2):  # 2x oversubscribed queue
            plen = int(rng.integers(4, args.prompt_len))
            sched.submit(Request(rid=rid, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                                 max_new=int(rng.integers(3, args.new_tokens))))
        done = sched.run()
        print(f"scheduler: completed {sum(r.done for r in done)} requests "
              f"(continuous batching over {args.batch} slots)")


if __name__ == "__main__":
    main()
