"""Production mesh builders (DESIGN.md section 5).

Defined as FUNCTIONS so importing this module never touches jax device
state; callers (dryrun.py) must set XLA_FLAGS before first jax init.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "HW"]


class HW:
    """TPU v5e-class hardware constants (roofline denominators)."""

    PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
    HBM_BW = 819e9  # bytes/s per chip
    ICI_BW = 50e9  # bytes/s per link (conservative single-link figure)
    HBM_BYTES = 16 * 1024**3  # 16 GiB per chip
    CHIPS_PER_POD = 256


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh helper for tests/perf variants."""
    return jax.make_mesh(shape, axes)
