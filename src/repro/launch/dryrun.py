import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell against the production mesh using
ShapeDtypeStruct inputs -- no allocation, real SPMD partitioning.

Lowering strategy (DESIGN.md section 8):

* the FULL model compiles in scan-mode (repeated layer pattern as one
  ``lax.scan``): proves sharding coherence and gives the realistic
  per-device memory picture (while-loop bodies reuse buffers);
* XLA's cost analysis counts a while body ONCE, so HLO FLOPs / bytes /
  collective bytes are reconstructed exactly from two small *unrolled
  probes* (1 and 2 pattern-units): ``total = f(1) + (units-1) * (f(2)-f(1))``
  -- per-layer deltas include real fusion effects.  The probe pair and the
  extrapolation are recorded per cell.

Per cell -> results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis(), corrected cost, per-kind collective bytes, analytic
MODEL_FLOPS, parameter counts; consumed by launch/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--force]
"""

import argparse
import dataclasses
import gc
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config, shape_cells
from ..models import get_model
from ..models import transformer as lm
from ..models.sharding import FSDP_RULES, batch_spec, param_pspecs
from ..training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update, zero1_pspecs
from ..utils.flops import model_flops, param_counts
from ..utils.hlo import collective_bytes
from .mesh import HW, make_production_mesh

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
)


# --------------------------------------------------------------------------- #
# sharding for inputs & caches                                                 #
# --------------------------------------------------------------------------- #


def _cache_pspecs(cache_tree: Any, bspec: P) -> Any:
    """Decode-cache shardings: batch over the data axes, the long axis
    (sequence / heads) over ``model`` -- flash-decoding-style split-K."""
    batch_axes = bspec[0] if len(bspec) else None

    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        if nd <= 1:
            return P(batch_axes) if nd == 1 else P()
        if name.endswith("['conv']"):  # [B, w-1, C]
            return P(batch_axes, None, "model")
        if nd >= 3:  # k/v/c_kv/k_rope/state: [B, S|H, ...]
            return P(batch_axes, "model", *([None] * (nd - 2)))
        return P(batch_axes, "model")  # rec h: [B, W]

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])


def _batch_pspecs(batch_tree: Any, bspec: P) -> Any:
    return jax.tree.map(
        lambda leaf: P(
            bspec[0] if len(bspec) else None, *([None] * (len(leaf.shape) - 1))
        ),
        batch_tree,
    )


# --------------------------------------------------------------------------- #
# lower+compile one configuration                                              #
# --------------------------------------------------------------------------- #


def _data_parallel_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def _maybe_replicate_batch(specs, tree, mesh):
    """Drop any spec axis whose mesh extent does not divide the dim
    (long_500k has global_batch=1 -> TP-only decode; whisper's cross-KV has
    T_enc=1500 which 16 does not divide -> replicated sequence)."""

    def axis_size(entry) -> int:
        n = 1
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            if a is not None:
                n *= mesh.shape[a]
        return n

    def fix(leaf, spec):
        if not len(spec):
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = []
        for dim, entry in enumerate(parts):
            if entry is not None and leaf.shape[dim] % axis_size(entry) != 0:
                out.append(None)
            else:
                out.append(entry)
        return P(*out)

    return jax.tree.map(fix, tree, specs)


def _build(cfg, shape_name: str, mesh, *, zero1: bool, remat: bool, scan: bool,
           overrides=None):
    """Returns (fn, args, in_shardings, step_name).

    ``overrides`` (perf-iteration hooks, benchmarks/perf_iterations.py):
      rules: 'default'|'fsdp'|explicit rules list
      residual_spec: PartitionSpec constraint on the residual stream
      remat_policy: 'full'|'dots'
    """
    overrides = overrides or {}
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # FSDP rules whenever TP-only weight shards would exceed ~1/4 of HBM
    from ..utils.flops import param_counts as _pc

    per_chip_tp = _pc(cfg, params_shapes)["total"] * 2 / mesh.shape["model"]
    rules = FSDP_RULES if per_chip_tp > HW.HBM_BYTES / 4 else None
    ro = overrides.get("rules")
    if ro is not None:
        if isinstance(ro, str):
            rules = {"default": None, "fsdp": FSDP_RULES}[ro]
        else:
            rules = ro
    p_specs = param_pspecs(params_shapes, rules)
    bspec = batch_spec(mesh)
    step_name, batch_specs, cache_specs = model.input_specs(shape)

    def shard(specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    if step_name == "train_step":
        opt_cfg = AdamWConfig()
        opt_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_shapes)
        mv = (zero1_pspecs(p_specs, params_shapes, data_size=mesh.shape["data"])
              if zero1 else p_specs)
        opt_specs = AdamWState(step=P(), m=mv, v=mv)

        if cfg.is_encdec:
            from ..models import encdec as encdec_mod

            def loss(p, b):
                return encdec_mod.loss_fn(p, cfg, b, remat=remat, layout_scan=scan)
        else:
            def loss(p, b):
                return lm.loss_fn(
                    p, cfg, b, remat=remat, layout_scan=scan,
                    remat_policy=overrides.get("remat_policy", "full"),
                    residual_spec=overrides.get("residual_spec"),
                )

        def fn(params, opt, batch):
            # allow_int: packed sparse params carry int32 indices (kept /
            # block_rows); their float0 cotangents are skipped by adamw_update
            (l, _), grads = jax.value_and_grad(loss, has_aux=True, allow_int=True)(
                params, batch
            )
            new_params, new_opt, _ = adamw_update(grads, opt, params, opt_cfg)
            return new_params, new_opt, l

        b_specs = _maybe_replicate_batch(
            _batch_pspecs(batch_specs, bspec), batch_specs, mesh
        )
        return (
            fn,
            (params_shapes, opt_shapes, batch_specs),
            (shard(p_specs), shard(opt_specs), shard(b_specs)),
            step_name,
        )
    if step_name == "prefill":
        if cfg.is_encdec:
            fn = lambda p, b: model.forward(p, b)
        else:
            def fn(p, b):
                return lm.forward(
                    p, cfg, b["tokens"], patch_embeds=b.get("patch_embeds"),
                    layout_scan=scan,
                    residual_spec=overrides.get("residual_spec"),
                    attn_chunk=overrides.get("attn_chunk", 1024),
                )[0]
        b_specs = _maybe_replicate_batch(
            _batch_pspecs(batch_specs, bspec), batch_specs, mesh
        )
        return (
            fn,
            (params_shapes, batch_specs),
            (shard(p_specs), shard(b_specs)),
            step_name,
        )
    # serve_step (decode): layer loop is cheap to compile; always unrolled
    def fn(p, b, caches):
        return model.decode_step(p, b, caches)

    b_specs = _maybe_replicate_batch(
        _batch_pspecs(batch_specs, bspec), batch_specs, mesh
    )
    c_specs = _maybe_replicate_batch(
        _cache_pspecs(cache_specs, bspec), cache_specs, mesh
    )
    return (
        fn,
        (params_shapes, batch_specs, cache_specs),
        (shard(p_specs), shard(b_specs), shard(c_specs)),
        step_name,
    )


def _compile_once(cfg, shape_name, mesh, *, zero1, remat, scan, overrides=None):
    fn, args, in_sh, step_name = _build(
        cfg, shape_name, mesh, zero1=zero1, remat=remat, scan=scan,
        overrides=overrides,
    )
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    from ..utils.jax_compat import cost_analysis

    ca = cost_analysis(compiled)
    total, per_kind = collective_bytes(compiled.as_text())
    out = {
        "step": step_name,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "cost": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": {"total_bytes": int(total), "per_kind": per_kind},
    }
    live = mem.argument_size_in_bytes + mem.temp_size_in_bytes - mem.alias_size_in_bytes
    out["memory"]["live_bytes"] = int(live)
    out["memory"]["fits_hbm"] = bool(live < HW.HBM_BYTES)
    del compiled, lowered
    gc.collect()
    return out


def _probe_cfg(cfg, n_units: int):
    """Config with prefix + n_units pattern-units of layers (unrolled probes)."""
    prefix, unit, _, _ = lm.scan_plan(cfg)
    n_layers = len(prefix) + n_units * unit
    kw = {"n_layers": n_layers}
    if cfg.is_encdec:
        kw["encoder_layers"] = n_units  # probe enc+dec pairs together
    return dataclasses.replace(cfg, **kw), unit, len(prefix)


# --------------------------------------------------------------------------- #
# one cell                                                                     #
# --------------------------------------------------------------------------- #


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    zero1: bool = True,
    remat: bool = True,
    probes: bool = True,
    verbose: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
    cfg_override=None,
) -> Dict[str, Any]:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    status = shape_cells(arch)[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": status,
    }
    if status != "run":
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["chips"] = mesh.size
    model = get_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    counts = param_counts(cfg, params_shapes)
    shape = SHAPES[shape_name]
    rec.update(
        params_total=counts["total"],
        params_active=counts["active"],
        model_flops=model_flops(cfg, shape, counts),
    )

    try:
        # 1) full model, scan-mode: compile proof + memory picture
        full = _compile_once(
            cfg, shape_name, mesh, zero1=zero1, remat=remat, scan=True,
            overrides=overrides,
        )
        rec.update(full)
        rec["ok"] = True

        # 2) probes (unrolled): exact per-unit cost extrapolation
        if probes:
            prefix, unit, n_units, suffix = lm.scan_plan(cfg)
            if cfg.is_encdec:
                n_total_units, rem_layers = cfg.n_layers, 0
            else:
                n_total_units, rem_layers = n_units, len(suffix)
            cfg1, _, _ = _probe_cfg(cfg, 1)
            cfg2, _, _ = _probe_cfg(cfg, 2)
            p1 = _compile_once(cfg1, shape_name, mesh, zero1=zero1, remat=remat,
                               scan=False, overrides=overrides)
            p2 = _compile_once(cfg2, shape_name, mesh, zero1=zero1, remat=remat,
                               scan=False, overrides=overrides)

            def extra(field, sub=None):
                a = p1[field][sub] if sub else p1[field]
                b = p2[field][sub] if sub else p2[field]
                d = b - a
                scale = (n_total_units - 1) + rem_layers / unit
                return a + d * scale, d

            flops, flops_per_unit = extra("cost", "flops")
            bytes_, bytes_per_unit = extra("cost", "bytes_accessed")
            coll, coll_per_unit = extra("collectives", "total_bytes")
            per_kind = {}
            for k in set(p1["collectives"]["per_kind"]) | set(p2["collectives"]["per_kind"]):
                a = p1["collectives"]["per_kind"].get(k, 0)
                b = p2["collectives"]["per_kind"].get(k, 0)
                per_kind[k] = int(a + (b - a) * ((n_total_units - 1) + rem_layers / unit))
            rec["cost_corrected"] = {
                "flops": float(flops),
                "bytes_accessed": float(bytes_),
                "per_unit_flops": float(flops_per_unit),
                "probe_compile_s": [p1["compile_s"], p2["compile_s"]],
            }
            rec["collectives_corrected"] = {
                "total_bytes": float(coll),
                "per_kind": per_kind,
            }
    except Exception as e:  # noqa: BLE001 -- recorded, cell marked failed
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    gc.collect()
    if verbose:
        if rec.get("ok"):
            cc = rec.get("cost_corrected", rec.get("cost", {}))
            co = rec.get("collectives_corrected", rec.get("collectives", {}))
            print(
                f"[ok] {arch:22s} {shape_name:12s} {mesh_kind:6s} "
                f"compile={rec['compile_s']:6.1f}s flops/dev={cc.get('flops', 0):.3e} "
                f"coll/dev={co.get('total_bytes', 0):.3e}B "
                f"live={rec['memory']['live_bytes'] / 2**30:.2f}GiB",
                flush=True,
            )
        else:
            print(f"[FAIL] {arch} {shape_name} {mesh_kind}: {rec.get('error')}", flush=True)
    return rec


# --------------------------------------------------------------------------- #
# driver                                                                       #
# --------------------------------------------------------------------------- #


def cell_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--seqpar", action="store_true",
                    help="sequence-parallel residual stream (the section-Perf winner)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(args.out, arch, shape, mesh_kind)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        rec = json.load(f)
                    print(f"[cached] {arch} {shape} {mesh_kind} ok={rec.get('ok')}")
                else:
                    overrides = None
                    if args.seqpar:
                        overrides = {"residual_spec": P(
                            ("pod", "data") if mesh_kind == "multi" else "data",
                            "model", None)}
                    rec = run_cell(
                        arch, shape, mesh_kind,
                        zero1=not args.no_zero1, remat=not args.no_remat,
                        probes=(not args.no_probes) and mesh_kind == "single",
                        overrides=overrides,
                    )
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                if rec["status"] != "run":
                    n_skip += 1
                elif rec.get("ok"):
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"\ndry-run matrix: ok={n_ok} fail={n_fail} skip={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
