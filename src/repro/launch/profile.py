"""Plan profiler launcher: where does the millisecond go, per step.

Compiles one of the paper's demo apps through the full pipeline (masks ->
PassManager -> execution plan, optionally calibrated + quantized to INT8),
runs it under tracing via :func:`repro.obs.profile.profile_plan`, and
prints the per-step cost table -- wall ms, share of total, estimated bytes
moved, kernel-vs-reference attribution.

Examples (CPU)::

  PYTHONPATH=src python -m repro.launch.profile --graph-app style_transfer \
      --trace-out trace.json             # Chrome-trace JSON for Perfetto
  PYTHONPATH=src python -m repro.launch.profile --graph-app coloring \
      --quantize --runs 5 --json-out profile.json
  PYTHONPATH=src python -m repro.launch.profile --graph-app super_resolution \
      --backend guarded --top 10

Load ``--trace-out`` files at https://ui.perfetto.dev (or
``chrome://tracing``): one ``cat="plan"`` span per run, one ``cat="step"``
span per plan step nested under it.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def build_app_plan(args):
    """The shared demo-app build path (same pipeline as launch/serve.py):
    returns ``(plan, params, input_shape)`` for ``args.graph_app``."""
    from ..core.graph import PassContext, PassManager, compile_plan
    from ..models.cnn import APP_ACT_SKIP, APP_QUANT_SKIP, APPS, app_masks

    g = APPS[args.graph_app](jax.random.PRNGKey(args.seed), base=args.base)
    masks, structures = app_masks(g, args.graph_app, sparsity=args.sparsity)
    go = PassManager().run(g, PassContext(masks=masks, structures=structures))

    on_tpu = jax.default_backend() == "tpu"
    backend = args.backend or ("kernel" if on_tpu else "reference")
    c_in = 1 if args.graph_app == "coloring" else 3
    shape = (args.batch, c_in, args.size, args.size)
    rng = np.random.default_rng(args.seed)

    if args.quantize:
        from ..quant import calibrate_plan

        plan_f32 = compile_plan(go, backend="reference")
        batches = [
            jnp.asarray(rng.standard_normal(shape), jnp.float32)
            for _ in range(args.calib_batches)
        ]
        table = calibrate_plan(plan_f32, go.params, batches)
        qctx = PassContext(
            calibration=table, quant_skip=APP_QUANT_SKIP[args.graph_app],
            act_quant_skip=APP_ACT_SKIP[args.graph_app],
        )
        go = PassManager(("quantize",)).run(go, qctx)
        if args.backend is None:
            backend = "quant" if on_tpu else "reference"
    plan = compile_plan(go, backend=backend)
    return plan, go.params, shape


def main() -> None:
    from ..obs import profile_plan

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--graph-app",
                    choices=["style_transfer", "coloring", "super_resolution"],
                    required=True, help="demo app to profile")
    ap.add_argument("--quantize", action="store_true",
                    help="calibrate + quantize the plan to INT8 first")
    ap.add_argument("--backend", default=None,
                    choices=["kernel", "reference", "quant", "guarded"],
                    help="override the auto backend (kernel on TPU, "
                         "reference elsewhere)")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--size", type=int, default=64, help="frame size")
    ap.add_argument("--base", type=int, default=16, help="channel width")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--runs", type=int, default=3,
                    help="traced executions; per-step ms is their median")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--top", type=int, default=None,
                    help="print only the N hottest steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--trace-out", default=None,
                    help="write the (last traced run's) Chrome-trace JSON "
                         "here -- loadable in Perfetto / chrome://tracing")
    ap.add_argument("--json-out", default=None,
                    help="write the per-step profile table as JSON here")
    args = ap.parse_args()

    plan, params, shape = build_app_plan(args)
    x = jnp.asarray(
        np.random.default_rng(args.seed).standard_normal(shape), jnp.float32
    )
    prof = profile_plan(plan, params, x, runs=args.runs, warmup=args.warmup)
    print(f"{args.graph_app}: {shape[0]}x{shape[2]}x{shape[3]} "
          f"sparsity={args.sparsity} quantize={args.quantize}")
    print(prof.render_text(top=args.top))
    mem = prof.memory
    print(f"memory: peak_act={mem['peak_activation_bytes'] / 1e6:.2f}MB "
          f"params={mem['param_bytes'] / 1e6:.2f}MB "
          f"saved={mem['weight_bytes_saved'] / 1e6:.2f}MB")
    if args.trace_out:
        print(f"trace: {prof.trace.save(args.trace_out)} "
              f"({len(prof.trace.events)} events; load in Perfetto)")
    if args.json_out:
        print(f"profile json: {prof.save_json(args.json_out)}")


if __name__ == "__main__":
    main()
