"""Quantized-tensor core: symmetric INT8 storage with f32 scales.

A :class:`QTensor` is the storage format produced by the ``quantize``
compiler pass (PatDNN/GRIM pair their pruned mobile runtimes with compressed
low-precision weight storage; this is our TPU-side equivalent): an int8
``values`` array plus a float32 ``scale`` -- a scalar for per-tensor
quantization, or a vector along ``axis`` for per-channel (one scale per
output channel, the scheme that keeps GEMM/conv accuracy at 8 bits).

Symmetric absmax quantization::

    scale  = absmax(x) / 127          (per tensor or per channel)
    q      = clip(round(x / scale), -127, 127)  as int8
    dequant(q) = q * scale

The value ``-128`` is never produced (symmetric range), so ``-q`` is always
representable and the format is negation-safe.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["QTensor", "quantize_array", "fake_quant", "QMAX"]

#: symmetric int8 range: [-127, 127] (never -128)
QMAX = 127.0

#: scales below this are clamped so all-zero channels dequantize to zeros
#: instead of NaNs
_EPS = 1e-12


def _absmax(x: jax.Array, axis: Optional[int]) -> jax.Array:
    """absmax over all dims (per-tensor) or all-but-``axis`` (per-channel)."""
    if axis is None:
        return jnp.max(jnp.abs(x))
    reduce_axes = tuple(i for i in range(x.ndim) if i != (axis % x.ndim))
    return jnp.max(jnp.abs(x), axis=reduce_axes)


def quantize_array(
    x: jax.Array, scale: jax.Array, axis: Optional[int] = None
) -> jax.Array:
    """``clip(round(x / scale), -127, 127)`` as int8; ``scale`` broadcasts
    along ``axis`` (or is a scalar)."""
    s = scale
    if axis is not None and jnp.ndim(scale) == 1:
        shape = [1] * x.ndim
        shape[axis % x.ndim] = -1
        s = scale.reshape(shape)
    q = jnp.round(x.astype(jnp.float32) / s)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def fake_quant(x: jax.Array, scale: jax.Array, axis: Optional[int] = None) -> jax.Array:
    """Quantize-then-dequantize in f32: the reference-side simulation of the
    kernel's int8 activation path (bit-compatible rounding/clipping)."""
    q = quantize_array(x, scale, axis)
    s = scale
    if axis is not None and jnp.ndim(scale) == 1:
        shape = [1] * x.ndim
        shape[axis % x.ndim] = -1
        s = scale.reshape(shape)
    return q.astype(jnp.float32) * s


@dataclasses.dataclass(frozen=True)
class QTensor:
    """Symmetric int8 tensor: ``dequantize() == values * scale``.

    ``axis=None`` -> per-tensor (``scale`` a scalar); ``axis=i`` ->
    per-channel along dim ``i`` (``scale`` a vector of ``shape[i]``).
    """

    values: jax.Array  # int8
    scale: jax.Array  # f32, () or [shape[axis]]
    axis: Optional[int] = None

    # -- construction -------------------------------------------------------- #
    @classmethod
    def from_float(cls, x: jax.Array, axis: Optional[int] = None) -> "QTensor":
        """Absmax-calibrated symmetric quantization of ``x``."""
        scale = jnp.maximum(_absmax(x, axis), _EPS).astype(jnp.float32) / QMAX
        return cls(values=quantize_array(x, scale, axis), scale=scale, axis=axis)

    # -- views --------------------------------------------------------------- #
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.values.shape)

    @property
    def nbytes(self) -> int:
        """Stored bytes: int8 payload + f32 scales."""
        return int(self.values.size) + int(np.size(self.scale)) * 4

    def compression_ratio(self, orig_dtype=jnp.float32) -> float:
        dense = int(self.values.size) * np.dtype(orig_dtype).itemsize
        return dense / max(self.nbytes, 1)

    def scale_broadcast(self) -> jax.Array:
        """``scale`` shaped to broadcast against ``values``."""
        if self.axis is None or jnp.ndim(self.scale) == 0:
            return self.scale
        shape = [1] * self.values.ndim
        shape[self.axis % self.values.ndim] = -1
        return self.scale.reshape(shape)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return (self.values.astype(jnp.float32) * self.scale_broadcast()).astype(dtype)

    def max_abs_error(self, x: jax.Array) -> float:
        """Worst-case reconstruction error against the original ``x``."""
        return float(jnp.max(jnp.abs(self.dequantize() - x.astype(jnp.float32))))
