"""Quantization subsystem: INT8 storage, calibration, and the compiler
entry points that rewrite GEMM/conv nodes to quantized ops.

* :mod:`repro.quant.qtensor` -- symmetric per-tensor/per-channel int8
  :class:`QTensor` with absmax quantize/dequantize helpers;
* :mod:`repro.quant.calibrate` -- :func:`calibrate_plan` runs sample batches
  through an ExecutionPlan and records per-value activation ranges
  (:class:`CalibrationTable`, JSON-persistable);
* the ``quantize`` pass lives in :mod:`repro.core.graph.passes` (it is a
  graph rewrite like every other pass); the INT8 Pallas kernels in
  :mod:`repro.kernels.quant_matmul`; the ``qlinear``/``qconv2d`` handlers and
  the ``backend="quant"`` selection mode in
  :mod:`repro.core.graph.executor`.
"""

from .calibrate import CalibrationTable, calibrate_plan
from .qtensor import QMAX, QTensor, fake_quant, quantize_array

__all__ = [
    "QTensor",
    "QMAX",
    "quantize_array",
    "fake_quant",
    "CalibrationTable",
    "calibrate_plan",
]
