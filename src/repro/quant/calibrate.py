"""Absmax activation calibration over execution plans.

The ``quantize`` pass needs one number per graph value to pick activation
scales for W8A8 GEMMs: the largest magnitude that value takes on
representative inputs.  :func:`calibrate_plan` runs sample batches through a
compiled :class:`~repro.core.graph.executor.ExecutionPlan` (reference backend
recommended -- pure jnp, runs anywhere) and records per-node absmax ranges
into a :class:`CalibrationTable`, which persists to JSON so calibration can
happen once offline and ship with the model.

Table keys are *graph value names*: the graph's input names plus every node
name (a node's name is the name of the value it produces).  A node's
activation scale is looked up under its **input** name -- the range of what
flows *into* the GEMM.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from .qtensor import QMAX

__all__ = ["CalibrationTable", "calibrate_plan"]


@dataclasses.dataclass
class CalibrationTable:
    """Per-value activation ranges: ``{value_name: absmax}`` (f32 floats).

    ``observe`` folds a new observation in via running max -- the table is
    monotone over batches, so calibration order never matters.
    """

    ranges: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: number of sample batches folded in (provenance, not used numerically)
    batches: int = 0

    def observe(self, name: str, value: Any) -> None:
        r = float(jnp.max(jnp.abs(jnp.asarray(value).astype(jnp.float32))))
        prev = self.ranges.get(name)
        self.ranges[name] = r if prev is None else max(prev, r)

    def __contains__(self, name: str) -> bool:
        return name in self.ranges

    def scale(self, name: str) -> float:
        """Symmetric int8 activation scale for value ``name``."""
        return max(self.ranges[name], 1e-12) / QMAX

    def get_scale(self, name: str) -> Optional[float]:
        return self.scale(name) if name in self.ranges else None

    # -- persistence --------------------------------------------------------- #
    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(
                {"version": 1, "batches": self.batches, "ranges": self.ranges},
                f,
                indent=2,
                sort_keys=True,
            )
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as f:
            payload = json.load(f)
        return cls(
            ranges={k: float(v) for k, v in payload["ranges"].items()},
            batches=int(payload.get("batches", 0)),
        )


def calibrate_plan(
    plan,
    params: Dict[str, Dict[str, Any]],
    batches: Iterable[Union[jax.Array, Tuple[jax.Array, ...], Sequence[jax.Array]]],
    table: Optional[CalibrationTable] = None,
) -> CalibrationTable:
    """Run ``batches`` through ``plan`` recording per-value absmax ranges.

    Each batch is one plan invocation's inputs: a single array for
    single-input graphs, or a tuple/list of arrays.  An existing ``table``
    may be passed to fold more batches into a previous calibration.
    """
    table = table or CalibrationTable()
    for xs in batches:
        if not isinstance(xs, (tuple, list)):
            xs = (xs,)
        plan.run_steps(params, *xs, observer=table.observe)
        table.batches += 1
    return table
