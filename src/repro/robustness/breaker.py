"""Per-key circuit breakers + the guarded-execution config.

The ``guarded`` executor backend (``core/graph/executor.py``) demotes a
failing kernel step to its jnp ``reference`` handler.  A breaker sits in
front of every demotable ``(op, scheme)`` family of a plan so that a
*persistently* failing kernel stops being retried request after request:

::

    closed --[>= threshold failures within window]--> open
    open   --[cooldown elapsed]--> half_open (one probe allowed)
    half_open --[probe succeeds]--> closed
    half_open --[probe fails]-----> open (cooldown restarts)

While ``open``, :meth:`CircuitBreaker.allow` returns ``False`` and the
executor short-circuits straight to the reference handler -- no kernel
attempt, no exception churn.  The clock is injectable so tests (and the
chaos suite) can drive the cooldown deterministically.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict

__all__ = ["BreakerOpen", "CircuitBreaker", "GuardConfig", "NumericGuardError"]


class NumericGuardError(RuntimeError):
    """Raised (and caught) by the guarded executor when a kernel step
    produced NaN/Inf output -- treated exactly like a kernel exception:
    the step demotes to reference and the breaker records a failure."""


class BreakerOpen(RuntimeError):
    """Raised by :meth:`CircuitBreaker.raise_if_open` for callers that
    want open-breaker short-circuits to be an exception, not a branch."""


class CircuitBreaker:
    """closed -> open -> half_open state machine over a failure window."""

    def __init__(
        self,
        threshold: int = 3,
        window: float = 30.0,
        cooldown: float = 5.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.window = window
        self.cooldown = cooldown
        self.clock = clock
        self.state = "closed"
        self.trips = 0  # closed/half_open -> open transitions
        self.opened_at: float | None = None
        self._failures: Deque[float] = deque()

    def allow(self) -> bool:
        """May the caller attempt the primary path right now?  An open
        breaker whose cooldown has elapsed moves to ``half_open`` and
        allows exactly the probe attempt(s) until a verdict lands."""
        if self.state == "closed" or self.state == "half_open":
            return True
        if self.clock() - self.opened_at >= self.cooldown:
            self.state = "half_open"
            return True
        return False

    def raise_if_open(self) -> None:
        if not self.allow():
            raise BreakerOpen(
                f"breaker open for {self.cooldown - (self.clock() - self.opened_at):.3f}s more"
            )

    def record_failure(self) -> None:
        now = self.clock()
        if self.state == "half_open":  # failed probe: back to open
            self.state = "open"
            self.opened_at = now
            self.trips += 1
            return
        self._failures.append(now)
        while self._failures and now - self._failures[0] > self.window:
            self._failures.popleft()
        if len(self._failures) >= self.threshold:
            self.state = "open"
            self.opened_at = now
            self.trips += 1
            self._failures.clear()

    def record_success(self) -> None:
        if self.state == "half_open":  # probe succeeded: recover
            self.state = "closed"
            self.opened_at = None
            self._failures.clear()

    def snapshot(self) -> Dict[str, Any]:
        return {
            "state": self.state,
            "trips": self.trips,
            "recent_failures": len(self._failures),
        }


@dataclasses.dataclass
class GuardConfig:
    """Knobs for the ``guarded`` executor backend.

    ``primary`` names the handler table tried first (``"quant"`` -- the
    kernel overlay including the INT8 handlers -- by default, so guarded
    plans execute both plain and quantized graphs); the fallback is always
    the ``reference`` table.  ``numeric_guards`` adds a post-step NaN/Inf
    check on concrete outputs (a poisoned output demotes like an
    exception).  The breaker fields configure one :class:`CircuitBreaker`
    per demotable ``(op, scheme)`` key of the plan."""

    primary: str = "quant"
    numeric_guards: bool = True
    breaker_threshold: int = 3
    breaker_window: float = 30.0
    breaker_cooldown: float = 5.0
    clock: Callable[[], float] = time.monotonic

    def make_breaker(self) -> CircuitBreaker:
        return CircuitBreaker(
            threshold=self.breaker_threshold,
            window=self.breaker_window,
            cooldown=self.breaker_cooldown,
            clock=self.clock,
        )
