"""Seeded, deterministic fault injection for chaos testing.

A :class:`FaultPlan` is a registry of :class:`FaultRule`\\ s -- *where* to
inject (an fnmatch pattern over fault **sites**), *what* to inject, and at
what per-call rate.  Sites come in two flavors:

* **kernel entry points** -- the public wrappers in ``kernels/ops.py``
  (``matmul`` / ``qmatmul`` / ``conv2d`` / ``fused_elementwise``).
  :meth:`FaultPlan.install` monkey-patches the module attributes, so every
  caller that resolves them at call time (the executor's kernel/quant
  handlers do) sees the faulty versions; :meth:`uninstall` restores the
  originals bit-for-bit.
* **op handler sites** -- node op names (``linear``, ``conv2d``,
  ``qlinear``, ...).  The ``guarded`` executor consults
  :func:`wrap_handler` before every primary attempt, so handler-site
  faults hit guarded plans regardless of when the plan was compiled.
  Reference handlers are never wrapped -- the fallback/oracle path stays
  clean by construction.

Fault kinds:

``raise``
    raise :class:`InjectedFault` *before* the real op runs (a crashing
    kernel).
``nan`` / ``inf``
    run the real op, then poison the output array (a numerically broken
    kernel -- what the guarded backend's post-step numeric guards catch).
``latency``
    sleep ``delay`` seconds, then run the real op (a hung compile /
    straggler step -- what the serving watchdog catches).
``cache_corrupt``
    one-shot at :meth:`install`: overwrite a ``rate`` fraction of the
    process :class:`~repro.kernels.ops.TuningCache` entries with degenerate
    block tuples (all-zero), so the next kernel launch through those keys
    fails -- corrupted-persistence chaos.

Determinism: every decision comes from one ``random.Random(seed)`` stream
(guarded by a lock), so a chaos run with a fixed seed and a fixed call
order injects the identical fault sequence.  Installed plans stack;
:func:`uninstall_all` force-restores everything (the conftest isolation
fixture calls it so a failing chaos test can never leak patched kernels
into the rest of the suite).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from fnmatch import fnmatch
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..kernels import ops as kops

__all__ = [
    "FaultPlan",
    "FaultRule",
    "InjectedFault",
    "active_fault_plan",
    "corrupt_tuning_cache",
    "uninstall_all",
    "wrap_handler",
]

#: the ops-module attributes a plan may patch (the four kernel families'
#: public entry points; col_matmul reaches matmul through the module global,
#: so patching matmul covers it too)
ENTRY_POINTS = ("matmul", "qmatmul", "conv2d", "fused_elementwise")

KINDS = ("raise", "nan", "inf", "latency", "cache_corrupt")


class InjectedFault(RuntimeError):
    """The exception a ``raise``-kind rule throws at its site."""


@dataclasses.dataclass(frozen=True)
class FaultRule:
    site: str  # fnmatch pattern over fault sites ("matmul", "conv2d", "*")
    kind: str  # one of KINDS
    rate: float = 1.0  # per-call injection probability (fraction for cache_corrupt)
    delay: float = 0.05  # latency-kind sleep seconds
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r}: want one of {KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


#: stack of installed plans (last installed wins for overlapping sites --
#: each plan's wrappers nest)
_ACTIVE: List["FaultPlan"] = []


def active_fault_plan() -> Optional["FaultPlan"]:
    """The most recently installed plan (None when chaos is off)."""
    return _ACTIVE[-1] if _ACTIVE else None


def wrap_handler(site: str, fn: Callable) -> Callable:
    """Wrap an op handler with every active plan's injection at ``site``
    (identity when no plan is installed or no rule matches) -- the guarded
    executor's per-step hook."""
    for plan in _ACTIVE:
        fn = plan.wrap(site, fn)
    return fn


def uninstall_all() -> int:
    """Force-restore every installed plan (teardown safety net)."""
    n = 0
    while _ACTIVE:
        _ACTIVE[-1].uninstall()
        n += 1
    return n


def corrupt_tuning_cache(rng, fraction: float = 1.0) -> List[str]:
    """Overwrite a deterministic ``fraction`` of the process TuningCache's
    entries with degenerate all-zero block tuples (same arity, so legacy
    normalization keeps them) -- the next kernel launch that resolves one
    dies on a zero block size, which is exactly what the guarded executor
    must absorb.  Returns the corrupted keys."""
    cache = kops.tuning_cache()
    keys = sorted(cache.entries)
    corrupted = []
    for k in keys:
        if rng.random() < fraction:
            e = cache.entries[k]
            cache.entries[k] = kops.TuneEntry(
                tuple(0 for _ in e.blocks), "corrupt", None
            )
            corrupted.append(k)
    return corrupted


class FaultPlan:
    """A seeded registry of fault rules, installable over the kernel entry
    points (and consulted per-step by the guarded executor).  Use as a
    context manager so a failing test can never leak the patches::

        with FaultPlan([FaultRule("matmul", "raise", rate=0.05)], seed=0):
            ...  # 5% of matmul calls raise InjectedFault, deterministically
    """

    def __init__(
        self,
        rules: Sequence[FaultRule],
        *,
        seed: int = 0,
        entry_points: Sequence[str] = ENTRY_POINTS,
        sleep: Callable[[float], None] = time.sleep,
    ):
        import random

        self.rules = tuple(rules)
        self.seed = seed
        self.entry_points = tuple(entry_points)
        self.sleep = sleep
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._originals: Dict[str, Callable] = {}
        #: site -> kind -> injections actually fired
        self.injected: Dict[str, Dict[str, int]] = {}
        #: site -> calls observed (fired or not): rate denominators
        self.calls: Dict[str, int] = {}
        self.corrupted_keys: Tuple[str, ...] = ()

    # -- bookkeeping --------------------------------------------------------- #
    def injection_count(self, site: Optional[str] = None) -> int:
        with self._lock:
            sites = [site] if site is not None else list(self.injected)
            return sum(
                sum(self.injected.get(s, {}).values()) for s in sites
            )

    # -- decision + effects -------------------------------------------------- #
    def _fire(self, site: str):
        """Roll the dice for ``site``.  Raises for ``raise`` rules, sleeps
        for ``latency`` rules, and returns a post-processor (or None) for
        poisoning rules.  First matching rule wins."""
        with self._lock:
            self.calls[site] = self.calls.get(site, 0) + 1
            rule = None
            for r in self.rules:
                if r.kind != "cache_corrupt" and fnmatch(site, r.site):
                    if self._rng.random() < r.rate:
                        rule = r
                    break  # first matching rule owns the site
            if rule is not None:
                by_kind = self.injected.setdefault(site, {})
                by_kind[rule.kind] = by_kind.get(rule.kind, 0) + 1
        if rule is None:
            return None
        if rule.kind == "raise":
            raise InjectedFault(f"{site}: {rule.message}")
        if rule.kind == "latency":
            self.sleep(rule.delay)
            return None
        poison = jnp.nan if rule.kind == "nan" else jnp.inf
        return lambda y: jnp.full_like(y, poison)

    def wrap(self, site: str, fn: Callable) -> Callable:
        """``fn`` with this plan's injection at ``site`` (identity when no
        non-corrupt rule matches the site)."""
        if not any(
            r.kind != "cache_corrupt" and fnmatch(site, r.site)
            for r in self.rules
        ):
            return fn

        def faulty(*args, **kwargs):
            post = self._fire(site)
            y = fn(*args, **kwargs)
            return post(y) if post is not None else y

        faulty.__wrapped__ = fn
        faulty.__name__ = f"faulty_{getattr(fn, '__name__', site)}"
        return faulty

    # -- install / uninstall ------------------------------------------------- #
    def install(self) -> "FaultPlan":
        if self._originals:
            raise RuntimeError("FaultPlan already installed")
        for name in self.entry_points:
            orig = getattr(kops, name)
            wrapped = self.wrap(name, orig)
            if wrapped is not orig:
                self._originals[name] = orig
                setattr(kops, name, wrapped)
        for r in self.rules:  # one-shot corruption rules fire at install
            if r.kind == "cache_corrupt":
                with self._lock:
                    keys = corrupt_tuning_cache(self._rng, r.rate)
                    self.corrupted_keys += tuple(keys)
                    by_kind = self.injected.setdefault("tuning_cache", {})
                    by_kind["cache_corrupt"] = (
                        by_kind.get("cache_corrupt", 0) + len(keys)
                    )
        _ACTIVE.append(self)
        return self

    def uninstall(self) -> None:
        for name, orig in self._originals.items():
            setattr(kops, name, orig)
        self._originals.clear()
        if self in _ACTIVE:
            _ACTIVE.remove(self)

    def __enter__(self) -> "FaultPlan":
        return self.install()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.uninstall()
