from .breaker import BreakerOpen, CircuitBreaker, GuardConfig, NumericGuardError
from .faults import (
    FaultPlan,
    FaultRule,
    InjectedFault,
    active_fault_plan,
    corrupt_tuning_cache,
    uninstall_all,
    wrap_handler,
)

__all__ = [
    "BreakerOpen",
    "CircuitBreaker",
    "FaultPlan",
    "FaultRule",
    "GuardConfig",
    "InjectedFault",
    "NumericGuardError",
    "active_fault_plan",
    "corrupt_tuning_cache",
    "uninstall_all",
    "wrap_handler",
]
