"""The paper's contribution: ADMM structured pruning + compiler optimizations."""
from . import graph, pruning, sparse  # noqa: F401
