"""Euclidean projections onto the structured-sparsity sets of structures.py.

The ADMM Z-step is ``Z = Pi_S(W + U)`` -- the closest point (Frobenius norm) in
the structure set.  For every magnitude-type structure this is "keep the
largest-magnitude prune-units, zero the rest", with the unit's magnitude pooled
as the group L2 norm.  All projections are pure jnp, jit- and grad-safe
(straight-through where used inside training), and return ``(projected, mask)``
with ``mask`` broadcastable to the weight shape.

Shapes follow structures.py: 2-D ``W[K, N]`` for matrix structures, 4-D
``W[C_out, C_in, kh, kw]`` for PatternKernel.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .structures import (
    NM,
    BankBalanced,
    Block,
    Channel,
    Column,
    PatternKernel,
    Row,
    Structure,
    Unstructured,
)

__all__ = ["project", "mask_for", "topk_mask"]

Array = jax.Array


def topk_mask(scores: Array, k: int, axis: int = -1) -> Array:
    """0/1 mask keeping the top-``k`` entries of ``scores`` along ``axis``.

    Deterministic tie-break (by index) via jax.lax.top_k on a stable ordering.
    """
    if k <= 0:
        return jnp.zeros_like(scores)
    n = scores.shape[axis]
    if k >= n:
        return jnp.ones_like(scores)
    moved = jnp.moveaxis(scores, axis, -1)
    # threshold = k-th largest value along the axis
    kth = jax.lax.top_k(moved, k)[0][..., -1:]
    keep = moved >= kth
    # Resolve ties so exactly k survive: rank by (value, -index).
    # cumsum over a >=-threshold mask in descending index order keeps the
    # first k hits in top_k's own ordering.
    order = jnp.argsort(jnp.argsort(-moved, axis=-1, stable=True), axis=-1, stable=True)
    keep = keep & (order < k)
    return jnp.moveaxis(keep.astype(scores.dtype), -1, axis)


# --------------------------------------------------------------------------- #
# per-structure projections                                                    #
# --------------------------------------------------------------------------- #


def _project_unstructured(w: Array, s: Unstructured) -> Tuple[Array, Array]:
    k = s.n_kept(w.size)
    mask = topk_mask(jnp.abs(w).reshape(-1), k).reshape(w.shape)
    return w * mask, mask


def _project_row(w: Array, s: Row) -> Tuple[Array, Array]:
    norms = jnp.linalg.norm(w, axis=1)  # [K]
    mask = topk_mask(norms, s.n_kept(w.shape[0]))[:, None]
    return w * mask, jnp.broadcast_to(mask, w.shape)


def _project_column(w: Array, s: Column) -> Tuple[Array, Array]:
    # paper's column pruning: prune along the input-feature axis (axis 0 of
    # W[K, N]) -- the same position removed from every output filter.
    norms = jnp.linalg.norm(w, axis=1)  # [K]
    mask = topk_mask(norms, s.n_kept(w.shape[0]))[:, None]
    return w * mask, jnp.broadcast_to(mask, w.shape)


def _project_channel(w: Array, s: Channel) -> Tuple[Array, Array]:
    norms = jnp.linalg.norm(w, axis=0)  # [N]
    mask = topk_mask(norms, s.n_kept(w.shape[1]))[None, :]
    return w * mask, jnp.broadcast_to(mask, w.shape)


def _project_block(w: Array, s: Block) -> Tuple[Array, Array]:
    kb, nb = s.grid(w.shape)
    blocks = w.reshape(kb, s.bm, nb, s.bn)
    norms = jnp.sqrt(jnp.sum(blocks.astype(jnp.float32) ** 2, axis=(1, 3)))  # [kb, nb]
    if s.balanced:
        # same number of kept blocks in every block-COLUMN (output feature
        # group): with output-stationary execution every output tile of the
        # BSR kernel then does identical work -- the load-balance contract the
        # paper's matrix reorder establishes for its thread grid
        # (DESIGN.md section 2).
        keep_per_col = s.n_kept(kb)
        bmask = topk_mask(norms, keep_per_col, axis=0)
    else:
        keep = s.n_kept(kb * nb)
        bmask = topk_mask(norms.reshape(-1), keep).reshape(kb, nb)
    mask = jnp.broadcast_to(bmask[:, None, :, None], blocks.shape).reshape(w.shape)
    mask = mask.astype(w.dtype)
    return w * mask, mask


def _project_nm(w: Array, s: NM) -> Tuple[Array, Array]:
    k, n = w.shape
    groups = w.reshape(k // s.m, s.m, n)
    mask = topk_mask(jnp.abs(groups), s.n_keep, axis=1)
    mask = mask.reshape(w.shape)
    return w * mask, mask


def _project_bank(w: Array, s: BankBalanced) -> Tuple[Array, Array]:
    k, n = w.shape
    banks = w.reshape(k, n // s.bank, s.bank)
    keep = s.n_kept(s.bank)
    mask = topk_mask(jnp.abs(banks), keep, axis=2).reshape(w.shape)
    return w * mask, mask


def _pattern_library(s: PatternKernel) -> np.ndarray:
    """[P, kh*kw] 0/1 library matrix (static, numpy)."""
    ksz = s.kernel_size * s.kernel_size
    lib = np.zeros((len(s.patterns), ksz), np.float32)
    for i, pat in enumerate(s.patterns):
        lib[i, list(pat)] = 1.0
    return lib


def _project_pattern(w: Array, s: PatternKernel) -> Tuple[Array, Array]:
    """Pattern + connectivity projection for conv weights [C_out, C_in, kh, kw].

    Per kernel: pick the library pattern retaining the most energy, zero the
    rest of the kernel.  Then cut the ``connectivity`` fraction of kernels with
    the smallest retained energy (whole-kernel removal).
    """
    co, ci, kh, kw = w.shape
    lib = jnp.asarray(_pattern_library(s))  # [P, ksz]
    flat = w.reshape(co, ci, kh * kw)
    energy = flat.astype(jnp.float32) ** 2  # [co, ci, ksz]
    # retained energy under each pattern: [co, ci, P]
    retained = jnp.einsum("oik,pk->oip", energy, lib)
    best = jnp.argmax(retained, axis=-1)  # [co, ci]
    kmask = lib[best]  # [co, ci, ksz]
    if s.connectivity > 0.0:
        kept_energy = jnp.max(retained, axis=-1)  # [co, ci]
        n_keep = max(1, int(round(ci * co * (1.0 - s.connectivity))))
        conn = topk_mask(kept_energy.reshape(-1), n_keep).reshape(co, ci)
        kmask = kmask * conn[..., None]
    mask = kmask.reshape(w.shape).astype(w.dtype)
    return w * mask, mask


_DISPATCH = {
    Unstructured: _project_unstructured,
    Row: _project_row,
    Column: _project_column,
    Channel: _project_channel,
    Block: _project_block,
    NM: _project_nm,
    BankBalanced: _project_bank,
    PatternKernel: _project_pattern,
}


def project(w: Array, structure: Structure) -> Tuple[Array, Array]:
    """Euclidean projection of ``w`` onto ``structure``; returns (w_proj, mask)."""
    structure.validate(tuple(w.shape))
    try:
        fn = _DISPATCH[type(structure)]
    except KeyError:
        raise NotImplementedError(f"no projection for {type(structure).__name__}")
    return fn(w, structure)


def mask_for(w: Array, structure: Structure) -> Array:
    """Just the 0/1 mask of the projection (same dtype as ``w``)."""
    return project(w, structure)[1]
