"""Per-layer sparsity scheduling + one-shot sensitivity analysis.

The paper assigns per-layer sparsities by hand ("column pruning for style
transfer, kernel pruning for coloring/SR").  At framework scale we automate
the assignment: a quick *sensitivity scan* (one-shot prune each layer at a few
candidate sparsities, measure loss delta on a probe batch) followed by a greedy
global assignment that hits a target overall compression at minimum summed
sensitivity -- the standard recipe (cf. AutoSlim, the paper's own citation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .projections import project
from .structures import Structure

__all__ = ["SensitivityResult", "sensitivity_scan", "assign_sparsities", "polynomial_schedule"]

PyTree = Any


@dataclasses.dataclass
class SensitivityResult:
    #: {path: {sparsity: loss_delta}}
    table: Dict[str, Dict[float, float]]
    base_loss: float


def _set_leaf(params: PyTree, target: str, value) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, w in flat:
        out.append(value if jax.tree_util.keystr(path) == target else w)
    return jax.tree_util.tree_unflatten(treedef, out)


def sensitivity_scan(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    candidates: Dict[str, Structure],
    sparsities: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
) -> SensitivityResult:
    """One-shot prune each candidate leaf at each sparsity; record loss delta.

    ``loss_fn`` should close over a fixed probe batch (deterministic).
    """
    base = float(loss_fn(params))
    table: Dict[str, Dict[float, float]] = {}
    flat = {
        jax.tree_util.keystr(p): w
        for p, w in jax.tree_util.tree_flatten_with_path(params)[0]
    }
    for path, st in candidates.items():
        w = flat[path]
        row: Dict[float, float] = {}
        for sp in sparsities:
            st_sp = dataclasses.replace(st, sparsity=sp)
            try:
                st_sp.validate(tuple(w.shape))
            except ValueError:
                continue
            wp, _ = project(w, st_sp)
            loss = float(loss_fn(_set_leaf(params, path, wp.astype(w.dtype))))
            row[sp] = loss - base
        table[path] = row
    return SensitivityResult(table=table, base_loss=base)


def assign_sparsities(
    sens: SensitivityResult,
    sizes: Dict[str, int],
    target_compression: float,
    sparsities: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
) -> Dict[str, float]:
    """Greedy: repeatedly bump the layer whose next sparsity level costs the
    least loss-delta per pruned weight, until the global pruned fraction over
    candidate layers reaches ``target_compression``."""
    levels = sorted(sparsities)
    cur: Dict[str, int] = {p: -1 for p in sens.table}  # index into levels, -1 = dense
    total = sum(sizes[p] for p in sens.table)
    if total == 0:
        return {}

    def pruned_now() -> float:
        return (
            sum(sizes[p] * (levels[i] if i >= 0 else 0.0) for p, i in cur.items())
            / total
        )

    while pruned_now() < target_compression:
        best_path, best_cost = None, float("inf")
        for p, i in cur.items():
            if i + 1 >= len(levels) or levels[i + 1] not in sens.table[p]:
                continue
            nxt = levels[i + 1]
            prev_delta = sens.table[p].get(levels[i], 0.0) if i >= 0 else 0.0
            gain_weights = sizes[p] * (nxt - (levels[i] if i >= 0 else 0.0))
            cost = (sens.table[p][nxt] - prev_delta) / max(gain_weights, 1)
            if cost < best_cost:
                best_cost, best_path = cost, p
        if best_path is None:
            break  # nothing left to bump
        cur[best_path] += 1
    return {p: (levels[i] if i >= 0 else 0.0) for p, i in cur.items()}


def polynomial_schedule(
    step: jax.Array, begin: int, end: int, final_sparsity: float, power: float = 3.0
) -> jax.Array:
    """Zhu&Gupta-style gradual sparsity ramp for mask-updating baselines."""
    t = jnp.clip((step - begin) / jnp.maximum(end - begin, 1), 0.0, 1.0)
    return final_sparsity * (1.0 - (1.0 - t) ** power)
