"""ADMM structured pruning (the paper's uniform pruning framework, section 2).

Solves  ``min_W f(W)  s.t.  W_i in S_i``  by ADMM (Boyd et al. 2011; Zhang et
al. 2018 applied it to DNN pruning).  With ``g`` the indicator of ``S`` and the
constraint ``W = Z``::

    W-step:  W <- argmin_W f(W) + rho/2 * ||W - Z + U||^2     (SGD, T steps)
    Z-step:  Z <- Pi_S(W + U)                                  (projection)
    U-step:  U <- U + W - Z                                    (dual ascent)

The W-step is folded into normal training: :func:`admm_penalty` returns the
quadratic augment to add to the task loss; :func:`admm_update` performs the
Z/U steps (run every ``update_every`` optimizer steps); :func:`hard_prune`
projects the final weights and returns masks for masked fine-tuning.

Everything is functional: the ADMM state is a pytree and shards exactly like
the parameters (Z and U inherit each weight's sharding), so the procedure runs
unchanged under pjit on a production mesh.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .projections import project
from .structures import Structure, structure_from_spec

__all__ = [
    "PrunePlan",
    "AdmmConfig",
    "AdmmState",
    "admm_init",
    "admm_penalty",
    "admm_update",
    "hard_prune",
    "convergence_metrics",
]

Array = jax.Array
PyTree = Any


# --------------------------------------------------------------------------- #
# plan: which leaves get which structure                                       #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class PrunePlan:
    """Maps parameter paths (glob patterns over ``jax.tree_util.keystr``) to
    structures.  First matching rule wins; unmatched leaves stay dense.

    Example::

        plan = PrunePlan.from_rules([
            ("*ffn*w_in*",  {"kind": "column", "sparsity": 0.6}),
            ("*attn*",      {"kind": "block", "sparsity": 0.5, "bm": 128, "bn": 128}),
        ])
    """

    rules: Tuple[Tuple[str, Structure], ...]
    #: leaves with fewer elements than this are never pruned (norms, biases)
    min_size: int = 4096

    @classmethod
    def from_rules(
        cls, rules: List[Tuple[str, Any]], min_size: int = 4096
    ) -> "PrunePlan":
        out = []
        for pat, spec in rules:
            st = spec if isinstance(spec, Structure) else structure_from_spec(spec)
            out.append((pat, st))
        return cls(tuple(out), min_size)

    @staticmethod
    def _glob_match(path: str, pat: str) -> bool:
        """Glob where ONLY ``*`` is special -- fnmatch would treat the
        ``['w']`` brackets of pytree key paths as character classes."""
        rx = ".*".join(re.escape(part) for part in pat.split("*"))
        return re.search(f"^{rx}$", path) is not None

    def structure_for(self, path: str, shape: Tuple[int, ...]) -> Optional[Structure]:
        size = 1
        for d in shape:
            size *= d
        if size < self.min_size:
            return None
        for pat, st in self.rules:
            if self._glob_match(path, pat):
                try:
                    st.validate(shape)
                except ValueError:
                    return None  # structure does not fit this leaf; skip
                return st
        return None

    def assign(self, params: PyTree) -> Dict[str, Structure]:
        """Resolved {path: structure} over a params tree (diagnostics/tests)."""
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        out = {}
        for path, w in flat:
            name = jax.tree_util.keystr(path)
            st = self.structure_for(name, tuple(w.shape))
            if st is not None:
                out[name] = st
        return out


@dataclasses.dataclass(frozen=True)
class AdmmConfig:
    rho: float = 1e-3
    #: multiply rho by this factor at every Z/U update (classic rho ramp)
    rho_ramp: float = 1.0
    rho_max: float = 1e-1
    #: run the Z/U update every this many optimizer steps
    update_every: int = 100


# --------------------------------------------------------------------------- #
# state                                                                        #
# --------------------------------------------------------------------------- #


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdmmState:
    """Pytree ADMM state.  ``z``/``u`` mirror params with None on dense leaves.

    ``structures`` is static metadata (not traced): {path: Structure}.
    """

    z: PyTree
    u: PyTree
    rho: Array  # scalar f32
    n_updates: Array  # scalar i32
    structures: Dict[str, Structure] = dataclasses.field(
        metadata=dict(static=True), default_factory=dict
    )


def _is_none(x) -> bool:
    return x is None


def _map_pruned(fn: Callable, params: PyTree, *trees: PyTree) -> PyTree:
    """tree.map over (path-aware) leaves; fn(path, w, *rest) on every leaf."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    rests = [jax.tree.leaves(t, is_leaf=_is_none) for t in trees]
    out = []
    for i, (path, w) in enumerate(flat):
        name = jax.tree_util.keystr(path)
        out.append(fn(name, w, *(r[i] for r in rests)))
    return jax.tree_util.tree_unflatten(treedef, out)


def admm_init(params: PyTree, plan: PrunePlan, config: AdmmConfig) -> AdmmState:
    """Z starts at the projection of W, U at zero (standard initialization)."""
    structures = plan.assign(params)

    def init_z(name, w):
        st = structures.get(name)
        if st is None:
            return None
        return project(w.astype(jnp.float32), st)[0]

    def init_u(name, w):
        return None if structures.get(name) is None else jnp.zeros(w.shape, jnp.float32)

    z = _map_pruned(init_z, params)
    u = _map_pruned(init_u, params)
    return AdmmState(
        z=z,
        u=u,
        rho=jnp.asarray(config.rho, jnp.float32),
        n_updates=jnp.asarray(0, jnp.int32),
        structures=structures,
    )


def admm_penalty(params: PyTree, state: AdmmState) -> Array:
    """``rho/2 * sum_i ||W_i - Z_i + U_i||_F^2`` -- add to the task loss."""

    def term(name, w, z, u):
        if z is None:
            return jnp.zeros((), jnp.float32)
        d = w.astype(jnp.float32) - z + u
        return 0.5 * jnp.sum(d * d)

    terms = _map_pruned(term, params, state.z, state.u)
    return state.rho * sum(jax.tree.leaves(terms))


def admm_update(params: PyTree, state: AdmmState, config: AdmmConfig) -> AdmmState:
    """Z-step (projection) + U-step (dual ascent) + rho ramp."""

    def new_z(name, w, u):
        if u is None:
            return None
        return project(w.astype(jnp.float32) + u, state.structures[name])[0]

    z = _map_pruned(new_z, params, state.u)

    def new_u(name, w, zi, u):
        if u is None:
            return None
        return u + w.astype(jnp.float32) - zi

    u = _map_pruned(new_u, params, z, state.u)
    rho = jnp.minimum(state.rho * config.rho_ramp, config.rho_max)
    return AdmmState(
        z=z, u=u, rho=rho, n_updates=state.n_updates + 1, structures=state.structures
    )


def hard_prune(params: PyTree, state: AdmmState) -> Tuple[PyTree, PyTree]:
    """Final projection: returns (pruned_params, mask_tree) for masked retrain."""

    def prune(name, w):
        st = state.structures.get(name)
        if st is None:
            return w, None
        wp, m = project(w, st)
        return wp.astype(w.dtype), m.astype(jnp.float32)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    ws, ms = [], []
    for path, w in flat:
        wp, m = prune(jax.tree_util.keystr(path), w)
        ws.append(wp)
        ms.append(m)
    return (
        jax.tree_util.tree_unflatten(treedef, ws),
        jax.tree_util.tree_unflatten(treedef, ms),
    )


def convergence_metrics(params: PyTree, state: AdmmState) -> Dict[str, Array]:
    """Primal residual ``||W - Z|| / ||W||`` (global); drives stop criteria."""

    def sq(name, w, z):
        if z is None:
            return jnp.zeros(()), jnp.zeros(())
        wf = w.astype(jnp.float32)
        return jnp.sum((wf - z) ** 2), jnp.sum(wf * wf)

    pairs = jax.tree.leaves(
        _map_pruned(sq, params, state.z), is_leaf=lambda x: isinstance(x, tuple)
    )
    num = sum(p[0] for p in pairs)
    den = sum(p[1] for p in pairs)
    res = jnp.sqrt(num) / jnp.maximum(jnp.sqrt(den), 1e-12)
    return {"primal_residual": res, "rho": state.rho}
