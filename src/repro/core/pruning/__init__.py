from .structures import (
    BankBalanced,
    Block,
    CANONICAL_PATTERNS,
    Channel,
    NM,
    PatternKernel,
    Row,
    Structure,
    Unstructured,
    Column,
    structure_from_spec,
)
from .projections import mask_for, project, topk_mask
from .masks import (
    apply_masks,
    combine_masks,
    count_params,
    mask_gradients,
    sparsity,
    tree_sparsity_report,
)
from .admm import (
    AdmmConfig,
    AdmmState,
    PrunePlan,
    admm_init,
    admm_penalty,
    admm_update,
    convergence_metrics,
    hard_prune,
)
from .schedule import (
    SensitivityResult,
    assign_sparsities,
    polynomial_schedule,
    sensitivity_scan,
)
