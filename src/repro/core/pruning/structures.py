"""Structured-sparsity set definitions (the ``S_i`` of the paper's Eq. 1).

Each structure describes *what unit is pruned as a whole* for a 2-D weight
matrix ``W[K, N]`` (input-features x output-features; convolutions are viewed
through im2col as ``[C_in*kh*kw, C_out]``) and knows how to

* ``group_shape`` -- the granularity at which magnitude statistics are pooled,
* ``project``     -- (in projections.py) the Euclidean projection onto the set,
* describe itself for the compiler layer (storage format + reorder legality).

The paper's taxonomy maps as:

==================  =============================================
paper term          structure here
==================  =============================================
filter pruning      ``Row``     (prunes W rows / conv filters)
channel pruning     ``Channel`` (prunes W cols / conv in-channels)
column pruning      ``Column``  (same position in every filter)
pattern pruning     ``PatternKernel`` (per 3x3 kernel patterns)
connectivity        ``PatternKernel(connectivity=...)``
(TPU adaptation)    ``Block``   (MXU-tile aligned bm x bn blocks)
(TPU adaptation)    ``NM``      (N:M within fixed groups)
==================  =============================================

``Block`` is the TPU-native prune unit (DESIGN.md section 2): a pruned block is
skipped entirely by the Pallas BSR kernel, so the surviving compute still runs
as dense MXU tiles.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

__all__ = [
    "Structure",
    "Unstructured",
    "Row",
    "Column",
    "Channel",
    "Block",
    "NM",
    "PatternKernel",
    "BankBalanced",
    "CANONICAL_PATTERNS",
    "structure_from_spec",
]


@dataclasses.dataclass(frozen=True)
class Structure:
    """Base class for a structured-sparsity set."""

    #: fraction of prune-units removed (0.0 = dense, 0.9 = 90% pruned)
    sparsity: float = 0.5

    def validate(self, shape: Tuple[int, ...]) -> None:
        if not (0.0 <= self.sparsity < 1.0):
            raise ValueError(f"sparsity must be in [0,1), got {self.sparsity}")
        if len(shape) != 2:
            raise ValueError(f"{type(self).__name__} expects 2-D weights, got {shape}")

    # ------------------------------------------------------------------ #
    # Metadata consumed by the compiler layer (core/graph, core/sparse). #
    # ------------------------------------------------------------------ #
    @property
    def kind(self) -> str:
        return type(self).__name__.lower()

    @property
    def storage_format(self) -> str:
        """Preferred compact storage format for weights pruned with this set."""
        return "masked"  # fall-back: dense + mask

    @property
    def reorderable(self) -> bool:
        """Whether matrix-reorder (row permutation) can balance this structure."""
        return False

    def n_kept(self, n_units: int) -> int:
        """Number of prune-units kept for a given unit count (at least one)."""
        return max(1, int(round(n_units * (1.0 - self.sparsity))))


@dataclasses.dataclass(frozen=True)
class Unstructured(Structure):
    """Element-wise magnitude pruning (baseline the paper argues *against*)."""

    @property
    def storage_format(self) -> str:
        return "csr"


@dataclasses.dataclass(frozen=True)
class Row(Structure):
    """Filter pruning: removes entire rows of W (output features / filters)."""

    @property
    def storage_format(self) -> str:
        return "rowcompact"

    @property
    def reorderable(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Column(Structure):
    """Column pruning (paper: style transfer): removes the same input position
    from every filter, i.e. entire rows of the im2col'd ``W[K, N]`` viewed from
    the K side.  Here we prune along axis 0 of ``W[K, N]`` -- the compacted
    weight is a strictly smaller dense GEMM plus a static input gather."""

    @property
    def storage_format(self) -> str:
        return "colcompact"

    @property
    def reorderable(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Channel(Structure):
    """Channel pruning: removes output columns of ``W[K, N]`` *and* the
    corresponding input channel of the next layer (handled by the graph pass).

    Contract: a pruned channel is removed *entirely* -- its bias too.  The
    masked-dense reference of a channel-pruned layer is therefore
    ``act(x @ (W*mask) + b*col_mask)`` (see graph/passes.substitute_sparse)."""

    @property
    def storage_format(self) -> str:
        return "channelcompact"

    @property
    def reorderable(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Block(Structure):
    """MXU-tile block pruning (TPU adaptation, DESIGN.md section 2).

    ``W[K, N]`` is tiled into ``(bm, bn)`` blocks; whole blocks are pruned by
    pooled magnitude.  Surviving blocks execute as dense MXU tiles via the
    Pallas BSR kernel.  ``bm``/``bn`` should be multiples of the hardware tile
    (8 sublanes x 128 lanes; 128x128 keeps the MXU square-fed).
    """

    bm: int = 128
    bn: int = 128
    #: if set, force the same number of kept blocks per block-row
    #: (load-balance contract consumed by the BSR kernel; the matrix-reorder
    #: pass can establish this post-hoc for free-form block sparsity).
    balanced: bool = True

    def validate(self, shape: Tuple[int, ...]) -> None:
        super().validate(shape)
        k, n = shape
        if k % self.bm or n % self.bn:
            raise ValueError(
                f"Block({self.bm},{self.bn}) does not tile weight {shape}; "
                "pad the layer or choose divisor block dims"
            )

    @property
    def storage_format(self) -> str:
        return "pbcsr"

    @property
    def reorderable(self) -> bool:
        return True

    def grid(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        return shape[0] // self.bm, shape[1] // self.bn


@dataclasses.dataclass(frozen=True)
class NM(Structure):
    """N:M sparsity: keep ``n_keep`` of every ``m`` consecutive weights along
    the input (K) axis.  ``sparsity`` is derived, not free."""

    n_keep: int = 2
    m: int = 4

    def __post_init__(self):
        object.__setattr__(self, "sparsity", 1.0 - self.n_keep / self.m)

    def validate(self, shape: Tuple[int, ...]) -> None:
        if len(shape) != 2:
            raise ValueError(f"NM expects 2-D weights, got {shape}")
        if shape[0] % self.m:
            raise ValueError(f"K={shape[0]} not divisible by m={self.m}")

    @property
    def storage_format(self) -> str:
        return "nmpacked"


#: The canonical 4-entry patterns inside a 3x3 kernel used by pattern pruning
#: (PCONV, Ma et al. 2019 -- the paper's own citation).  Each pattern keeps the
#: centre plus three of its 4-neighbours; these dominate trained CNNs and keep
#: the receptive field connected.
CANONICAL_PATTERNS: Tuple[Tuple[int, ...], ...] = (
    (1, 3, 4, 5),  # centre + W,E + N      (indices into the 3x3 raster 0..8)
    (1, 4, 5, 7),  # centre + N,S + E
    (3, 4, 5, 7),  # centre + W,E + S
    (1, 3, 4, 7),  # centre + N,S + W
    (0, 1, 3, 4),  # NW corner block
    (1, 2, 4, 5),  # NE corner block
    (3, 4, 6, 7),  # SW corner block
    (4, 5, 7, 8),  # SE corner block
)


@dataclasses.dataclass(frozen=True)
class PatternKernel(Structure):
    """Pattern + connectivity pruning for conv kernels (paper: coloring & SR).

    Operates on 4-D conv weights ``[C_out, C_in, kh, kw]`` flattened per-kernel:
    every (c_out, c_in) kernel is either (a) assigned the best-matching pattern
    from the pattern library (pattern pruning) or (b) removed entirely
    (connectivity pruning), with ``connectivity`` the fraction of kernels cut.
    """

    patterns: Tuple[Tuple[int, ...], ...] = CANONICAL_PATTERNS
    #: fraction of whole kernels removed on top of per-kernel patterns
    connectivity: float = 0.0
    kernel_size: int = 3

    def validate(self, shape: Tuple[int, ...]) -> None:  # 4-D here
        if len(shape) != 4:
            raise ValueError(f"PatternKernel expects 4-D conv weights, got {shape}")
        kh, kw = shape[2], shape[3]
        if kh != self.kernel_size or kw != self.kernel_size:
            raise ValueError(
                f"PatternKernel(kernel_size={self.kernel_size}) vs weight {shape}"
            )
        if not (0.0 <= self.connectivity < 1.0):
            raise ValueError(f"connectivity in [0,1), got {self.connectivity}")

    @property
    def storage_format(self) -> str:
        return "pattern"

    @property
    def reorderable(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class BankBalanced(Structure):
    """Bank-balanced sparsity: within every row, keep exactly ``n_kept`` of the
    elements of each contiguous bank of ``bank`` columns.  A middle ground
    between unstructured and column pruning; vector-unit friendly."""

    bank: int = 128

    def validate(self, shape: Tuple[int, ...]) -> None:
        super().validate(shape)
        if shape[1] % self.bank:
            raise ValueError(f"N={shape[1]} not divisible by bank={self.bank}")

    @property
    def storage_format(self) -> str:
        return "bankpacked"


def structure_from_spec(spec: dict) -> Structure:
    """Build a Structure from a plain-dict config (configs/*.py use this)."""
    kinds = {
        "unstructured": Unstructured,
        "row": Row,
        "filter": Row,
        "column": Column,
        "channel": Channel,
        "block": Block,
        "nm": NM,
        "pattern": PatternKernel,
        "bank": BankBalanced,
    }
    spec = dict(spec)
    kind = spec.pop("kind")
    try:
        cls = kinds[kind]
    except KeyError:
        raise ValueError(f"unknown structure kind {kind!r}; one of {sorted(kinds)}")
    if "patterns" in spec:
        spec["patterns"] = tuple(tuple(p) for p in spec["patterns"])
    return cls(**spec)
