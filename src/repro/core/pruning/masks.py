"""Mask algebra + sparsity accounting over pytrees of weights.

A *mask tree* mirrors a params pytree, with a 0/1 array for every pruned leaf
and ``None`` for untouched leaves.  All functions are pure; masked training is
"multiply weights by mask inside the step" (gradients flow only to survivors
because the mask is constant).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "apply_masks",
    "mask_gradients",
    "sparsity",
    "tree_sparsity_report",
    "combine_masks",
    "count_params",
]

Array = jax.Array
PyTree = Any


def _is_leaf_none(x) -> bool:
    return x is None


def apply_masks(params: PyTree, masks: PyTree) -> PyTree:
    """Elementwise ``w * m`` wherever the mask tree has a mask, identity else."""
    return jax.tree.map(
        lambda w, m: w if m is None else w * m.astype(w.dtype),
        params,
        masks,
        is_leaf=_is_leaf_none,
    )


def mask_gradients(grads: PyTree, masks: PyTree) -> PyTree:
    """Zero gradients of pruned weights (masked-retraining step rule)."""
    return apply_masks(grads, masks)


def sparsity(mask: Array) -> float:
    """Fraction of zeros in a single mask."""
    return float(1.0 - jnp.mean(mask.astype(jnp.float32)))


def count_params(params: PyTree) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


def combine_masks(a: Optional[Array], b: Optional[Array]) -> Optional[Array]:
    """Intersection of two masks (None = all-ones)."""
    if a is None:
        return b
    if b is None:
        return a
    return a * b


def tree_sparsity_report(params: PyTree, masks: PyTree) -> Dict[str, Any]:
    """Per-leaf and global sparsity accounting.

    Returns ``{"per_leaf": {path: (n_total, n_zero)}, "global": frac,
    "pruned_global": frac_over_masked_leaves}``.
    """
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_m = jax.tree.leaves(masks, is_leaf=_is_leaf_none)
    per_leaf: Dict[str, Tuple[int, int]] = {}
    tot = zero = masked_tot = masked_zero = 0
    for (path, w), m in zip(flat_p, flat_m):
        name = jax.tree_util.keystr(path)
        n = int(w.size)
        z = 0 if m is None else int(n - jnp.sum(m != 0))
        per_leaf[name] = (n, z)
        tot += n
        zero += z
        if m is not None:
            masked_tot += n
            masked_zero += z
    return {
        "per_leaf": per_leaf,
        "global": zero / max(tot, 1),
        "pruned_global": masked_zero / max(masked_tot, 1),
        "n_params": tot,
        "n_zero": zero,
    }
