"""Layout helpers shared by formats.py, reorder.py and the Pallas kernels."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "block_mask",
    "pad_to_multiple",
    "extract_blocks",
    "pack_balanced",
    "unpack_balanced",
]

Array = jax.Array


def block_mask(mask: Array, bm: int, bn: int) -> Array:
    """[K, N] elementwise mask -> [Kb, Nb] bool kept-block map."""
    k, n = mask.shape
    return jnp.any(mask.reshape(k // bm, bm, n // bn, bn) != 0, axis=(1, 3))


def pad_to_multiple(x: Array, multiple: int, axis: int) -> Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def extract_blocks(w: Array, bm: int, bn: int) -> Array:
    """[K, N] -> [Kb, Nb, bm, bn]."""
    k, n = w.shape
    return w.reshape(k // bm, bm, n // bn, bn).transpose(0, 2, 1, 3)


def pack_balanced(
    w: Array, bmask: np.ndarray, bm: int, bn: int
) -> Tuple[Array, Array]:
    """Column-major packing padded to the max per-column count.

    Returns ``(values [Nb, S, bm, bn], block_rows [Nb, S] int32 with -1 pad)``.
    Host-side (numpy) -- runs once at deployment/compile time, not in the step.
    """
    k, n = w.shape
    kb, nb = k // bm, n // bn
    blocks = np.asarray(w).reshape(kb, bm, nb, bn).transpose(2, 0, 1, 3)
    counts = bmask.sum(axis=0)
    s_max = max(int(counts.max(initial=0)), 1)
    values = np.zeros((nb, s_max, bm, bn), np.asarray(w).dtype)
    rows = np.full((nb, s_max), -1, np.int32)
    for j in range(nb):
        kept = np.nonzero(bmask[:, j])[0]
        values[j, : len(kept)] = blocks[j, kept]
        rows[j, : len(kept)] = kept
    return jnp.asarray(values), jnp.asarray(rows)


def unpack_balanced(
    values: Array, rows: Array, shape: Tuple[int, int], bm: int, bn: int
) -> Array:
    """Inverse of pack_balanced (exact, ignoring -1 pads)."""
    k, n = shape
    kb, nb = k // bm, n // bn
    v = np.asarray(values)
    r = np.asarray(rows)
    out = np.zeros((kb, bm, nb, bn), v.dtype)
    for j in range(nb):
        for s in range(r.shape[1]):
            if r[j, s] >= 0:
                out[r[j, s], :, j, :] = v[j, s]
    return jnp.asarray(out.reshape(k, n))
