from .formats import CSR, ChannelCompact, ColumnCompact, PBCSR, dense_nbytes
from .packing import (
    block_mask,
    extract_blocks,
    pack_balanced,
    pad_to_multiple,
    unpack_balanced,
)
from .reorder import (
    Band,
    ReorderPlan,
    apply_column_perm,
    balance_stats,
    fold_perm_into_next,
    invert_column_perm,
    plan_reorder,
)
