"""Compact sparse weight storage (paper section 3, "Sparse model storage").

The paper's point: structured pruning leaves *regularity* that generic CSR
throws away -- storing one index per surviving weight is redundant when whole
columns/kernels/blocks survive together.  The formats here keep exactly one
index per surviving *structure*:

``PBCSR``
    Packed Block Compressed Sparse (column-major) storage for block pruning.
    One int32 per surviving 128x128 block (~0.00006 index/weight vs CSR's 1).
    Stored output-column-major so the Pallas BSR kernel streams it with an
    output-stationary grid; the per-column counts are equalized by the
    balanced projection or by the reorder pass (bands).

``ColumnCompact``
    For column pruning along K: the kept rows of ``W[K, N]`` are physically
    compacted to a dense ``[K_kept, N]`` plus one int32 per kept row.  Runtime
    = static input gather + strictly smaller dense GEMM.

``ChannelCompact``
    For channel pruning along N: dense ``[K, N_kept]`` + kept-column indices;
    the graph pass folds the index map into the *next* layer, so runtime cost
    is zero.

``CSR``
    The textbook baseline the paper compares against (storage only).

All formats support exact ``to_dense`` round-trip, and report ``nbytes`` for
the storage-ratio benchmark (EXPERIMENTS.md section Table1/Kernels).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PBCSR", "ColumnCompact", "ChannelCompact", "CSR", "dense_nbytes"]

Array = jax.Array


def dense_nbytes(shape: Tuple[int, ...], dtype=jnp.bfloat16) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * jnp.dtype(dtype).itemsize


def _block_mask(mask: Array, bm: int, bn: int) -> Array:
    """[K, N] elementwise mask -> [Kb, Nb] bool block-kept map."""
    k, n = mask.shape
    blocks = mask.reshape(k // bm, bm, n // bn, bn)
    return jnp.any(blocks != 0, axis=(1, 3))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PBCSR:
    """Packed block storage, output-column-major, padded to uniform count.

    ``values[j, s]`` is the s-th surviving (bm, bn) block of output
    block-column j; ``block_rows[j, s]`` its block-row index in the dense
    weight (-1 marks padding; padded values are zero so accumulating them is
    exact, merely wasted work -- the reorder pass exists to minimize it).
    """

    values: Array  # [Nb, S, bm, bn]
    block_rows: Array  # [Nb, S] int32, -1 = pad
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    bm: int = dataclasses.field(metadata=dict(static=True), default=128)
    bn: int = dataclasses.field(metadata=dict(static=True), default=128)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_dense(
        cls, w: Array, mask: Array, bm: int = 128, bn: int = 128
    ) -> "PBCSR":
        k, n = w.shape
        if k % bm or n % bn:
            raise ValueError(f"blocks ({bm},{bn}) do not tile {w.shape}")
        w = jnp.asarray(w) * jnp.asarray(mask, w.dtype)
        kb, nb = k // bm, n // bn
        bmask = np.asarray(_block_mask(jnp.asarray(mask), bm, bn))  # [Kb, Nb]
        counts = bmask.sum(axis=0)  # per output block-column
        s_max = max(int(counts.max(initial=0)), 1)
        blocks = np.asarray(w).reshape(kb, bm, nb, bn).transpose(2, 0, 1, 3)
        # blocks: [Nb, Kb, bm, bn]
        values = np.zeros((nb, s_max, bm, bn), dtype=np.asarray(w).dtype)
        rows = np.full((nb, s_max), -1, dtype=np.int32)
        for j in range(nb):
            kept = np.nonzero(bmask[:, j])[0]
            values[j, : len(kept)] = blocks[j, kept]
            rows[j, : len(kept)] = kept
        return cls(
            values=jnp.asarray(values),
            block_rows=jnp.asarray(rows),
            shape=(k, n),
            bm=bm,
            bn=bn,
        )

    def to_dense(self) -> Array:
        k, n = self.shape
        kb, nb = k // self.bm, n // self.bn
        vals = np.asarray(self.values)
        rows = np.asarray(self.block_rows)
        out = np.zeros((kb, self.bm, nb, self.bn), dtype=vals.dtype)
        for j in range(nb):
            for s in range(rows.shape[1]):
                r = rows[j, s]
                if r >= 0:
                    out[r, :, j, :] = vals[j, s]
        return jnp.asarray(out.reshape(k, n))

    @property
    def n_blocks(self) -> int:
        return int(jnp.sum(self.block_rows >= 0))

    @property
    def padded_blocks(self) -> int:
        return int(self.block_rows.size) - self.n_blocks

    @property
    def nbytes(self) -> int:
        """True storage cost: surviving blocks + one int32 each (padding is an
        execution artefact, not a storage one -- serialized form stores ragged)."""
        item = jnp.dtype(self.values.dtype).itemsize
        return self.n_blocks * (self.bm * self.bn * item + 4)

    @property
    def nbytes_padded(self) -> int:
        item = jnp.dtype(self.values.dtype).itemsize
        return int(self.values.size) * item + int(self.block_rows.size) * 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ColumnCompact:
    """Column pruning along K: dense [K_kept, N] + kept-row indices."""

    values: Array  # [K_kept, N]
    kept: Array  # [K_kept] int32 (sorted)
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_dense(cls, w: Array, mask: Array) -> "ColumnCompact":
        keep_rows = np.nonzero(np.asarray(jnp.any(mask != 0, axis=1)))[0]
        if len(keep_rows) == 0:
            keep_rows = np.array([0])
        return cls(
            values=jnp.asarray(w)[jnp.asarray(keep_rows)],
            kept=jnp.asarray(keep_rows, jnp.int32),
            shape=tuple(w.shape),
        )

    def to_dense(self) -> Array:
        out = jnp.zeros(self.shape, self.values.dtype)
        return out.at[self.kept].set(self.values)

    def apply(self, x: Array) -> Array:
        """y = x @ W via static gather + small dense GEMM."""
        return jnp.take(x, self.kept, axis=-1) @ self.values

    @property
    def nbytes(self) -> int:
        item = jnp.dtype(self.values.dtype).itemsize
        return int(self.values.size) * item + int(self.kept.size) * 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ChannelCompact:
    """Channel pruning along N: dense [K, N_kept] + kept-column indices."""

    values: Array  # [K, N_kept]
    kept: Array  # [N_kept] int32 (sorted)
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_dense(cls, w: Array, mask: Array) -> "ChannelCompact":
        keep_cols = np.nonzero(np.asarray(jnp.any(mask != 0, axis=0)))[0]
        if len(keep_cols) == 0:
            keep_cols = np.array([0])
        return cls(
            values=jnp.asarray(w)[:, jnp.asarray(keep_cols)],
            kept=jnp.asarray(keep_cols, jnp.int32),
            shape=tuple(w.shape),
        )

    def to_dense(self) -> Array:
        out = jnp.zeros(self.shape, self.values.dtype)
        return out.at[:, self.kept].set(self.values)

    def apply(self, x: Array) -> Array:
        """y_compact = x @ W_kept; caller scatters or folds into next layer."""
        return x @ self.values

    def scatter(self, y_compact: Array) -> Array:
        out_shape = y_compact.shape[:-1] + (self.shape[1],)
        out = jnp.zeros(out_shape, y_compact.dtype)
        return out.at[..., self.kept].set(y_compact)

    @property
    def nbytes(self) -> int:
        item = jnp.dtype(self.values.dtype).itemsize
        return int(self.values.size) * item + int(self.kept.size) * 4


@dataclasses.dataclass
class CSR:
    """Textbook CSR -- storage-size baseline only (host-side, numpy)."""

    data: np.ndarray
    indices: np.ndarray  # int32 column index per nonzero  <- the redundancy
    indptr: np.ndarray  # [K+1]
    shape: Tuple[int, int]

    @classmethod
    def from_dense(cls, w, mask) -> "CSR":
        w = np.asarray(w) * np.asarray(mask, dtype=np.asarray(w).dtype)
        k, n = w.shape
        indptr = np.zeros(k + 1, np.int64)
        idx, data = [], []
        for i in range(k):
            nz = np.nonzero(w[i])[0]
            idx.append(nz.astype(np.int32))
            data.append(w[i, nz])
            indptr[i + 1] = indptr[i] + len(nz)
        return cls(
            data=np.concatenate(data) if data else np.zeros(0, w.dtype),
            indices=np.concatenate(idx) if idx else np.zeros(0, np.int32),
            indptr=indptr,
            shape=(k, n),
        )

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, self.data.dtype)
        for i in range(self.shape[0]):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            out[i, self.indices[lo:hi]] = self.data[lo:hi]
        return out

    @property
    def nbytes(self) -> int:
        return (
            self.data.nbytes + self.indices.nbytes + self.indptr.nbytes
        )
