"""Matrix reorder (paper section 3, "Matrix reorder") adapted to TPU.

The paper reorders *rows* (filters) so rows with the same/similar pruning
pattern sit together, then compacts along columns -- fixing SpMM thread load
imbalance and irregular access on mobile SIMD.

On TPU the executor is an output-stationary Pallas grid: one program per
(M-tile, output block-column).  The imbalance analogue is *per-output-column
surviving-block counts* differing -> every program pads to the max count and
the padding is wasted MXU work.  The reorder pass therefore:

1. sorts output block-columns by surviving count ("rows with similar pattern
   together" -- here columns, because im2col'd conv filters are W's columns);
2. partitions them into *bands* of equal (or near-equal) count, so lowering
   can issue one pallas_call per band with an exact trip count -- zero padding
   inside a band;
3. emits a column permutation which the graph layer *folds into the next op*
   (permuting a layer's output features = permuting the next weight's input
   rows), so runtime permutation cost is zero -- same trick as the paper's
   offline reorder.

Balance metrics quantify the win (EXPERIMENTS.md section Kernels).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ReorderPlan", "Band", "plan_reorder", "balance_stats", "apply_column_perm"]

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Band:
    """A contiguous (post-permutation) group of output block-columns executed
    with one pallas_call of exactly ``count`` accumulation steps."""

    start: int  # first block-column (in permuted order)
    stop: int  # one past last
    count: int  # surviving blocks per column in this band (max over members)

    @property
    def n_cols(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class ReorderPlan:
    """Column permutation + band partition for one pruned weight."""

    #: permutation of output block-columns: new_j = perm[old_j] position;
    #: ``order[new_pos] = old_j`` (argsort form, easiest to apply)
    order: np.ndarray  # [Nb] int32
    bands: Tuple[Band, ...]
    bm: int
    bn: int
    #: waste fraction before/after (padded blocks / real blocks)
    waste_before: float
    waste_after: float

    @property
    def identity(self) -> bool:
        return bool(np.all(self.order == np.arange(len(self.order))))


def _counts(bmask: np.ndarray) -> np.ndarray:
    return bmask.sum(axis=0).astype(np.int64)  # per output block-column


def balance_stats(bmask: np.ndarray) -> dict:
    """Imbalance metrics of a [Kb, Nb] block-kept map (output-column view)."""
    c = _counts(bmask)
    mx = int(c.max(initial=0))
    total = int(c.sum())
    padded = int((mx - c).sum())
    return {
        "max": mx,
        "mean": float(c.mean()) if len(c) else 0.0,
        "min": int(c.min(initial=0)),
        "waste_frac": padded / max(total, 1),
        "imbalance": (mx / max(float(c.mean()), 1e-9)) if len(c) else 1.0,
    }


def plan_reorder(
    bmask: np.ndarray, max_bands: int = 4, bm: int = 128, bn: int = 128
) -> ReorderPlan:
    """Sort output block-columns by surviving count and cut into <=max_bands
    bands minimizing total padding (dynamic programming over split points).
    """
    bmask = np.asarray(bmask, bool)
    kb, nb = bmask.shape
    c = _counts(bmask)
    order = np.argsort(c, kind="stable").astype(np.int32)  # ascending count
    sorted_c = c[order]

    before = balance_stats(bmask)

    # DP: cost(prefix, bands) = padding if each band pads to its own max
    # (= its last element, counts sorted ascending).
    INF = float("inf")
    # cum[i] = sum of counts[0:i]
    cum = np.concatenate([[0], np.cumsum(sorted_c)])

    def band_cost(i: int, j: int) -> float:  # columns i..j-1 in one band
        mx = sorted_c[j - 1]
        return float(mx * (j - i) - (cum[j] - cum[i]))

    n = nb
    dp = np.full((max_bands + 1, n + 1), INF)
    choice = np.zeros((max_bands + 1, n + 1), np.int32)
    dp[0, 0] = 0.0
    for b in range(1, max_bands + 1):
        for j in range(1, n + 1):
            for i in range(j):
                if dp[b - 1, i] == INF:
                    continue
                cost = dp[b - 1, i] + band_cost(i, j)
                if cost < dp[b, j]:
                    dp[b, j] = cost
                    choice[b, j] = i
    # best number of bands
    best_b = int(np.argmin(dp[:, n]))
    cuts = []
    j = n
    for b in range(best_b, 0, -1):
        i = int(choice[b, j])
        cuts.append((i, j))
        j = i
    cuts.reverse()
    bands = tuple(
        Band(start=i, stop=j, count=int(sorted_c[j - 1]) if j > i else 0)
        for i, j in cuts
        if j > i
    )
    total = int(sorted_c.sum())
    padded_after = sum(b.count * b.n_cols for b in bands) - total
    waste_after = padded_after / max(total, 1)
    return ReorderPlan(
        order=order,
        bands=bands,
        bm=bm,
        bn=bn,
        waste_before=before["waste_frac"],
        waste_after=waste_after,
    )


def apply_column_perm(w: Array, order: np.ndarray, bn: int) -> Array:
    """Permute output block-columns of ``W[K, N]`` per ``order`` (gather)."""
    k, n = w.shape
    nb = n // bn
    wb = w.reshape(k, nb, bn)
    return jnp.take(wb, jnp.asarray(order), axis=1).reshape(k, n)


def invert_column_perm(order: np.ndarray) -> np.ndarray:
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order), dtype=order.dtype)
    return inv


def fold_perm_into_next(w_next: Array, order: np.ndarray, bn: int) -> Array:
    """Fold an output-column permutation of layer L into layer L+1's input
    rows: if y' = y[perm], then (x' @ W_next) == (y @ W_next_folded) requires
    W_next_folded = W_next with input-row blocks gathered by the same order.
    ``W_next[K, N]`` with K = bn * Nb_prev."""
    k, n = w_next.shape
    nb = k // bn
    wb = w_next.reshape(nb, bn, n)
    return jnp.take(wb, jnp.asarray(order), axis=0).reshape(k, n)
