"""PassManager: the compile pipeline as a registry of named passes.

The paper's compiler is a *sequence* of graph rewrites (norm folding,
activation fusion, sparse substitution, gather folding, DCE, ...).  The seed
hardcoded that sequence inside ``passes.optimize``; this module turns it into
a subsystem:

* every pass is **registered by name** via :func:`register_pass` and declares
  optional ``pre``/``post`` invariants (callables that raise
  :class:`InvariantViolation`);
* a :class:`PassManager` runs an ordered pipeline, validating the graph
  between stages and recording per-pass :class:`PassStats`;
* passes that consume pruning artifacts declare ``needs_masks`` and are
  skipped automatically when the :class:`PassContext` carries none.

``passes.optimize`` is now a thin wrapper over
``PassManager(DEFAULT_PIPELINE)``; new passes (see ``fuse_elementwise`` and
``cse`` in passes.py) plug in without touching the driver.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ...obs import trace as _otrace
from .ir import Graph

__all__ = [
    "InvariantViolation",
    "PassContext",
    "PassStats",
    "GraphPass",
    "register_pass",
    "get_pass",
    "available_passes",
    "PassManager",
    "DEFAULT_PIPELINE",
    "graph_valid",
    "no_foldable_batchnorm",
    "no_dead_nodes",
    "params_bound_to_nodes",
]


class InvariantViolation(RuntimeError):
    """A declared pre/post condition of a pass does not hold."""


@dataclasses.dataclass
class PassContext:
    """Everything a pass may consume besides the graph itself."""

    masks: Dict[str, Any] = dataclasses.field(default_factory=dict)
    structures: Dict[str, Any] = dataclasses.field(default_factory=dict)
    max_bands: int = 4
    #: activation-range table (repro.quant.calibrate.CalibrationTable) for
    #: the ``quantize`` pass; None leaves the pipeline at full precision
    #: (an *empty* table selects weight-only quantization)
    calibration: Optional[Any] = None
    #: node names the ``quantize`` pass leaves at f32 (the standard
    #: keep-the-output-layer-full-precision accuracy practice)
    quant_skip: Tuple[str, ...] = ()
    #: node names whose *activations* stay f32 (weights still quantize to
    #: int8, scheme pinned to w8): the mixed-precision escape hatch for
    #: residual trunks, where static activation quantization noise
    #: accumulates across blocks (see models/cnn.py:APP_ACT_SKIP)
    act_quant_skip: Tuple[str, ...] = ()
    #: per-pass statistics, filled by PassManager.run in pipeline order
    stats: Dict[str, "PassStats"] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class PassStats:
    name: str
    nodes_before: int
    nodes_after: int
    #: structural change (node set / wiring / param keys) -- pure param-value
    #: rewrites (e.g. masked-dense fallbacks) intentionally do not count
    changed: bool


def _structure_fingerprint(g: Graph):
    return (
        tuple((n.name, n.op, n.inputs) for n in g.nodes),
        g.inputs,
        g.outputs,
        tuple(sorted(g.params)),
    )


Invariant = Callable[[Graph, PassContext], None]
PassFn = Callable[[Graph, PassContext], Graph]


@dataclasses.dataclass(frozen=True)
class GraphPass:
    name: str
    fn: PassFn
    pre: Tuple[Invariant, ...] = ()
    post: Tuple[Invariant, ...] = ()
    #: consumes ctx.masks/structures; skipped when the context has no masks
    needs_masks: bool = False
    #: consumes ctx.calibration; skipped when the context carries none
    needs_calibration: bool = False


_PASS_REGISTRY: Dict[str, GraphPass] = {}


def register_pass(
    name: str,
    *,
    pre: Sequence[Invariant] = (),
    post: Sequence[Invariant] = (),
    needs_masks: bool = False,
    needs_calibration: bool = False,
) -> Callable[[PassFn], PassFn]:
    """Decorator: register ``fn(graph, ctx) -> graph`` under ``name``."""

    def deco(fn: PassFn) -> PassFn:
        if name in _PASS_REGISTRY:
            raise ValueError(f"pass {name!r} already registered")
        _PASS_REGISTRY[name] = GraphPass(
            name=name, fn=fn, pre=tuple(pre), post=tuple(post),
            needs_masks=needs_masks, needs_calibration=needs_calibration,
        )
        return fn

    return deco


def get_pass(name: str) -> GraphPass:
    try:
        return _PASS_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown pass {name!r}; registered: {sorted(_PASS_REGISTRY)}"
        ) from None


def available_passes() -> List[str]:
    return sorted(_PASS_REGISTRY)


# --------------------------------------------------------------------------- #
# standard invariants                                                          #
# --------------------------------------------------------------------------- #


def graph_valid(g: Graph, ctx: PassContext) -> None:
    """Structural well-formedness: unique names, topological def-before-use,
    bound outputs (delegates to Graph.validate)."""
    try:
        g.validate()
    except ValueError as e:
        raise InvariantViolation(str(e)) from e


def no_foldable_batchnorm(g: Graph, ctx: PassContext) -> None:
    """Post fold_norm: no inference BatchNorm left sitting on a single-consumer
    conv/linear output (those must have been folded)."""
    for n in g.nodes:
        if n.op != "norm" or n.attrs.get("kind") != "batch":
            continue
        (src_name,) = n.inputs
        try:
            src = g.node(src_name)
        except KeyError:
            continue
        if src.op in ("linear", "conv2d") and len(g.consumers(src_name)) == 1:
            raise InvariantViolation(f"unfolded batchnorm {n.name} after {src_name}")


def no_dead_nodes(g: Graph, ctx: PassContext) -> None:
    """Post dce: every node is reachable from the graph outputs."""
    live = set(g.outputs)
    by_name = {n.name: n for n in g.nodes}
    stack = [n for n in g.outputs if n in by_name]
    while stack:
        n = by_name[stack.pop()]
        for i in n.inputs:
            if i not in live:
                live.add(i)
                if i in by_name:
                    stack.append(i)
    dead = [n.name for n in g.nodes if n.name not in live]
    if dead:
        raise InvariantViolation(f"dead nodes survive dce: {dead}")


def params_bound_to_nodes(g: Graph, ctx: PassContext) -> None:
    """Every params entry belongs to an existing node (passes that delete
    nodes must also drop their params)."""
    names = {n.name for n in g.nodes}
    orphans = [k for k in g.params if k not in names]
    if orphans:
        raise InvariantViolation(f"params for nonexistent nodes: {orphans}")


# --------------------------------------------------------------------------- #
# the manager                                                                  #
# --------------------------------------------------------------------------- #

#: the deployment pipeline (paper's compiler, end to end).  cse runs before
#: fuse_elementwise so duplicate chains collapse once, not twice;
#: fuse_epilogue runs last-but-dce so it sees both surviving single
#: elementwise nodes and fused_elementwise chains, folding them into their
#: GEMM/conv producer's epilogue program.  quantize comes after
#: fuse_epilogue (epilogue attrs must already be attached so qlinear nodes
#: inherit them) and is skipped unless the context carries a calibration
#: table -- full-precision pipelines are untouched.
DEFAULT_PIPELINE: Tuple[str, ...] = (
    "fold_norm",
    "fuse_activation",
    "substitute_sparse",
    "fold_gathers",
    "cse",
    "fuse_elementwise",
    "fuse_epilogue",
    "quantize",
    "dce",
)


class PassManager:
    """Run an ordered pipeline of registered passes with between-stage
    validation.

    ``passes`` may mix registered names and ad-hoc :class:`GraphPass`
    instances (handy in tests).  ``strict=False`` downgrades invariant
    violations from exceptions to recorded stats -- the default is to fail
    loudly: a broken graph mid-pipeline is a compiler bug.
    """

    def __init__(
        self,
        passes: Optional[Sequence[Union[str, GraphPass]]] = None,
        *,
        validate_between: bool = True,
    ):
        names = DEFAULT_PIPELINE if passes is None else passes
        self.passes: List[GraphPass] = [
            p if isinstance(p, GraphPass) else get_pass(p) for p in names
        ]
        self.validate_between = validate_between

    def run(self, g: Graph, ctx: Optional[PassContext] = None) -> Graph:
        ctx = ctx or PassContext()
        for p in self.passes:
            if (p.needs_masks and not ctx.masks) or (
                p.needs_calibration and ctx.calibration is None
            ):
                ctx.stats[p.name] = PassStats(p.name, len(g.nodes), len(g.nodes), False)
                continue
            for inv in p.pre:
                inv(g, ctx)
            before = len(g.nodes)
            fp = _structure_fingerprint(g)
            with _otrace.span(p.name, cat="pass", nodes_before=before) as sp:
                g2 = p.fn(g, ctx)
                if self.validate_between:
                    graph_valid(g2, ctx)
                for inv in p.post:
                    inv(g2, ctx)
                stats = PassStats(
                    p.name,
                    before,
                    len(g2.nodes),
                    changed=g2 is not g and _structure_fingerprint(g2) != fp,
                )
                sp.set("nodes_after", stats.nodes_after)
                sp.set("changed", stats.changed)
            ctx.stats[p.name] = stats
            g = g2
        return g

    __call__ = run

    def summary(self, ctx: PassContext) -> str:
        lines = ["pass                     nodes  ->  nodes"]
        for s in ctx.stats.values():
            mark = "*" if s.changed else " "
            lines.append(f"{s.name:24s} {s.nodes_before:5d}  -> {s.nodes_after:5d} {mark}")
        return "\n".join(lines)
