"""Graph transformation passes (paper section 3, "DSL related optimization"
plus the sparse-execution planning that consumes pruning masks).

Pass pipeline for deployment (see :func:`optimize`):

1. ``fold_norm``         Conv/Linear + BatchNorm -> folded Conv/Linear
2. ``fuse_activation``   Conv/Linear + Activation -> fused epilogue attr
3. ``substitute_sparse`` pruned weights -> compact formats + sparse ops
                         (ColumnCompact / ChannelCompact / PBCSR+reorder)
4. ``fold_gathers``      compaction gathers folded into adjacent weights
5. ``dce``               drop dead nodes

All passes are pure: Graph in, Graph out.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..pruning.structures import Block, Channel, Column, PatternKernel, Structure
from ..sparse.formats import ChannelCompact, ColumnCompact, PBCSR
from ..sparse.packing import block_mask
from ..sparse.reorder import apply_column_perm, plan_reorder
from .ir import Graph, Node

__all__ = [
    "fold_norm",
    "fuse_activation",
    "substitute_sparse",
    "fold_gathers",
    "fuse_elementwise",
    "fuse_epilogue",
    "quantize",
    "cse",
    "dce",
    "optimize",
]

_FUSABLE = ("linear", "conv2d", "sparse_linear")


# --------------------------------------------------------------------------- #
# 1. norm folding                                                              #
# --------------------------------------------------------------------------- #


def fold_norm(g: Graph) -> Graph:
    """Fold BatchNorm (inference stats) into the preceding conv/linear.

    y = scale * (conv(x) - mean) / sqrt(var + eps) + bias
      = conv'(x) + b'   with w' = w * s, b' = (b - mean) * s + bias,
      s = scale / sqrt(var + eps).

    Instance/Layer norm have data-dependent statistics and are left alone
    (the paper folds BN only).
    """
    g = dataclasses.replace(g, nodes=list(g.nodes), params=dict(g.params))
    for node in list(g.nodes):
        if node.op != "norm" or node.attrs.get("kind") != "batch":
            continue
        (src_name,) = node.inputs
        try:
            src = g.node(src_name)
        except KeyError:
            continue
        if src.op not in ("linear", "conv2d"):
            continue
        if len(g.consumers(src_name)) != 1:
            continue  # conv output used elsewhere: cannot fold
        p = g.params[node.name]
        eps = node.attrs.get("eps", 1e-5)
        s = p["scale"] / jnp.sqrt(p["var"] + eps)
        sp = dict(g.params[src_name])
        w = sp["w"]
        if src.op == "conv2d":  # w [Co, Ci, kh, kw]; stats per Co
            sp["w"] = w * s[:, None, None, None]
        else:  # linear w [K, N]; stats per N
            sp["w"] = w * s[None, :]
        b = sp.get("b")
        b = jnp.zeros(s.shape, w.dtype) if b is None else b
        sp["b"] = (b - p["mean"]) * s + p["bias"]
        g.params[src_name] = sp
        g = g.without({node.name}).rewire(node.name, src_name)
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 2. activation fusion                                                         #
# --------------------------------------------------------------------------- #


def fuse_activation(g: Graph) -> Graph:
    """Attach a following activation node to its GEMM producer as a fused
    epilogue attr (executed inside the Pallas kernel)."""
    for node in list(g.nodes):
        if node.op != "activation":
            continue
        (src_name,) = node.inputs
        try:
            src = g.node(src_name)
        except KeyError:
            continue
        if src.op not in _FUSABLE or src.attrs.get("activation"):
            continue
        if len(g.consumers(src_name)) != 1:
            continue
        new_src = src.replace(attrs={**src.attrs, "activation": node.attrs["fn"]})
        g = g.replace_node(src_name, new_src)
        g = g.without({node.name}).rewire(node.name, src_name)
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 3. sparse substitution                                                       #
# --------------------------------------------------------------------------- #


def substitute_sparse(
    g: Graph,
    masks: Dict[str, Any],
    structures: Dict[str, Structure],
    *,
    max_bands: int = 4,
) -> Graph:
    """Rewrite pruned linear/conv nodes to their compact execution form.

    ``masks``/``structures`` are keyed by node name.  Rules:

    * Column  -> ``sparse_linear(format=colcompact)``: gather + smaller GEMM.
    * Channel -> ``sparse_linear(format=channelcompact)`` + ``gather_channels``
      glue node (folded into the next layer by :func:`fold_gathers`).
    * Block   -> ``sparse_linear(format=pbcsr)`` with reorder bands; the
      output block-column permutation is recorded as a ``gather_channels``
      glue node (foldable).
    * PatternKernel (conv) -> masked dense conv (TPU keeps the MXU dense;
      storage shrinks, compute does not -- DESIGN.md section 2); whole-kernel
      connectivity pruning *is* exploited: fully-dead input channels are
      compacted like Channel pruning of the previous layer.
    """
    for stale in list(g.nodes):
        if stale.name not in masks or masks[stale.name] is None:
            continue
        # re-fetch: earlier iterations may have rewired this node's inputs
        node = g.node(stale.name)
        st = structures[node.name]
        mask = masks[node.name]
        p = g.params[node.name]
        if node.op == "linear":
            w = p["w"] * mask.astype(p["w"].dtype)
            if isinstance(st, Column):
                fmt = ColumnCompact.from_dense(w, mask)
                g.params[node.name] = {
                    "values": fmt.values,
                    "kept": fmt.kept,
                    **({"b": p["b"]} if "b" in p else {}),
                }
                g = g.replace_node(
                    node.name,
                    node.replace(
                        op="sparse_linear",
                        attrs={**node.attrs, "format": "colcompact", "k_full": w.shape[0]},
                    ),
                )
            elif isinstance(st, Channel):
                fmt = ChannelCompact.from_dense(w, mask)
                bias = p.get("b")
                g.params[node.name] = {
                    "values": fmt.values,
                    **(
                        {"b": bias[np.asarray(fmt.kept)]} if bias is not None else {}
                    ),
                }
                # glue: scatter back to full width unless folded away
                glue = Node(
                    op="gather_channels",
                    name=node.name + "_scatter",
                    inputs=(node.name,),
                    attrs={"mode": "scatter", "idx": np.asarray(fmt.kept), "n": w.shape[1]},
                )
                g = g.replace_node(
                    node.name,
                    node.replace(
                        op="sparse_linear",
                        attrs={**node.attrs, "format": "channelcompact"},
                    ),
                )
                g = _insert_after(g, node.name, glue)
            elif isinstance(st, Block):
                bmask = np.asarray(block_mask(mask, st.bm, st.bn))
                plan = plan_reorder(bmask, max_bands=max_bands, bm=st.bm, bn=st.bn)
                w_perm = apply_column_perm(w, plan.order, st.bn)
                m_perm = apply_column_perm(mask, plan.order, st.bn)
                fmt = PBCSR.from_dense(w_perm, m_perm, st.bm, st.bn)
                bias = p.get("b")
                elem_order = (
                    np.asarray(plan.order)[:, None] * st.bn + np.arange(st.bn)[None, :]
                ).reshape(-1)
                g.params[node.name] = {
                    "values": fmt.values,
                    "block_rows": fmt.block_rows,
                    **({"b": bias[elem_order]} if bias is not None else {}),
                }
                g = g.replace_node(
                    node.name,
                    node.replace(
                        op="sparse_linear",
                        attrs={
                            **node.attrs,
                            "format": "pbcsr",
                            "bands": tuple((b.start, b.stop, b.count) for b in plan.bands),
                            "bn": st.bn,
                        },
                    ),
                )
                if not plan.identity:
                    # undo the column permutation for consumers (foldable)
                    inv = np.empty_like(elem_order)
                    inv[elem_order] = np.arange(len(elem_order))
                    glue = Node(
                        op="gather_channels",
                        name=node.name + "_unperm",
                        inputs=(node.name,),
                        attrs={"mode": "gather", "idx": inv, "n": w.shape[1]},
                    )
                    g = _insert_after(g, node.name, glue)
            else:  # masked dense fallback (NM, bank, unstructured)
                g.params[node.name] = {**p, "w": w}
        elif node.op == "conv2d":
            # any conv structure (pattern / column-as-channel): apply the mask,
            # then *compact away* input channels that died across all filters
            # (pattern-connectivity or column pruning at channel granularity --
            # the only conv sparsity the MXU can exploit, DESIGN.md section 2).
            # The compaction folds into the conv node itself
            # (format="channelcompact" + a ``kept`` param): the conv kernel
            # gathers the live channels and contracts a K shrunk by the
            # pruned ratio -- no glue node, no extra plan step.
            w = p["w"] * mask.astype(p["w"].dtype)
            g.params[node.name] = {**p, "w": w}
            dead_in = np.asarray(jnp.all(mask == 0, axis=(0, 2, 3)))
            if dead_in.any() and not dead_in.all():
                kept = np.nonzero(~dead_in)[0]
                g.params[node.name] = {
                    **g.params[node.name],
                    "w": g.params[node.name]["w"][:, kept],
                    "kept": jnp.asarray(kept, jnp.int32),
                }
                g = g.replace_node(
                    node.name,
                    node.replace(
                        attrs={**node.attrs, "format": "channelcompact"}
                    ),
                )
        else:
            w = p["w"] * mask.astype(p["w"].dtype)
            g.params[node.name] = {**p, "w": w}
    g.validate()
    return g


def _insert_after(g: Graph, name: str, glue: Node) -> Graph:
    """Insert ``glue`` (consuming ``name``) between node and its consumers."""
    g = g.rewire(name, glue.name)
    # rewire also rewrote glue's own input; restore it
    nodes = []
    for n in g.nodes:
        if n.name == glue.name:
            continue
        nodes.append(n)
        if n.name == name:
            nodes.append(glue.replace(inputs=(name,)))
    if glue.name not in [n.name for n in nodes]:  # name was a graph input
        nodes.insert(0, glue.replace(inputs=(name,)))
    return dataclasses.replace(g, nodes=nodes)


def _insert_before(g: Graph, name: str, glue: Node) -> Graph:
    nodes = []
    for n in g.nodes:
        if n.name == name:
            nodes.append(glue)
            n = n.replace(inputs=(glue.name,) + n.inputs[1:])
        nodes.append(n)
    return dataclasses.replace(g, nodes=nodes)


# --------------------------------------------------------------------------- #
# 4. gather folding                                                            #
# --------------------------------------------------------------------------- #


def fold_gathers(g: Graph) -> Graph:
    """Fold ``gather_channels`` glue into the next linear's weight rows:
    gather(y, idx) @ W == y @ W_expanded  (scatter mode: rows placed at idx;
    gather mode: rows selected by idx).  Zero runtime cost -- the paper's
    offline reorder trick."""
    for node in list(g.nodes):
        if node.op != "gather_channels" or node.attrs.get("axis", -1) == 1:
            continue
        consumers = g.consumers(node.name)
        if len(consumers) != 1 or consumers[0].op != "linear":
            continue
        nxt = consumers[0]
        idx = jnp.asarray(np.asarray(node.attrs["idx"]))
        w = g.params[nxt.name]["w"]
        if node.attrs["mode"] == "scatter":
            # y_full = scatter(y_compact, idx); y_full @ W == y_compact @ W[idx]
            w_new = w[idx]
        else:
            # y_perm = y[idx] (idx a permutation of 0..n-1, len == K of next W);
            # y_perm @ W == y @ W_scat with W_scat[idx[j]] = W[j].
            if int(idx.shape[0]) != int(w.shape[0]):
                continue
            w_new = jnp.zeros((node.attrs["n"], w.shape[1]), w.dtype).at[idx].set(w)
        g.params[nxt.name] = {**g.params[nxt.name], "w": w_new}
        g = g.without({node.name}).rewire(node.name, node.inputs[0])
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 5. elementwise-chain fusion                                                  #
# --------------------------------------------------------------------------- #

_EW_OPS = ("activation", "add", "mul")


def _is_elementwise(n: Node) -> bool:
    return n.op in _EW_OPS or (n.op == "norm" and n.attrs.get("kind") == "layer")


def fuse_elementwise(g: Graph) -> Graph:
    """Collapse straight-line runs of memory-bound elementwise ops
    (``add``/``mul``/``activation``/``norm(layer)``) into one
    ``fused_elementwise`` node.

    Each run becomes a single node carrying a ``steps`` program:

    * ``("activation", fn)``
    * ``("add", i)`` / ``("mul", i)`` -- ``i`` indexes the fused node's
      ``inputs`` tuple (the side operand of the binary op)
    * ``("norm_layer", pkey, eps)`` -- layernorm whose scale/bias live in the
      fused node's params under ``{pkey}_scale`` / ``{pkey}_bias``

    The fused node keeps the *last* chain member's name, so consumers and
    graph outputs are untouched.  One kernel launch instead of k, one trip
    through memory instead of k -- the paper's "DSL related optimization" for
    the non-GEMM glue between layers.
    """
    outputs = set(g.outputs)
    merged: set = set()
    chains: List[List[Node]] = []
    for n in g.nodes:
        if n.name in merged or not _is_elementwise(n):
            continue
        chain = [n]
        while True:
            cur = chain[-1]
            if cur.name in outputs:
                break
            cons = g.consumers(cur.name)
            if len(cons) != 1:
                break
            nxt = cons[0]
            if (
                not _is_elementwise(nxt)
                or nxt.name in merged
                or nxt.inputs.count(cur.name) != 1
            ):
                break
            chain.append(nxt)
        if len(chain) >= 2:
            chains.append(chain)
            merged.update(c.name for c in chain)

    if not chains:
        return g

    nodes = list(g.nodes)
    params = dict(g.params)
    for chain in chains:
        head, tail = chain[0], chain[-1]
        fused_inputs: List[str] = [head.inputs[0]]
        fused_params: Dict[str, Any] = {}
        steps: List[Tuple[Any, ...]] = []

        def side_index(name: str) -> int:
            if name not in fused_inputs:
                fused_inputs.append(name)
            return fused_inputs.index(name)

        prev_name = None  # chain value flows implicitly; head consumes inputs[0]
        for j, c in enumerate(chain):
            if c.op == "activation":
                steps.append(("activation", c.attrs["fn"]))
            elif c.op in ("add", "mul"):
                sides = list(c.inputs)
                if prev_name is not None:
                    sides.remove(prev_name)
                else:
                    sides = sides[1:]  # head: inputs[0] is the chain entry
                steps.append((c.op, side_index(sides[0])))
            else:  # norm(layer)
                pkey = f"s{j}"
                p = params.pop(c.name)
                fused_params[f"{pkey}_scale"] = p["scale"]
                fused_params[f"{pkey}_bias"] = p["bias"]
                steps.append(("norm_layer", pkey, c.attrs.get("eps", 1e-5)))
            prev_name = c.name

        fused = Node(
            op="fused_elementwise",
            name=tail.name,
            inputs=tuple(fused_inputs),
            attrs={"steps": tuple(steps)},
        )
        drop = {c.name for c in chain[:-1]}
        nodes = [fused if n.name == tail.name else n for n in nodes if n.name not in drop]
        for d in drop:
            params.pop(d, None)
        if fused_params:
            params[tail.name] = fused_params
    g = dataclasses.replace(g, nodes=nodes, params=params)
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 5b. GEMM epilogue-program fusion                                             #
# --------------------------------------------------------------------------- #

#: producers whose handlers execute an ``epilogue`` attr (see executor.py)
_EPI_PRODUCERS = ("linear", "sparse_linear", "conv2d")


def _epilogue_candidate(g: Graph, n: Node):
    """If ``n`` is an elementwise follower foldable into a GEMM/conv producer,
    return ``(src_name, raw_steps)`` where raw steps carry side operands as
    *names* (resolved to input slots by the caller) and norm params as
    ``("param", scale, bias)`` markers.  Else return None."""
    if n.op == "activation":
        return n.inputs[0], [("activation", n.attrs["fn"])]
    if n.op in ("add", "mul"):
        if len(set(n.inputs)) != 2:
            return None  # y+y consumes the producer twice; not a single edge
        a_name, b_name = n.inputs

        def foldable(name):
            try:
                nd = g.node(name)
            except KeyError:
                return False
            return (
                nd.op in _EPI_PRODUCERS
                and len(g.consumers(name)) == 1
                and name not in g.outputs
            )

        src = a_name if foldable(a_name) else (b_name if foldable(b_name) else None)
        if src is None:
            return None
        side = b_name if src == a_name else a_name
        return src, [(n.op, ("side", side))]
    if n.op == "norm" and n.attrs.get("kind") in ("instance", "layer"):
        p = g.params.get(n.name, {})
        kind = "norm_instance" if n.attrs["kind"] == "instance" else "norm_layer"
        return n.inputs[0], [
            (kind, ("param", p["scale"], p["bias"]), n.attrs.get("eps", 1e-5))
        ]
    if n.op == "rmsnorm":
        p = g.params.get(n.name, {})
        return n.inputs[0], [
            ("norm_rms", ("param", p["scale"], None), n.attrs.get("eps", 1e-6))
        ]
    if n.op == "rope":
        return n.inputs[0], [
            ("rope", ("side", n.inputs[1]), n.attrs["heads"],
             n.attrs.get("theta", 10000.0))
        ]
    if n.op == "fused_elementwise":
        if n.inputs.count(n.inputs[0]) != 1:
            return None
        steps = []
        p = g.params.get(n.name, {})
        for step in n.attrs["steps"]:
            kind = step[0]
            if kind == "activation":
                steps.append(step)
            elif kind in ("add", "mul"):
                if step[1] == 0:
                    return None  # references the producer's raw output
                steps.append((kind, ("side", n.inputs[step[1]])))
            elif kind == "norm_layer":
                pkey, eps = step[1], step[2]
                steps.append(
                    ("norm_layer", ("param", p[f"{pkey}_scale"], p[f"{pkey}_bias"]), eps)
                )
            else:
                return None
        return n.inputs[0], steps
    return None


def fuse_epilogue(g: Graph) -> Graph:
    """Fold an elementwise follower (``activation``/``add``/``mul``/
    ``norm(instance|layer)``/``fused_elementwise``) into its GEMM/conv
    producer's **epilogue program** -- a ``("epilogue", ...)`` attr executed
    by the producer's handler: inside the Pallas matmul tile for
    linear/colcompact/channelcompact (bias + activation + residual-add +
    scale on the f32 accumulator in registers, no HBM round-trip), and as a
    post-GEMM jnp tail for pbcsr/conv (still one plan step instead of two).

    Generalizes ``fuse_activation`` (the single-``activation``-string special
    case).  The fused node takes the *follower's* name, so consumers and
    graph outputs are untouched.  Epilogue side slots index the fused node's
    own ``inputs`` tuple; norm scale/bias move into its params under fresh
    ``e{i}_scale`` / ``e{i}_bias`` keys.  Runs to fixpoint, so
    conv -> IN -> relu -> add collapses into one node."""
    changed = True
    while changed:
        changed = False
        for n in list(g.nodes):
            cand = _epilogue_candidate(g, n)
            if cand is None:
                continue
            src_name, raw_steps = cand
            if n.inputs.count(src_name) != 1 or src_name in g.outputs:
                continue
            try:
                src = g.node(src_name)
            except KeyError:
                continue  # producer is a graph input
            if src.op not in _EPI_PRODUCERS or len(g.consumers(src_name)) != 1:
                continue
            if any(
                step[0] == "norm_instance" for step in raw_steps
            ) and src.op != "conv2d":
                continue  # instance norm is NCHW-only

            params = dict(g.params)
            new_params = dict(params.pop(src_name, {}))
            epi = list(src.attrs.get("epilogue", ()))
            n_norm = sum(s[0].startswith("norm") for s in epi)
            new_inputs = list(src.inputs)
            steps: List[Tuple[Any, ...]] = []
            for step in raw_steps:
                kind = step[0]
                if kind == "activation":
                    steps.append(step)
                elif kind in ("add", "mul"):
                    side = step[1][1]
                    if side not in new_inputs:
                        new_inputs.append(side)
                    steps.append((kind, new_inputs.index(side)))
                elif kind == "rope":  # position ids become a side operand
                    side = step[1][1]
                    if side not in new_inputs:
                        new_inputs.append(side)
                    steps.append((kind, new_inputs.index(side), *step[2:]))
                else:  # norm_layer / norm_instance / norm_rms
                    _, scale, bias = step[1]
                    pkey = f"e{n_norm}"
                    n_norm += 1
                    new_params[f"{pkey}_scale"] = scale
                    if bias is not None:
                        new_params[f"{pkey}_bias"] = bias
                    steps.append((kind, pkey, step[2]))
            params.pop(n.name, None)  # follower params absorbed above
            params[n.name] = new_params
            fused = Node(
                op=src.op,
                name=n.name,
                inputs=tuple(new_inputs),
                attrs={**src.attrs, "epilogue": tuple(epi) + tuple(steps)},
            )
            nodes = []
            for nd in g.nodes:
                if nd.name == src_name:
                    continue
                nodes.append(fused if nd.name == n.name else nd)
            g = dataclasses.replace(g, nodes=nodes, params=params)
            changed = True
            break  # node list changed: restart the scan
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 5c. weight quantization                                                      #
# --------------------------------------------------------------------------- #

#: sparse formats whose packed values are plain [K', N'] matrices -- these
#: ride the W8 qmatmul path.  pbcsr values are 4-D packed blocks; per-tile
#: dequant for that layout is future work, so pbcsr nodes stay f32.
_QUANT_SPARSE_FORMATS = ("colcompact", "channelcompact")


def quantize(
    g: Graph,
    calibration=None,
    *,
    skip: Tuple[str, ...] = (),
    act_skip: Tuple[str, ...] = (),
) -> Graph:
    """Rewrite GEMM/conv nodes to INT8-stored quantized ops (symmetric
    per-output-channel absmax, :class:`repro.quant.qtensor.QTensor` layout).

    * ``linear`` / ``sparse_linear(colcompact|channelcompact)`` ->
      ``qlinear``: int8 ``values`` + f32 ``w_scale[N]``.  When
      ``calibration`` (a :class:`~repro.quant.calibrate.CalibrationTable`)
      has an activation range for the node's input, the node is tagged
      ``scheme="w8a8"`` with the static ``x_scale`` -- the executor then
      contracts int8 x int8 on the MXU; otherwise ``scheme="w8"`` keeps f32
      activations and dequantizes weight tiles in VMEM.
    * ``conv2d`` -> ``qconv2d``: int8 storage (4x smaller weight stream)
      executed by the INT8 implicit-GEMM conv kernel -- ``scheme="w8a8"``
      (+ ``x_scale``) when the input's range is calibrated (int8 x int8 on
      the MXU), else ``scheme="w8"`` (filter tiles dequantized in VMEM).
      Channelcompact convs keep their ``kept`` indices.
    * ``sparse_linear(pbcsr)`` is left untouched (blocked payload), as is
      any node named in ``skip`` (the classic keep-first/last-layer-f32
      accuracy escape hatch).  Nodes named in ``act_skip`` still quantize
      their weights but are pinned to ``scheme="w8"`` even when calibrated
      -- the mixed-precision knob for residual trunks, where static
      activation quantization noise accumulates across blocks (measured on
      the demo apps: all-W8A8 breaches the 5e-2 parity contract on the two
      residual-trunk apps while the BN-normalized coloring stack holds it
      with every conv at W8A8; see ``models/cnn.py:APP_ACT_SKIP``).

    Every rewritten node is annotated with ``bytes_saved`` (dense f32 bytes
    minus int8 payload + scales), which
    :meth:`ExecutionPlan.memory_estimate` aggregates as
    ``weight_bytes_saved``.  Runs after ``fuse_epilogue`` so epilogue attrs
    (and their ``e{i}_scale``/``e{i}_bias`` params, which are preserved)
    are already attached.
    """
    from ...quant.qtensor import QTensor  # local: quant layer is optional

    g = dataclasses.replace(g, nodes=list(g.nodes), params=dict(g.params))

    def elect_scheme(node) -> Dict[str, Any]:
        """The one W8A8-vs-W8 policy shared by linear and conv rewrites:
        upgrade iff the node's input range is calibrated and its activations
        are not pinned to f32 by ``act_skip``."""
        x_scale = (
            calibration.get_scale(node.inputs[0])
            if calibration is not None and node.name not in act_skip
            else None
        )
        if x_scale is None:
            return {"scheme": "w8"}
        return {"scheme": "w8a8", "x_scale": float(x_scale)}

    nodes = []
    for node in g.nodes:
        if node.name in skip:
            nodes.append(node)
            continue
        p = g.params.get(node.name, {})
        is_qlinear = node.op == "linear" or (
            node.op == "sparse_linear"
            and node.attrs.get("format") in _QUANT_SPARSE_FORMATS
        )
        if is_qlinear:
            wkey = "w" if node.op == "linear" else "values"
            w = p[wkey]
            qt = QTensor.from_float(w, axis=1)  # per output channel (N)
            saved = int(w.size) * w.dtype.itemsize - qt.nbytes
            # keep every non-weight param (bias, colcompact gather indices,
            # epilogue norm scale/bias) alongside the packed payload
            g.params[node.name] = {
                **{k: v for k, v in p.items() if k != wkey},
                "values": qt.values,
                "w_scale": qt.scale,
            }
            attrs = {
                **node.attrs,
                "format": node.attrs.get("format", "dense"),
                "bytes_saved": saved,
                **elect_scheme(node),
            }
            nodes.append(node.replace(op="qlinear", attrs=attrs))
        elif node.op == "conv2d" and "w" in p:
            w = p["w"]
            qt = QTensor.from_float(w, axis=0)  # per output channel (Co)
            saved = int(w.size) * w.dtype.itemsize - qt.nbytes
            # the ``kept`` channel indices of a channelcompact conv (and any
            # epilogue norm params) ride along untouched
            g.params[node.name] = {
                **{k: v for k, v in p.items() if k != "w"},
                "values": qt.values,
                "w_scale": qt.scale,
            }
            # w8a8 conv contracts int8 x int8 on the MXU (the channel gather
            # preserves values, so the input's scale applies to the gathered
            # activations too)
            attrs = {**node.attrs, "bytes_saved": saved, **elect_scheme(node)}
            nodes.append(node.replace(op="qconv2d", attrs=attrs))
        else:
            nodes.append(node)
    g = dataclasses.replace(g, nodes=nodes)
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 6. common-subexpression elimination                                          #
# --------------------------------------------------------------------------- #


def _attr_key(v: Any) -> Any:
    """Hashable fingerprint of an attrs value (arrays by content)."""
    if isinstance(v, dict):
        return ("dict",) + tuple(sorted((k, _attr_key(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return ("seq",) + tuple(_attr_key(x) for x in v)
    if isinstance(v, (np.ndarray, jnp.ndarray)):
        a = np.asarray(v)
        return ("arr", a.shape, str(a.dtype), a.tobytes())
    return v


def cse(g: Graph) -> Graph:
    """Deduplicate nodes computing the same value: identical op, (resolved)
    inputs and attrs, and -- for parameterized nodes -- the *same* parameter
    arrays (identity, not value equality: cheap and never wrong)."""
    seen: Dict[Any, str] = {}
    replaced: Dict[str, str] = {}
    keep: List[Node] = []
    params = dict(g.params)
    for n in g.nodes:
        inputs = tuple(replaced.get(i, i) for i in n.inputs)
        pfp = tuple(sorted((k, id(v)) for k, v in g.params.get(n.name, {}).items()))
        key = (n.op, inputs, _attr_key(n.attrs), pfp)
        if n.op != "input" and key in seen:
            replaced[n.name] = seen[key]
            params.pop(n.name, None)
            continue
        seen.setdefault(key, n.name)
        keep.append(n.replace(inputs=inputs))
    if not replaced:
        return g
    outputs = tuple(replaced.get(o, o) for o in g.outputs)
    g = dataclasses.replace(g, nodes=keep, outputs=outputs, params=params)
    g.validate()
    return g


# --------------------------------------------------------------------------- #
# 7. dead code elimination                                                     #
# --------------------------------------------------------------------------- #


def dce(g: Graph) -> Graph:
    live = set(g.outputs)
    changed = True
    by_name = {n.name: n for n in g.nodes}
    while changed:
        changed = False
        for name in list(live):
            n = by_name.get(name)
            if n is None:
                continue
            for i in n.inputs:
                if i not in live:
                    live.add(i)
                    changed = True
    dead = {n.name for n in g.nodes if n.name not in live}
    return g.without(dead)


# --------------------------------------------------------------------------- #
# registration + pipeline                                                      #
# --------------------------------------------------------------------------- #

from .pass_manager import (  # noqa: E402  (registry must exist before passes)
    PassContext,
    PassManager,
    no_dead_nodes,
    no_foldable_batchnorm,
    params_bound_to_nodes,
    register_pass,
)

register_pass("fold_norm", post=(no_foldable_batchnorm, params_bound_to_nodes))(
    lambda g, ctx: fold_norm(g)
)
register_pass("fuse_activation", post=(params_bound_to_nodes,))(
    lambda g, ctx: fuse_activation(g)
)
register_pass("substitute_sparse", needs_masks=True, post=(params_bound_to_nodes,))(
    lambda g, ctx: substitute_sparse(
        g, ctx.masks, ctx.structures, max_bands=ctx.max_bands
    )
)
register_pass("fold_gathers", needs_masks=True, post=(params_bound_to_nodes,))(
    lambda g, ctx: fold_gathers(g)
)
register_pass("cse", post=(params_bound_to_nodes,))(lambda g, ctx: cse(g))
register_pass("fuse_elementwise", post=(params_bound_to_nodes,))(
    lambda g, ctx: fuse_elementwise(g)
)
register_pass("fuse_epilogue", post=(params_bound_to_nodes,))(
    lambda g, ctx: fuse_epilogue(g)
)
register_pass("quantize", needs_calibration=True, post=(params_bound_to_nodes,))(
    lambda g, ctx: quantize(
        g, ctx.calibration, skip=tuple(ctx.quant_skip),
        act_skip=tuple(ctx.act_quant_skip),
    )
)
register_pass("dce", post=(no_dead_nodes, params_bound_to_nodes))(lambda g, ctx: dce(g))


def optimize(
    g: Graph,
    masks: Optional[Dict[str, Any]] = None,
    structures: Optional[Dict[str, Structure]] = None,
    *,
    max_bands: int = 4,
    calibration: Optional[Any] = None,
    quant_skip: Tuple[str, ...] = (),
    act_quant_skip: Tuple[str, ...] = (),
    pipeline: Optional[Tuple[str, ...]] = None,
) -> Graph:
    """The full deployment pipeline (paper's compiler, end to end).

    Thin wrapper over :class:`~.pass_manager.PassManager` -- pass ``pipeline``
    to run a custom ordered subset of registered passes.  ``calibration`` (a
    :class:`~repro.quant.calibrate.CalibrationTable`; an empty one selects
    weight-only quantization) arms the ``quantize`` pass, which is skipped
    otherwise.
    """
    ctx = PassContext(
        masks=masks or {}, structures=structures or {}, max_bands=max_bands,
        calibration=calibration, quant_skip=tuple(quant_skip),
        act_quant_skip=tuple(act_quant_skip),
    )
    return PassManager(pipeline).run(g, ctx)
