"""LR (layer-wise representation) DSL -- the paper's computational-graph IR.

A :class:`Graph` is a topologically-ordered list of :class:`Node`; every node
names its inputs, carries static ``attrs``, and owns parameters in a separate
``params`` dict (pytree-friendly: the same Graph lowers with different weights,
e.g. dense vs pruned vs packed).  "Essentially this DSL is equivalent to the
computational graph" (paper section 3) -- ours is exactly that, with passes in
passes.py and JAX lowering in lowering.py.

Supported ops (enough for the paper's three apps + generic MLP stacks):

=================  =====================================================
op                 attrs / params
=================  =====================================================
input              shape, dtype
linear             params w[K,N], b[N]?; attrs activation?, epilogue?
sparse_linear      packed params (format-dependent); attrs format, bands…,
                   epilogue?
conv2d             params w[Co,Ci,kh,kw], b?, kept? (channelcompact: live
                   input-channel indices, Ci already compacted); attrs
                   stride, padding, groups, dilation, format?,
                   activation?, epilogue?
norm               attrs kind in {batch, instance, layer}; params
                   scale, bias (+ mean, var for batch)
activation         attrs fn
add / mul          (binary, elementwise)
concat             attrs axis
pixel_shuffle      attrs factor       (super-resolution upsampling)
upsample           attrs factor       (nearest)
pad_reflect        attrs pad
gather_channels    attrs idx          (compaction glue, foldable)
=================  =====================================================
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["Node", "Graph"]


@dataclasses.dataclass
class Node:
    op: str
    name: str
    inputs: Tuple[str, ...] = ()
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def replace(self, **kw) -> "Node":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class Graph:
    nodes: List[Node]
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    #: {node_name: {param_name: array}} -- kept outside nodes so the same
    #: graph structure lowers against dense, masked or packed weights.
    params: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def consumers(self, name: str) -> List[Node]:
        return [n for n in self.nodes if name in n.inputs]

    def validate(self) -> None:
        seen = set(self.inputs)
        names = set()
        for n in self.nodes:
            if n.name in names:
                raise ValueError(f"duplicate node {n.name}")
            names.add(n.name)
            for i in n.inputs:
                if i not in seen and i not in names:
                    raise ValueError(f"node {n.name} uses undefined input {i!r}")
            seen.add(n.name)
        for o in self.outputs:
            if o not in seen:
                raise ValueError(f"undefined graph output {o!r}")

    def replace_node(self, name: str, new: Node) -> "Graph":
        nodes = [new if n.name == name else n for n in self.nodes]
        return dataclasses.replace(self, nodes=nodes)

    def without(self, names: set) -> "Graph":
        nodes = [n for n in self.nodes if n.name not in names]
        params = {k: v for k, v in self.params.items() if k not in names}
        return dataclasses.replace(self, nodes=nodes, params=params)

    def rewire(self, old: str, new: str) -> "Graph":
        """Point every consumer of ``old`` at ``new`` (and graph outputs)."""
        nodes = [
            n.replace(inputs=tuple(new if i == old else i for i in n.inputs))
            for n in self.nodes
        ]
        outputs = tuple(new if o == old else o for o in self.outputs)
        return dataclasses.replace(self, nodes=nodes, outputs=outputs)

    # ------------------------------------------------------------------ #
    def summary(self) -> str:
        lines = [f"Graph(inputs={self.inputs}, outputs={self.outputs})"]
        for n in self.nodes:
            np_ = self.params.get(n.name, {})
            pstr = ", ".join(f"{k}:{tuple(v.shape)}" for k, v in np_.items())
            lines.append(f"  {n.name:24s} {n.op:14s} <- {n.inputs} {n.attrs} [{pstr}]")
        return "\n".join(lines)


class GraphBuilder:
    """Tiny fluent helper used by models/cnn.py."""

    def __init__(self, input_names: Sequence[str]):
        self._nodes: List[Node] = []
        self._params: Dict[str, Dict[str, Any]] = {}
        self._inputs = tuple(input_names)
        self._n = 0

    def fresh(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}_{self._n}"

    def add(self, op: str, inputs, name: Optional[str] = None, params=None, **attrs) -> str:
        name = name or self.fresh(op)
        if isinstance(inputs, str):
            inputs = (inputs,)
        self._nodes.append(Node(op=op, name=name, inputs=tuple(inputs), attrs=attrs))
        if params:
            self._params[name] = dict(params)
        return name

    def build(self, outputs) -> Graph:
        if isinstance(outputs, str):
            outputs = (outputs,)
        g = Graph(
            nodes=self._nodes,
            inputs=self._inputs,
            outputs=tuple(outputs),
            params=self._params,
        )
        g.validate()
        return g
