"""Execution plans: op-registry compilation of LR graphs.

Replaces the monolithic if/elif interpreter that ``lowering.lower`` used to
be.  Compilation (:func:`compile_plan`) happens once per graph:

1. **handler resolution** -- every node op is looked up in the op registry
   (:func:`register_op`); unknown ops fail at *compile* time, not mid-run.
   Two handler sets exist: ``kernel`` (Pallas-backed GEMMs) and ``reference``
   (pure jnp, the XLA-native baseline).
2. **topological scheduling** -- Kahn's algorithm with graph order as the
   tiebreak, so plans execute correctly even if the node list was built out
   of order.
3. **buffer liveness** -- each step records which intermediates die after it
   (last use), and execution frees them immediately; peak-resident bytes can
   be estimated ahead of time via :meth:`ExecutionPlan.memory_estimate`
   (abstract eval, no FLOP spent).

The resulting :class:`ExecutionPlan` is callable as
``plan(params, *inputs)`` -- the exact contract of the old ``lower()`` --
and jits/grads/pjits like any JAX function.  Register new ops with::

    @register_op("my_op")
    def _my_op(p, xs, attrs, rt):
        return ...

Handlers take ``(params_dict, input_arrays, attrs, runtime)`` and return the
node's output array.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels import ops as kops
from ...kernels import ref as kref
from ...obs import metrics as _metrics
from ...obs import trace as _otrace
from ...robustness import faults as _faults
from ...robustness.breaker import GuardConfig, NumericGuardError
from .ir import Graph, Node

__all__ = [
    "BACKENDS",
    "EXEC_BACKENDS",
    "register_op",
    "registered_ops",
    "handlers_for",
    "guard_fallback_counts",
    "reset_guard_fallbacks",
    "Runtime",
    "Step",
    "ExecutionPlan",
    "BatchedPlan",
    "compile_plan",
]

_ACT = kref._ACT

#: ``kernel``: Pallas-backed GEMMs.  ``reference``: pure jnp (XLA baseline +
#: parity oracle).  ``quant``: the kernel set *overlaid* with the INT8
#: handlers -- the only backend that executes ``qlinear`` nodes with the
#: quantized Pallas kernels (selection mode for post-``quantize``-pass
#: plans); non-quantized ops fall through to their kernel handlers.
BACKENDS = ("kernel", "reference", "quant")

#: executable backends: the registration backends plus ``guarded`` -- a
#: policy backend (no handler table of its own) that tries a primary table
#: (``quant`` overlay by default) per step and demotes failures to the
#: ``reference`` handler under circuit breakers.  See ``_exec_guarded``.
EXEC_BACKENDS = BACKENDS + ("guarded",)

#: backend -> op -> handler(params, inputs, attrs, runtime) -> array
_HANDLERS: Dict[str, Dict[str, Callable]] = {b: {} for b in BACKENDS}


def handlers_for(backend: str) -> Dict[str, Callable]:
    """The effective handler table for ``backend`` (``quant`` inherits every
    kernel handler and overrides/extends with the quantized set; ``guarded``
    resolves to its default primary table -- the same overlay)."""
    if backend in ("quant", "guarded"):
        return {**_HANDLERS["kernel"], **_HANDLERS["quant"]}
    return dict(_HANDLERS[backend])


# --------------------------------------------------------------------------- #
# guarded-execution accounting (process-wide, mirrors conv_fallback_counts)    #
# --------------------------------------------------------------------------- #
#
# Process-wide demotion counts live in the metrics registry as the
# ``guard_demotions_total{op, scheme, reason}`` counter family (reason in
# {exception, numeric, breaker_open}); the per-plan breakdown lives in
# ``ExecutionPlan.guard_stats()``.  The accessors below are back-compat
# *views* over the registry.

_GUARD_METRIC = "guard_demotions_total"


def guard_fallback_counts() -> Dict[str, int]:
    """Process-wide guarded-executor demotion counts, keyed
    ``"op/scheme/reason"`` -- the guarded-backend sibling of
    :func:`repro.kernels.ops.conv_fallback_counts`.  A view over the
    ``guard_demotions_total`` registry family."""
    counts = _metrics.registry().label_counts(
        _GUARD_METRIC, "op", "scheme", "reason"
    )
    return {k: int(v) for k, v in counts.items()}


def reset_guard_fallbacks() -> None:
    _metrics.registry().reset(_GUARD_METRIC)


def _node_scheme(n: Node) -> str:
    """The quantization scheme a node executes under -- the breaker-key
    dimension that separates an INT8 kernel family from its f32 sibling."""
    if n.op in ("qlinear", "qconv2d"):
        s = n.attrs.get("scheme")
        if s:
            return s
        return "w8a8" if n.attrs.get("x_scale") is not None else "w8"
    return "f32"


def _check_finite(y) -> None:
    """Post-step numeric guard: raise :class:`NumericGuardError` when any
    concrete inexact leaf of ``y`` contains NaN/Inf.  Tracers (jit/vmap
    tracing) are skipped -- the guard is an eager-mode contract."""
    for leaf in jax.tree.leaves(y):
        if isinstance(leaf, jax.core.Tracer):
            continue
        if jnp.issubdtype(jnp.result_type(leaf), jnp.inexact) and not bool(
            jnp.all(jnp.isfinite(leaf))
        ):
            raise NumericGuardError("non-finite values in step output")


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-time knobs threaded to every handler."""

    backend: str
    interpret: Optional[bool] = None


def register_op(op: str, backends: Sequence[str] = BACKENDS):
    """Decorator: register an op handler for one or more backends."""

    def deco(fn: Callable) -> Callable:
        for b in backends:
            if b not in _HANDLERS:
                raise ValueError(f"unknown backend {b!r}")
            _HANDLERS[b][op] = fn
        return fn

    return deco


def registered_ops(backend: str = "kernel") -> List[str]:
    return sorted(handlers_for(backend))


# --------------------------------------------------------------------------- #
# epilogue programs (attached by the fuse_epilogue pass)                       #
# --------------------------------------------------------------------------- #
#
# A GEMM/conv node may carry an ``epilogue`` attr: a tuple of steps run on its
# output after bias + the fused ``activation`` attr.  Side-operand slots index
# the *node's own inputs* (like fused_elementwise steps index its inputs), and
# layer/instance-norm scale/bias live in the node's params under
# ``{pkey}_scale`` / ``{pkey}_bias``:
#
#   ("activation", fn) | ("add", j) | ("mul", j)
#   ("norm_layer", pkey, eps) | ("norm_instance", pkey, eps)


def _steps_local(steps, xs, p):
    """Resolve graph-form steps (side slots indexing the node's inputs, norm
    scale/bias under ``{pkey}_scale``/``{pkey}_bias`` params) into the
    kernel-local form shared with :func:`kref.apply_steps_ref` and the Pallas
    kernels: ``(steps, sides, norm_params)`` with renumbered slots."""
    out, sides, norms = [], [], []
    for step in steps:
        kind = step[0]
        if kind == "activation":
            out.append(step)
        elif kind in ("add", "mul"):
            sides.append(xs[step[1]])
            out.append((kind, len(sides) - 1))
        elif kind in ("norm_layer", "norm_instance"):
            pkey, eps = step[1], step[2]
            norms.append((p[f"{pkey}_scale"], p[f"{pkey}_bias"]))
            out.append(
                ("norm" if kind == "norm_layer" else kind, len(norms) - 1, eps)
            )
        elif kind == "norm_rms":  # decoder RMSNorm: scale-only, no bias param
            pkey, eps = step[1], step[2]
            norms.append((p[f"{pkey}_scale"], None))
            out.append((kind, len(norms) - 1, eps))
        elif kind == "rope":  # position ids stream in as a side operand
            sides.append(xs[step[1]])
            out.append((kind, len(sides) - 1, step[2], step[3]))
        else:
            raise NotImplementedError(f"step {kind}")
    return out, sides, norms


def _apply_epilogue(y, epilogue, xs, p):
    """jnp fallback applier -- delegates to the shared step interpreter
    (identical math to the unfused op handlers, so reference-backend plans
    stay bit-exact with their unfused counterparts)."""
    if not epilogue:
        return y
    steps, sides, norms = _steps_local(epilogue, xs, p)
    return kref.apply_steps_ref(y, steps, sides, norms)


def _kernel_epilogue(epilogue, xs, out_shape):
    """Translate an epilogue into the Pallas matmul's kernel-local form:
    ``(steps, sides)`` with slots renumbered into ``sides``.  Returns
    ``(None, None)`` when the program cannot run tiled in-kernel (norm steps
    need whole rows; mismatched side shapes cannot be streamed per-tile) --
    callers then fall back to :func:`_apply_epilogue` after the GEMM."""
    steps, sides = [], []
    for step in epilogue:
        kind = step[0]
        if kind == "activation":
            steps.append(step)
        elif kind in ("add", "mul"):
            s = xs[step[1]]
            if tuple(s.shape) != tuple(out_shape):
                return None, None
            sides.append(s)
            steps.append((kind, len(sides) - 1))
        else:  # norm_layer / norm_instance: need full rows / spatial planes
            return None, None
    return tuple(steps), tuple(sides)


# --------------------------------------------------------------------------- #
# handlers: GEMM family (kernel vs reference differ)                           #
# --------------------------------------------------------------------------- #


@register_op("linear", backends=("kernel",))
def _linear_kernel(p, xs, a, rt):
    epi = a.get("epilogue") or ()
    out_shape = (*xs[0].shape[:-1], p["w"].shape[1])
    steps, sides = _kernel_epilogue(epi, xs, out_shape)
    if steps is None:  # not tile-fusable: run the GEMM, apply epilogue in jnp
        y = kops.matmul(
            xs[0], p["w"], p.get("b"), activation=a.get("activation"),
            interpret=rt.interpret,
        )
        return _apply_epilogue(y, epi, xs, p)
    return kops.matmul(
        xs[0], p["w"], p.get("b"), activation=a.get("activation"),
        epilogue=steps, epilogue_sides=sides, interpret=rt.interpret,
    )


@register_op("linear", backends=("reference",))
def _linear_ref(p, xs, a, rt):
    y = kref.matmul_ref(xs[0], p["w"], p.get("b"), activation=a.get("activation"))
    return _apply_epilogue(y, a.get("epilogue") or (), xs, p)


@register_op("sparse_linear", backends=("kernel",))
def _sparse_linear_kernel(p, xs, a, rt):
    fmt = a["format"]
    epi = a.get("epilogue") or ()
    if fmt in ("colcompact", "channelcompact"):
        values = p["values"]
        out_shape = (*xs[0].shape[:-1], values.shape[1])
        steps, sides = _kernel_epilogue(epi, xs, out_shape)
        kw = dict(activation=a.get("activation"), interpret=rt.interpret)
        if steps is not None:
            kw.update(epilogue=steps, epilogue_sides=sides)
        if fmt == "colcompact":
            y = kops.col_matmul(xs[0], values, p["kept"], p.get("b"), **kw)
        else:
            y = kops.matmul(xs[0], values, p.get("b"), **kw)
        return y if steps is not None else _apply_epilogue(y, epi, xs, p)
    if fmt == "pbcsr":
        # band-dispatched kernel: tile-fusable epilogues run on the f32
        # accumulator inside each band's kernel (sides sliced per band);
        # norm steps / broadcast sides fall back to the jnp tail
        nb, _, _, bn = p["values"].shape
        out_shape = (*xs[0].shape[:-1], nb * bn)
        steps, sides = _kernel_epilogue(epi, xs, out_shape)
        kw = dict(
            activation=a.get("activation"), bands=a.get("bands"),
            interpret=rt.interpret,
        )
        if steps is not None:
            kw.update(epilogue=steps, epilogue_sides=sides)
        y = kops.bsr_matmul(xs[0], p["values"], p["block_rows"], p.get("b"), **kw)
        return y if steps is not None else _apply_epilogue(y, epi, xs, p)
    raise NotImplementedError(f"sparse format {fmt}")


@register_op("sparse_linear", backends=("reference",))
def _sparse_linear_ref(p, xs, a, rt):
    fmt = a["format"]
    if fmt == "colcompact":
        y = kref.matmul_ref(
            jnp.take(xs[0], p["kept"], axis=-1), p["values"], p.get("b"),
            activation=a.get("activation"),
        )
    elif fmt == "channelcompact":
        y = kref.matmul_ref(
            xs[0], p["values"], p.get("b"), activation=a.get("activation")
        )
    elif fmt == "pbcsr":
        x = xs[0]
        y = kref.bsr_matmul_ref(
            x.reshape(-1, x.shape[-1]), p["values"], p["block_rows"], p.get("b"),
            activation=a.get("activation"),
        ).reshape(*x.shape[:-1], -1)
    else:
        raise NotImplementedError(f"sparse format {fmt}")
    return _apply_epilogue(y, a.get("epilogue") or (), xs, p)


# --------------------------------------------------------------------------- #
# handlers: quantized GEMM family (produced by the ``quantize`` pass)          #
# --------------------------------------------------------------------------- #
#
# ``qlinear`` node contract -- params: ``values`` int8 [K', N] (+ ``kept``
# for colcompact, ``b`` f32), ``w_scale`` f32 [N]; attrs: ``format`` in
# {dense, colcompact, channelcompact}, ``scheme`` in {w8, w8a8} (+
# ``x_scale`` float when w8a8), plus the usual activation/epilogue attrs and
# a ``bytes_saved`` annotation from the pass.


@register_op("qlinear", backends=("quant",))
def _qlinear_quant(p, xs, a, rt):
    """INT8 Pallas path: W8A8 (int32 MXU accumulation) when the node carries
    a calibrated activation scale, else W8-only (per-tile VMEM dequant)."""
    x = xs[0]
    if a.get("format") == "colcompact":
        x = jnp.take(x, p["kept"], axis=-1)
    epi = a.get("epilogue") or ()
    out_shape = (*xs[0].shape[:-1], p["values"].shape[1])
    steps, sides = _kernel_epilogue(epi, xs, out_shape)
    kw = dict(
        x_scale=a.get("x_scale"), activation=a.get("activation"),
        interpret=rt.interpret, _format=a.get("format", "dense"),
    )
    if steps is not None:
        kw.update(epilogue=steps, epilogue_sides=sides)
    y = kops.qmatmul(x, p["values"], p["w_scale"], p.get("b"), **kw)
    return y if steps is not None else _apply_epilogue(y, epi, xs, p)


@register_op("qlinear", backends=("reference",))
def _qlinear_ref(p, xs, a, rt):
    """jnp oracle: dequantized weights (and fake-quantized activations for
    w8a8) through the f32 reference GEMM -- simulates the kernel's integer
    math bit-closely, and gives memory_estimate an abstract-evalable body."""
    x = xs[0]
    if a.get("format") == "colcompact":
        x = jnp.take(x, p["kept"], axis=-1)
    y = kref.qmatmul_ref(
        x, p["values"], p["w_scale"], p.get("b"),
        x_scale=a.get("x_scale"), activation=a.get("activation"),
    )
    return _apply_epilogue(y, a.get("epilogue") or (), xs, p)


def _conv_call_kwargs(p, a, rt):
    """Shared kwarg plumbing for the conv kernel handlers."""
    return dict(
        stride=a.get("stride", 1), padding=a.get("padding", "SAME"),
        groups=a.get("groups", 1), dilation=a.get("dilation", 1),
        kept=p.get("kept"), activation=a.get("activation"),
        interpret=rt.interpret, _format=a.get("format", "dense"),
    )


def _conv_out_shape(p, xs, a, wkey="w"):
    x, w = xs[0], p[wkey]
    oh, ow = kops.conv_out_hw(
        x.shape[2], x.shape[3], w.shape[2], w.shape[3],
        a.get("stride", 1), a.get("padding", "SAME"),
    )
    return (x.shape[0], w.shape[0], oh, ow)


@register_op("conv2d", backends=("kernel",))
def _conv2d_kernel(p, xs, a, rt):
    """Pallas implicit-GEMM path: tile-fusable epilogue steps (activation /
    add / mul with output-shaped sides) run on the f32 accumulator inside
    the kernel; norm steps and broadcast sides keep the jnp tail.  Channel-
    pruned convs (``format="channelcompact"``, ``kept`` param) contract only
    the surviving input channels.  Unsupported configs (groups, dilation,
    VMEM overflow) auto-fall back to lax.conv inside the wrapper."""
    epi = a.get("epilogue") or ()
    steps, sides = _kernel_epilogue(epi, xs, _conv_out_shape(p, xs, a))
    kw = _conv_call_kwargs(p, a, rt)
    if steps is not None:
        kw.update(epilogue=steps, epilogue_sides=sides)
    y = kops.conv2d(xs[0], p["w"], p.get("b"), **kw)
    return y if steps is not None else _apply_epilogue(y, epi, xs, p)


@register_op("conv2d", backends=("reference",))
def _conv2d_ref(p, xs, a, rt):
    """jnp oracle: lax.conv at f32 accumulation (+ the channel gather for
    pruned convs), epilogue as a jnp tail."""
    x = xs[0]
    if p.get("kept") is not None:
        x = jnp.take(x, p["kept"], axis=1)
    y = kref.conv2d_ref(
        x, p["w"], p.get("b"), stride=a.get("stride", 1),
        padding=a.get("padding", "SAME"), groups=a.get("groups", 1),
        dilation=a.get("dilation", 1), activation=a.get("activation"),
    )
    return _apply_epilogue(y, a.get("epilogue") or (), xs, p)


@register_op("qconv2d", backends=("quant",))
def _qconv2d_quant(p, xs, a, rt):
    """INT8 Pallas conv: W8A8 (int8 patches x int8 filters -> int32 MXU
    accumulation) when the node carries a calibrated activation scale, else
    W8-only (filter tiles dequantized in VMEM) -- replacing the old
    dequant-to-f32-then-lax.conv path, so the f32 weight copy never
    materializes in HBM."""
    epi = a.get("epilogue") or ()
    steps, sides = _kernel_epilogue(epi, xs, _conv_out_shape(p, xs, a, "values"))
    kw = _conv_call_kwargs(p, a, rt)
    kw.update(w_scale=p["w_scale"], x_scale=a.get("x_scale"))
    if steps is not None:
        kw.update(epilogue=steps, epilogue_sides=sides)
    y = kops.conv2d(xs[0], p["values"], p.get("b"), **kw)
    return y if steps is not None else _apply_epilogue(y, epi, xs, p)


@register_op("qconv2d", backends=("reference",))
def _qconv2d_ref(p, xs, a, rt):
    """jnp oracle: dequantized filters (and fake-quantized activations for
    w8a8) through the f32 reference conv."""
    x = xs[0]
    if p.get("kept") is not None:
        x = jnp.take(x, p["kept"], axis=1)
    y = kref.qconv2d_ref(
        x, p["values"], p["w_scale"], p.get("b"), x_scale=a.get("x_scale"),
        stride=a.get("stride", 1), padding=a.get("padding", "SAME"),
        groups=a.get("groups", 1), dilation=a.get("dilation", 1),
        activation=a.get("activation"),
    )
    return _apply_epilogue(y, a.get("epilogue") or (), xs, p)


# --------------------------------------------------------------------------- #
# handlers: shared ops (same implementation on both backends)                  #
# --------------------------------------------------------------------------- #


@register_op("norm")
def _norm(p, xs, a, rt):
    kind = a["kind"]
    eps = a.get("eps", 1e-5)
    x = xs[0]
    if kind == "batch":  # inference: stored stats, per-channel (C of NCHW)
        s = p["scale"] / jnp.sqrt(p["var"] + eps)
        return (x - p["mean"][None, :, None, None]) * s[None, :, None, None] + p[
            "bias"
        ][None, :, None, None]
    if kind == "instance":  # per (N, C) over spatial
        mu = x.mean(axis=(2, 3), keepdims=True)
        var = x.var(axis=(2, 3), keepdims=True)
        y = (x - mu) / jnp.sqrt(var + eps)
        return y * p["scale"][None, :, None, None] + p["bias"][None, :, None, None]
    if kind == "layer":  # over last dim
        mu = x.mean(axis=-1, keepdims=True)
        var = x.var(axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + eps) * p["scale"] + p["bias"]
    raise NotImplementedError(kind)


@register_op("activation")
def _activation(p, xs, a, rt):
    return _ACT[a["fn"]](xs[0])


@register_op("add")
def _add(p, xs, a, rt):
    return xs[0] + xs[1]


@register_op("mul")
def _mul(p, xs, a, rt):
    return xs[0] * xs[1]


@register_op("fused_elementwise", backends=("reference",))
def _fused_elementwise(p, xs, a, rt):
    """jnp step interpreter: the parity oracle for the Pallas kernel (and
    the XLA-native baseline -- one HBM round-trip *per step*)."""
    steps, sides, norms = _steps_local(a["steps"], xs, p)
    return kref.apply_steps_ref(xs[0], steps, sides, norms)


@register_op("fused_elementwise", backends=("kernel",))
def _fused_elementwise_kernel(p, xs, a, rt):
    """One VMEM-resident Pallas pass over the whole step program: one HBM
    read + write total.  Falls back to the jnp interpreter when the tiled
    kernel cannot express the node (broadcast sides, rank < 2, non-vector
    norm params)."""
    x = xs[0]
    if x.ndim < 2 or any(s.shape != x.shape for s in xs[1:]):
        return _fused_elementwise(p, xs, a, rt)
    steps, sides, norms = _steps_local(a["steps"], xs, p)
    if any(st[0] == "norm_instance" for st in steps) or any(
        s.ndim != 1 or s.shape[-1] != x.shape[-1] for pair in norms for s in pair
    ):
        return _fused_elementwise(p, xs, a, rt)
    return kops.fused_elementwise(x, sides, tuple(steps), norms, interpret=rt.interpret)


@register_op("concat")
def _concat(p, xs, a, rt):
    return jnp.concatenate(xs, axis=a.get("axis", 1))


@register_op("pixel_shuffle")
def _pixel_shuffle(p, xs, a, rt):
    x, r = xs[0], a["factor"]
    n, c, h, w = x.shape
    x = x.reshape(n, c // (r * r), r, r, h, w)
    x = x.transpose(0, 1, 4, 2, 5, 3)
    return x.reshape(n, c // (r * r), h * r, w * r)


@register_op("upsample")
def _upsample(p, xs, a, rt):
    r = a["factor"]
    return jnp.repeat(jnp.repeat(xs[0], r, axis=2), r, axis=3)


@register_op("pad_reflect")
def _pad_reflect(p, xs, a, rt):
    pd = a["pad"]
    return jnp.pad(xs[0], ((0, 0), (0, 0), (pd, pd), (pd, pd)), mode="reflect")


@register_op("gather_channels")
def _gather_channels(p, xs, a, rt):
    axis = a.get("axis", -1)
    idx = jnp.asarray(np.asarray(a["idx"]))
    x = xs[0]
    if a["mode"] == "gather":
        return jnp.take(x, idx, axis=axis)
    # scatter back to width n along axis
    if axis in (-1, x.ndim - 1):
        shp = x.shape[:-1] + (a["n"],)
        return jnp.zeros(shp, x.dtype).at[..., idx].set(x)
    if axis == 1:
        shp = (x.shape[0], a["n"]) + x.shape[2:]
        return jnp.zeros(shp, x.dtype).at[:, idx].set(x)
    raise NotImplementedError(axis)


@register_op("global_avg_pool")
def _global_avg_pool(p, xs, a, rt):
    return xs[0].mean(axis=(2, 3))


@register_op("broadcast_spatial")
def _broadcast_spatial(p, xs, a, rt):
    # fuse a [N, C] global feature into a [N, C, H, W] map
    return jnp.broadcast_to(
        xs[0][:, :, None, None],
        (xs[0].shape[0], xs[0].shape[1], xs[1].shape[2], xs[1].shape[3]),
    )


# --------------------------------------------------------------------------- #
# handlers: decoder-block ops (the transformer lowering)                       #
# --------------------------------------------------------------------------- #
#
# Node contracts (see models/transformer_graph.py, the builder):
#
#   embed      in (tokens [B, S] i32),              params {table [V, D]}
#   rmsnorm    in (x [..., D]),                     params {scale [D]}, attrs eps
#   rope       in (x [..., S, H*dh], pos [..., S]), attrs heads, theta
#   attention  phase="prefill": in (q, k, v [B, S, H|G * dh], lengths [B])
#              phase="decode":  in (q [B, 1, H*dh], k_new, v_new [B, 1, G*dh],
#                                   k_ctx, v_ctx [B, L, S, G, dh], lengths [B])
#              attrs n_heads, n_kv_heads (+ layer for decode)
#   ffn        in (x [..., D]),  params {w_gate, w_up [D, F]}, attrs activation
#   unembed    in (x [..., D]),  params {w [D, V_pad]}, attrs vocab
#
# ``lengths`` is the live token count per row: prefill masks each row to its
# own prompt (the batch is padded to a common S), decode masks the gathered
# page span and places the new token at slot == length (so the valid prefix
# stays contiguous -- exactly ``gqa_decode_step``'s slot = pos semantics).


def _attn_heads(q, k, v, a):
    """[B, S, H*dh] projections -> [B, H, S, dh] with KV groups repeated to
    the query head count (GQA: head gi*rep+ri reads group gi, matching the
    ``q.reshape(b, s, g, rep, dh)`` grouping in models/attention.py)."""
    h, g = a["n_heads"], a["n_kv_heads"]
    b, s, hd = q.shape
    dh = hd // h
    qh = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    kh = k.reshape(b, k.shape[1], g, dh).transpose(0, 2, 1, 3)
    vh = v.reshape(b, v.shape[1], g, dh).transpose(0, 2, 1, 3)
    if g != h:
        kh = jnp.repeat(kh, h // g, axis=1)
        vh = jnp.repeat(vh, h // g, axis=1)
    return qh, kh, vh, (b, s, hd)


def _attn_decode_merge(xs, a):
    """Merge the step's fresh k/v into the gathered cache span at
    slot == length, then head-split.  Returns (qh, kh, vh, shape, lengths+1)."""
    q, k_new, v_new, k_ctx, v_ctx, lengths = xs
    g = a["n_kv_heads"]
    dh = k_new.shape[-1] // g
    kc = k_ctx[:, a["layer"]]  # [B, S, G, dh]
    vc = v_ctx[:, a["layer"]]
    b, s_ctx = kc.shape[0], kc.shape[1]
    slot = (
        jnp.arange(s_ctx, dtype=jnp.int32)[None, :, None, None]
        == lengths[:, None, None, None]
    )
    k = jnp.where(slot, k_new.reshape(b, 1, g, dh), kc).reshape(b, s_ctx, -1)
    v = jnp.where(slot, v_new.reshape(b, 1, g, dh), vc).reshape(b, s_ctx, -1)
    qh, kh, vh, shape = _attn_heads(q, k, v, a)
    return qh, kh, vh, shape, lengths + 1


@register_op("attention", backends=("kernel",))
def _attention_kernel(p, xs, a, rt):
    """Flash-attention Pallas path.  Decode pads its single query row up to
    one (8-row) block; the valid-prefix mask keeps padded KV slots inert."""
    if a.get("phase") == "decode":
        qh, kh, vh, (b, s, hd), lens = _attn_decode_merge(xs, a)
        out = kops.attention(
            qh, kh, vh, lens, causal=False, block_q=8,
            interpret=rt.interpret,
        )
    else:
        q, k, v, lengths = xs
        qh, kh, vh, (b, s, hd) = _attn_heads(q, k, v, a)
        out = kops.attention(
            qh, kh, vh, lengths, causal=True, interpret=rt.interpret
        )
    return out.transpose(0, 2, 1, 3).reshape(b, s, hd)


@register_op("attention", backends=("reference",))
def _attention_ref(p, xs, a, rt):
    """jnp oracle (naive masked softmax at f32) -- also the abstract-eval
    body memory_estimate uses."""
    if a.get("phase") == "decode":
        qh, kh, vh, (b, s, hd), lens = _attn_decode_merge(xs, a)
        out = kref.flash_attention_ref(qh, kh, vh, lens, causal=False)
    else:
        q, k, v, lengths = xs
        qh, kh, vh, (b, s, hd) = _attn_heads(q, k, v, a)
        out = kref.flash_attention_ref(qh, kh, vh, lengths, causal=True)
    return out.transpose(0, 2, 1, 3).reshape(b, s, hd)


@register_op("embed")
def _embed(p, xs, a, rt):
    return jnp.take(p["table"], xs[0], axis=0)


@register_op("rmsnorm")
def _rmsnorm(p, xs, a, rt):
    # identical math to models/layers.rmsnorm: f32 compute, cast back
    # *before* the scale multiply
    x = xs[0]
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + a.get("eps", 1e-6))).astype(x.dtype) * p[
        "scale"
    ]


@register_op("rope")
def _rope(p, xs, a, rt):
    return kref.rope_ref(xs[0], xs[1], a["heads"], a.get("theta", 10000.0))


@register_op("ffn", backends=("kernel",))
def _ffn_kernel(p, xs, a, rt):
    return kops.ffn_gateup(
        xs[0], p["w_gate"], p["w_up"],
        activation=a.get("activation", "silu"), interpret=rt.interpret,
    )


@register_op("ffn", backends=("reference",))
def _ffn_ref(p, xs, a, rt):
    return kref.ffn_gateup_ref(
        xs[0], p["w_gate"], p["w_up"], activation=a.get("activation", "silu")
    )


@register_op("unembed")
def _unembed(p, xs, a, rt):
    # model-dtype matmul, pad-vocab classes masked: bit-identical to
    # transformer._unembed with w materialized as embed.table.T at build time
    logits = xs[0] @ p["w"]
    v, vp = a["vocab"], p["w"].shape[1]
    if v != vp:
        logits = jnp.where(
            jnp.arange(vp) < v, logits, jnp.asarray(-1e30, logits.dtype)
        )
    return logits


# --------------------------------------------------------------------------- #
# plan compilation                                                             #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Step:
    node: Node
    #: intermediate buffers whose last use is this step (freed right after)
    frees: Tuple[str, ...] = ()


def _topo_schedule(g: Graph) -> List[Node]:
    """Kahn's algorithm; original node order breaks ties (stable)."""
    defined = set(g.inputs)
    pending = list(g.nodes)
    order: List[Node] = []
    while pending:
        for i, n in enumerate(pending):
            if all(x in defined for x in n.inputs):
                order.append(n)
                defined.add(n.name)
                del pending[i]
                break
        else:
            names = [n.name for n in pending]
            raise ValueError(f"graph has a cycle or undefined inputs: {names}")
    return order


@dataclasses.dataclass(eq=False)
class ExecutionPlan:
    """A compiled, topologically scheduled program over registered op
    handlers.  Callable: ``plan(params, *inputs) -> outputs``."""

    graph: Graph
    steps: Tuple[Step, ...]
    backend: str
    interpret: Optional[bool] = None
    #: guarded-backend knobs; only meaningful (and auto-defaulted) when
    #: ``backend == "guarded"``
    guard: Optional[GuardConfig] = None

    def __post_init__(self):
        self._rt = Runtime(backend=self.backend, interpret=self.interpret)
        if self.backend == "guarded":
            if self.guard is None:
                self.guard = GuardConfig()
            self._handlers = handlers_for(self.guard.primary)
            self._ref_handlers = handlers_for("reference")
            self._guard_lock = threading.Lock()
            #: (op, scheme) -> CircuitBreaker, created lazily per step family
            self._breakers: Dict[Tuple[str, str], Any] = {}
            self.guard_counters: Dict[str, Any] = {
                "primary_ok": 0,
                "fallbacks": 0,
                "breaker_short_circuits": 0,
                "numeric_guard_trips": 0,
                "by_key": {},
            }
        else:
            if self.guard is not None:
                raise ValueError(
                    "guard config requires backend='guarded', "
                    f"got {self.backend!r}"
                )
            self._handlers = handlers_for(self.backend)

    # -- execution ----------------------------------------------------------- #
    def __call__(self, params: Dict[str, Dict[str, Any]], *args):
        return self.run_steps(params, *args)

    def run_steps(
        self,
        params: Dict[str, Dict[str, Any]],
        *args,
        observer: Optional[Callable[[str, Any], None]] = None,
    ):
        """Execute the plan; ``observer(name, value)`` (if given) sees every
        graph input and node output as it is produced -- the calibration hook
        used by :func:`repro.quant.calibrate.calibrate_plan`."""
        if len(args) != len(self.graph.inputs):
            raise TypeError(
                f"plan expects {len(self.graph.inputs)} inputs "
                f"{self.graph.inputs}, got {len(args)}"
            )
        env: Dict[str, Any] = dict(zip(self.graph.inputs, args))
        if observer is not None:
            for name, v in env.items():
                observer(name, v)
        guarded = self.backend == "guarded"
        if _otrace.enabled():  # one branch per run when tracing is off
            return self._run_steps_traced(env, params, observer, guarded)
        for step in self.steps:
            n = step.node
            xs = [env[i] for i in n.inputs]
            p = params.get(n.name, {})
            if guarded:
                env[n.name] = self._exec_guarded(n, p, xs)
            else:
                env[n.name] = self._handlers[n.op](p, xs, n.attrs, self._rt)
            if observer is not None:
                observer(n.name, env[n.name])
            for f in step.frees:  # dead intermediate: release our reference
                del env[f]
        outs = tuple(env[o] for o in self.graph.outputs)
        return outs[0] if len(outs) == 1 else outs

    def _run_steps_traced(self, env, params, observer, guarded):
        """The traced twin of the ``run_steps`` loop: one ``cat="plan"``
        span around the run, one ``cat="step"`` span per step carrying op /
        scheme / backend / output shape, demotions annotated in-span (the
        ``demoted`` arg + a nested ``cat="guard"`` instant)."""
        with _otrace.span(
            "plan", cat="plan", backend=self.backend, steps=len(self.steps),
            outputs=list(self.graph.outputs),
        ):
            for step in self.steps:
                n = step.node
                xs = [env[i] for i in n.inputs]
                p = params.get(n.name, {})
                with _otrace.span(
                    n.name, cat="step", op=n.op, scheme=_node_scheme(n),
                    backend=self.backend,
                ) as sp:
                    if guarded:
                        y = self._exec_guarded(n, p, xs, sp)
                    else:
                        y = self._handlers[n.op](p, xs, n.attrs, self._rt)
                    shape = jnp.shape(y)
                    if all(isinstance(d, int) for d in shape):
                        sp.set("out_shape", list(shape))
                env[n.name] = y
                if observer is not None:
                    observer(n.name, y)
                for f in step.frees:
                    del env[f]
        outs = tuple(env[o] for o in self.graph.outputs)
        return outs[0] if len(outs) == 1 else outs

    # -- guarded execution ---------------------------------------------------- #
    def _exec_guarded(self, n: Node, p, xs, sp=_otrace.NULL_SPAN):
        """One step under the guarded contract: try the primary (kernel)
        handler behind the step family's circuit breaker and fault-injection
        hook; on any exception or a numeric-guard trip, record the failure
        and demote to the ``reference`` handler for this step only.  Shared
        ops (same function object on both backends) run unguarded -- there
        is nothing to demote to."""
        cfg = self.guard
        ref = self._ref_handlers.get(n.op)
        primary = self._handlers.get(n.op, ref)
        if ref is None or primary is ref:
            return primary(p, xs, n.attrs, self._rt)
        key = (n.op, _node_scheme(n))
        with self._guard_lock:
            br = self._breakers.get(key)
            if br is None:
                br = self._breakers[key] = cfg.make_breaker()
            allowed = br.allow()
        if not allowed:
            self._count_guard(key, "breaker_open", sp)
            return ref(p, xs, n.attrs, self._rt)
        fn = _faults.wrap_handler(n.op, primary)
        try:
            y = fn(p, xs, n.attrs, self._rt)
            if cfg.numeric_guards:
                _check_finite(y)
        except Exception as e:  # demote: any failure mode, never propagate
            with self._guard_lock:
                br.record_failure()
            self._count_guard(
                key,
                "numeric" if isinstance(e, NumericGuardError) else "exception",
                sp,
            )
            return ref(p, xs, n.attrs, self._rt)
        with self._guard_lock:
            br.record_success()
            self.guard_counters["primary_ok"] += 1
        return y

    def _count_guard(
        self, key: Tuple[str, str], reason: str, sp=_otrace.NULL_SPAN
    ) -> None:
        gkey = f"{key[0]}/{key[1]}/{reason}"
        with self._guard_lock:
            c = self.guard_counters
            c["fallbacks"] += 1
            if reason == "breaker_open":
                c["breaker_short_circuits"] += 1
            elif reason == "numeric":
                c["numeric_guard_trips"] += 1
            c["by_key"][gkey] = c["by_key"].get(gkey, 0) + 1
        _metrics.registry().counter(
            _GUARD_METRIC, op=key[0], scheme=key[1], reason=reason
        ).inc()
        if _otrace.enabled():
            sp.set("demoted", reason)  # annotate the enclosing step span
            _otrace.instant(
                f"demote:{key[0]}", cat="guard", scheme=key[1], reason=reason
            )

    def guard_stats(self) -> Dict[str, Any]:
        """Snapshot of this plan's guarded-execution state: demotion
        counters plus every breaker's state machine -- the payload
        ``AsyncPlanServer.health()`` surfaces per plan."""
        if self.backend != "guarded":
            return {}
        with self._guard_lock:
            c = self.guard_counters
            return {
                "counters": {
                    **{k: v for k, v in c.items() if k != "by_key"},
                    "by_key": dict(c["by_key"]),
                },
                "breakers": {
                    f"{op}/{scheme}": br.snapshot()
                    for (op, scheme), br in self._breakers.items()
                },
            }

    # -- introspection ------------------------------------------------------- #
    def memory_estimate(self, *inputs) -> Dict[str, Any]:
        """Peak-resident activation bytes under this schedule (abstract eval:
        no arrays are materialized).  ``inputs`` are arrays or
        ShapeDtypeStructs.  Params are counted as always-live."""
        structs = [
            x if isinstance(x, jax.ShapeDtypeStruct)
            else jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))
            for x in inputs
        ]
        pstructs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
            self.graph.params,
        )
        nbytes = lambda s: int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize if s.shape else np.dtype(s.dtype).itemsize
        param_bytes = sum(nbytes(v) for v in jax.tree.leaves(pstructs))
        # per-dtype breakdown: quantized plans show their int8 payloads here
        # (the storage win the quantize pass bought)
        param_bytes_by_dtype: Dict[str, int] = {}
        for v in jax.tree.leaves(pstructs):
            key = np.dtype(v.dtype).name
            param_bytes_by_dtype[key] = param_bytes_by_dtype.get(key, 0) + nbytes(v)
        weight_bytes_saved = sum(
            int(n.attrs.get("bytes_saved", 0)) for n in self.graph.nodes
        )
        env: Dict[str, Any] = dict(zip(self.graph.inputs, structs))
        # prefer jnp reference handlers (abstract-eval anywhere), but fall
        # back to the plan's own backend for ops registered only there
        handlers = {**handlers_for(self.backend), **_HANDLERS["reference"]}
        rt = Runtime(backend="reference", interpret=self.interpret)
        peak = live = sum(nbytes(s) for s in env.values())
        per_step = []
        # conv steps do their im2col in VMEM, never in HBM: account that
        # scratch as per-step VMEM-side working memory, not activation bytes
        vmem_workspace_by_step: Dict[str, int] = {}
        for step in self.steps:
            n = step.node
            out = jax.eval_shape(
                lambda p, xs: handlers[n.op](p, xs, n.attrs, rt),
                pstructs.get(n.name, {}),
                [env[i] for i in n.inputs],
            )
            if n.op in ("conv2d", "qconv2d"):
                ws = self._conv_workspace(n, pstructs.get(n.name, {}), env[n.inputs[0]])
                if ws:
                    vmem_workspace_by_step[n.name] = ws
            env[n.name] = out
            live += nbytes(out)
            peak = max(peak, live)
            for f in step.frees:
                live -= nbytes(env.pop(f))
            per_step.append((n.name, nbytes(out), live))
        return {
            "peak_activation_bytes": int(peak),
            "param_bytes": int(param_bytes),
            "param_bytes_by_dtype": param_bytes_by_dtype,
            "weight_bytes_saved": int(weight_bytes_saved),
            "peak_total_bytes": int(peak + param_bytes),
            "per_step": per_step,
            "peak_vmem_workspace_bytes": max(vmem_workspace_by_step.values(), default=0),
            "vmem_workspace_by_step": vmem_workspace_by_step,
            "out_structs": tuple(env[o] for o in self.graph.outputs),
        }

    def _conv_workspace(self, n: Node, pstruct, x_struct) -> int:
        """Per-grid-step VMEM working set of one conv step through the
        implicit-GEMM kernel (resident image slab + filter tile + im2col
        patch + accumulator), at the tuned blocks when known, else the
        defaults."""
        wkey = "w" if n.op == "conv2d" else "values"
        if wkey not in pstruct or getattr(x_struct, "ndim", 0) != 4:
            return 0
        w = pstruct[wkey]
        a = n.attrs
        c = int(pstruct["kept"].shape[0]) if "kept" in pstruct else int(x_struct.shape[1])
        stride, padding = a.get("stride", 1), a.get("padding", "SAME")
        kh, kw = int(w.shape[2]), int(w.shape[3])
        nb, o = int(x_struct.shape[0]), int(w.shape[0])
        w8a8 = a.get("scheme") == "w8a8" or a.get("x_scale") is not None
        x_item = 1 if w8a8 else np.dtype(x_struct.dtype).itemsize
        w_item = np.dtype(w.dtype).itemsize
        interp = (
            kops.interpret_default() if self.interpret is None else self.interpret
        )
        # a 1x1 conv elects the direct-GEMM fast path at lowering time:
        # no im2col, no resident image -- it owns no conv-kernel workspace
        if kops.conv_gemm1x1_elected(kh, kw, a.get("groups", 1), padding, c):
            return 0
        # a step outside the kernel's matrix executes through lax.conv and
        # owns no Pallas VMEM workspace
        if kops.conv_fallback_reason(
            c, int(x_struct.shape[2]), int(x_struct.shape[3]), kh, kw, stride,
            padding, groups=a.get("groups", 1), dilation=a.get("dilation", 1),
            interpret=interp, x_itemsize=x_item, w_itemsize=w_item,
        ) is not None:
            return 0
        cache = kops.tuning_cache()
        fmt = f"{a.get('format', 'dense')}+" + (
            "f32" if n.op == "conv2d" else ("w8a8" if w8a8 else "w8")
        ) + kops.conv_padding_token(padding)
        # the executing handler appends the epilogue suffix only when the
        # program runs in-tile (norm steps / broadcast sides lower without
        # it), which this shape-only walk cannot decide -- probe both keys
        fmts = [fmt]
        epi = a.get("epilogue") or ()
        if epi:
            n_sides = sum(s[0] in ("add", "mul") for s in epi)
            fmts.insert(0, fmt + f"+e{len(epi)}s{n_sides}")
        shape = (nb, c, x_struct.shape[2], x_struct.shape[3], o, kh, kw, stride)
        dtype = jnp.int8 if w8a8 else x_struct.dtype
        blocks = next(
            (
                b for f in fmts
                if (b := cache.lookup_nd("conv2d", shape, dtype, f, interp))
            ),
            # no tuned winner: the wrapper would seed the shape-aware default
            # (resident when it fits VMEM, else the tiled-K granularity)
            kops._conv_default_blocks(
                c, int(x_struct.shape[2]), int(x_struct.shape[3]), kh, kw,
                stride, padding, x_item, w_item, interp,
            ),
        )
        return kops.conv_vmem_workspace(
            c, int(x_struct.shape[2]), int(x_struct.shape[3]), kh, kw, stride,
            padding, *blocks, x_itemsize=x_item, w_itemsize=w_item,
        )["total"]

    def summary(self) -> str:
        lines = [
            f"ExecutionPlan(backend={self.backend}, steps={len(self.steps)}, "
            f"inputs={self.graph.inputs}, outputs={self.graph.outputs})"
        ]
        for s in self.steps:
            fr = f"  frees {s.frees}" if s.frees else ""
            lines.append(f"  {s.node.name:24s} {s.node.op:18s} <- {s.node.inputs}{fr}")
        return "\n".join(lines)

    # -- batched serving ------------------------------------------------------ #
    def batched(self, batch_size: int, *, via_vmap: bool = False) -> "BatchedPlan":
        """Fixed-batch throughput wrapper: pads the caller's leading axis to a
        ``batch_size`` multiple, executes one jitted chunk call per slice
        (single compilation for every chunk), and slices the padding off.
        ``via_vmap=True`` vmaps the plan over the chunk axis instead of
        relying on the ops' native leading-batch polymorphism -- needed for
        graphs whose input shapes carry no batch dim of their own."""
        return BatchedPlan(self, batch_size, via_vmap=via_vmap)


@dataclasses.dataclass(eq=False)
class BatchedPlan:
    """Serve arbitrary-size macro-batches through a fixed-shape compiled
    plan.  Callable exactly like the plan: ``bp(params, *inputs)`` where every
    input's leading axis is the request batch.  The remainder chunk is padded
    (zeros) and the padding discarded, so the jitted chunk function compiles
    once per plan, never per request count."""

    plan: ExecutionPlan
    batch_size: int
    via_vmap: bool = False

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        n_in = len(self.plan.graph.inputs)
        if self.plan.backend == "guarded":
            # guarded semantics (per-step try/except, breakers, numeric
            # guards) are eager-mode contracts -- tracing would bake one
            # arbitrary branch into the jitted chunk and blind the guards
            if self.via_vmap:
                raise ValueError(
                    "guarded plans execute eagerly; via_vmap needs tracing"
                )
            self._chunk = self.plan
        else:
            call = (
                jax.vmap(self.plan, in_axes=(None,) + (0,) * n_in)
                if self.via_vmap
                else self.plan
            )
            self._chunk = jax.jit(call)
        #: stats of the most recent __call__ (padding overhead is the serving
        #: cost of fixed-shape compilation; surfaced by PlanServer)
        self.last_stats: Dict[str, int] = {}
        #: cumulative over every chunk ever executed (all callers, all
        #: threads) -- the async scheduler reads this; guarded by _lock
        self.total_stats: Dict[str, int] = {
            "frames": 0, "batches": 0, "padded_frames": 0,
        }
        self._lock = threading.Lock()

    def _validate(self, inputs) -> int:
        if not inputs:
            raise TypeError("batched plan needs at least one input")
        b = inputs[0].shape[0]
        if b == 0:
            raise ValueError("empty macro-batch (leading axis has length 0)")
        for x in inputs[1:]:
            if x.shape[0] != b:
                raise ValueError(
                    f"inconsistent leading batch: {x.shape[0]} vs {b}"
                )
        return int(b)

    def run_chunk(self, params: Dict[str, Dict[str, Any]], *inputs):
        """Execute exactly ONE compiled chunk: the leading axis must be at
        most ``batch_size`` (a short chunk is zero-padded to the compiled
        shape and the padding sliced off the outputs).  This is the
        scheduler's entry point -- stats accumulate into ``total_stats``
        under a lock, so concurrent scheduler threads never corrupt them."""
        b = self._validate(inputs)
        bs = self.batch_size
        if b > bs:
            raise ValueError(
                f"run_chunk takes at most batch_size={bs} frames, got {b}"
            )
        xs = inputs
        if b < bs:
            short = bs - b
            xs = tuple(
                jnp.concatenate([x, jnp.zeros((short,) + x.shape[1:], x.dtype)])
                for x in xs
            )
        out = self._chunk(params, *xs)
        with self._lock:
            self.total_stats["frames"] += b
            self.total_stats["batches"] += 1
            self.total_stats["padded_frames"] += bs - b
        if isinstance(out, tuple):
            return tuple(o[:b] for o in out)
        return out[:b]

    def __call__(self, params: Dict[str, Dict[str, Any]], *inputs):
        b = self._validate(inputs)
        bs = self.batch_size
        chunks = [
            self.run_chunk(params, *(x[i : i + bs] for x in inputs))
            for i in range(0, b, bs)
        ]
        self.last_stats = {
            "frames": int(b),
            "batches": len(chunks),
            "padded_frames": int((-b) % bs),
        }
        if isinstance(chunks[0], tuple):
            return tuple(
                jnp.concatenate([c[j] for c in chunks])
                for j in range(len(chunks[0]))
            )
        return jnp.concatenate(chunks)


def compile_plan(
    g: Graph,
    *,
    backend: str = "kernel",
    interpret: Optional[bool] = None,
    guard: Optional[GuardConfig] = None,
) -> ExecutionPlan:
    """Compile ``g`` into an :class:`ExecutionPlan` (validates the graph,
    resolves handlers, schedules topologically, computes buffer liveness).
    ``backend="guarded"`` compiles a degradation-tolerant plan: each step
    tries ``guard.primary``'s handler and demotes failures to ``reference``
    (see :meth:`ExecutionPlan._exec_guarded`)."""
    if backend not in _HANDLERS and backend != "guarded":
        raise ValueError(f"unknown backend {backend!r}; have {EXEC_BACKENDS}")
    # schedule before validating: Graph.validate requires def-before-use node
    # order, which the Kahn schedule establishes for out-of-order builders
    order = _topo_schedule(g)
    g = dataclasses.replace(g, nodes=order)
    g.validate()
    handlers = handlers_for(backend)
    if backend == "guarded":  # an op with only a reference handler still runs
        handlers = {**handlers, **handlers_for("reference")}
    missing = sorted({n.op for n in order if n.op not in handlers})
    if missing:
        raise NotImplementedError(
            f"no {backend!r} handler for ops {missing}; "
            f"registered: {registered_ops(backend)}"
        )
    # liveness: an intermediate dies at its last consuming step.  Graph inputs
    # are caller-owned and graph outputs must survive, so neither is freed.
    keep = set(g.inputs) | set(g.outputs)
    last_use: Dict[str, int] = {}
    for i, n in enumerate(order):
        for x in n.inputs:
            last_use[x] = i
    steps = []
    for i, n in enumerate(order):
        frees = tuple(
            x for x, j in last_use.items() if j == i and x not in keep
        )
        steps.append(Step(node=n, frees=frees))
    return ExecutionPlan(
        graph=g, steps=tuple(steps), backend=backend, interpret=interpret,
        guard=guard,
    )
