from .ir import Graph, GraphBuilder, Node
from .lowering import lower
from .passes import dce, fold_gathers, fold_norm, fuse_activation, optimize, substitute_sparse
