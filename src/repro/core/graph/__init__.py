from .ir import Graph, GraphBuilder, Node
from .executor import (
    BACKENDS,
    EXEC_BACKENDS,
    BatchedPlan,
    ExecutionPlan,
    compile_plan,
    guard_fallback_counts,
    handlers_for,
    register_op,
    registered_ops,
    reset_guard_fallbacks,
)
from .lowering import lower
from .pass_manager import (
    DEFAULT_PIPELINE,
    GraphPass,
    InvariantViolation,
    PassContext,
    PassManager,
    PassStats,
    available_passes,
    get_pass,
    register_pass,
)
from .passes import (
    cse,
    dce,
    fold_gathers,
    fold_norm,
    fuse_activation,
    fuse_elementwise,
    fuse_epilogue,
    optimize,
    quantize,
    substitute_sparse,
)
