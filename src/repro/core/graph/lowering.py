"""Lower a Graph (LR DSL) to a JAX callable -- back-compat shim.

The monolithic if/elif interpreter that used to live here is now the
op-registry execution-plan compiler in :mod:`.executor`.  :func:`lower` is a
thin wrapper kept for the old call sites: ``lower(g)(params, *inputs)``
returns exactly what the plan-based executor computes.

``use_kernels=True`` selects the Pallas-backed handler set, ``False`` the
pure-jnp reference handlers (the XLA-native baseline; used on CPU benchmarks
where interpret-mode Pallas would measure Python, not the algorithm).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

from .executor import ExecutionPlan, compile_plan
from .ir import Graph

__all__ = ["lower"]


def lower(
    g: Graph, *, use_kernels: bool = True, interpret: Optional[bool] = None
) -> Callable[..., Any]:
    """Compile ``g`` to a callable ``f(params, *inputs) -> outputs``.

    The returned object is an :class:`~.executor.ExecutionPlan`: it jits,
    grads, and pjits like any JAX function, and additionally exposes
    ``.summary()`` and ``.memory_estimate(*inputs)``.
    """
    backend = "kernel" if use_kernels else "reference"
    plan: ExecutionPlan = compile_plan(g, backend=backend, interpret=interpret)
    return plan
