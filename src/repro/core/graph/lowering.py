"""Lower a Graph (LR DSL) to a JAX callable.

Dense linear / sparse_linear nodes execute through the Pallas kernels
(:mod:`repro.kernels.ops`); convolutions through ``lax.conv_general_dilated``
(NCHW); everything else is plain jnp.  The returned function is
``f(params, *inputs) -> outputs`` with ``params = graph.params`` as a pytree,
so it jits, grads, and pjits like any JAX function.

``use_kernels=False`` lowers GEMMs with jnp instead (the XLA-native baseline;
used on CPU benchmarks where interpret-mode Pallas would measure Python, not
the algorithm).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ...kernels import ops as kops
from ...kernels import ref as kref
from .ir import Graph

__all__ = ["lower"]

_ACT = kref._ACT


def _conv2d(x, w, b, stride, padding, groups, activation):
    dn = lax.conv_dimension_numbers(x.shape, w.shape, ("NCHW", "OIHW", "NCHW"))
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=dn,
        feature_group_count=groups,
    )
    if b is not None:
        y = y + b[None, :, None, None]
    return _ACT[activation](y)


def _pixel_shuffle(x, r):
    n, c, h, w = x.shape
    x = x.reshape(n, c // (r * r), r, r, h, w)
    x = x.transpose(0, 1, 4, 2, 5, 3)
    return x.reshape(n, c // (r * r), h * r, w * r)


def lower(
    g: Graph, *, use_kernels: bool = True, interpret: Optional[bool] = None
) -> Callable[..., Any]:
    g.validate()
    nodes = list(g.nodes)

    def fn(params: Dict[str, Dict[str, Any]], *args):
        env: Dict[str, Any] = dict(zip(g.inputs, args))
        for n in nodes:
            p = params.get(n.name, {})
            a = n.attrs
            x = [env[i] for i in n.inputs]
            if n.op == "linear":
                if use_kernels:
                    y = kops.matmul(
                        x[0], p["w"], p.get("b"), activation=a.get("activation"),
                        interpret=interpret,
                    )
                else:
                    y = kref.matmul_ref(
                        x[0], p["w"], p.get("b"), activation=a.get("activation")
                    )
            elif n.op == "sparse_linear":
                fmt = a["format"]
                if fmt == "colcompact":
                    if use_kernels:
                        y = kops.col_matmul(
                            x[0], p["values"], p["kept"], p.get("b"),
                            activation=a.get("activation"), interpret=interpret,
                        )
                    else:
                        y = kref.matmul_ref(
                            jnp.take(x[0], p["kept"], axis=-1), p["values"],
                            p.get("b"), activation=a.get("activation"),
                        )
                elif fmt == "channelcompact":
                    if use_kernels:
                        y = kops.matmul(
                            x[0], p["values"], p.get("b"),
                            activation=a.get("activation"), interpret=interpret,
                        )
                    else:
                        y = kref.matmul_ref(
                            x[0], p["values"], p.get("b"),
                            activation=a.get("activation"),
                        )
                elif fmt == "pbcsr":
                    if use_kernels:
                        y = kops.bsr_matmul(
                            x[0], p["values"], p["block_rows"], p.get("b"),
                            activation=a.get("activation"),
                            bands=a.get("bands"), interpret=interpret,
                        )
                    else:
                        y = kref.bsr_matmul_ref(
                            x[0].reshape(-1, x[0].shape[-1]), p["values"],
                            p["block_rows"], p.get("b"),
                            activation=a.get("activation"),
                        ).reshape(*x[0].shape[:-1], -1)
                else:
                    raise NotImplementedError(f"sparse format {fmt}")
            elif n.op == "conv2d":
                y = _conv2d(
                    x[0], p["w"], p.get("b"), a.get("stride", 1),
                    a.get("padding", "SAME"), a.get("groups", 1),
                    a.get("activation"),
                )
            elif n.op == "norm":
                kind = a["kind"]
                eps = a.get("eps", 1e-5)
                xi = x[0]
                if kind == "batch":  # inference: stored stats, per-channel (C of NCHW)
                    s = p["scale"] / jnp.sqrt(p["var"] + eps)
                    y = (xi - p["mean"][None, :, None, None]) * s[
                        None, :, None, None
                    ] + p["bias"][None, :, None, None]
                elif kind == "instance":  # per (N, C) over spatial
                    mu = xi.mean(axis=(2, 3), keepdims=True)
                    var = xi.var(axis=(2, 3), keepdims=True)
                    y = (xi - mu) / jnp.sqrt(var + eps)
                    y = y * p["scale"][None, :, None, None] + p["bias"][None, :, None, None]
                elif kind == "layer":  # over last dim
                    mu = xi.mean(axis=-1, keepdims=True)
                    var = xi.var(axis=-1, keepdims=True)
                    y = (xi - mu) / jnp.sqrt(var + eps) * p["scale"] + p["bias"]
                else:
                    raise NotImplementedError(kind)
            elif n.op == "activation":
                y = _ACT[a["fn"]](x[0])
            elif n.op == "add":
                y = x[0] + x[1]
            elif n.op == "mul":
                y = x[0] * x[1]
            elif n.op == "concat":
                y = jnp.concatenate(x, axis=a.get("axis", 1))
            elif n.op == "pixel_shuffle":
                y = _pixel_shuffle(x[0], a["factor"])
            elif n.op == "upsample":
                r = a["factor"]
                y = jnp.repeat(jnp.repeat(x[0], r, axis=2), r, axis=3)
            elif n.op == "pad_reflect":
                pd = a["pad"]
                y = jnp.pad(x[0], ((0, 0), (0, 0), (pd, pd), (pd, pd)), mode="reflect")
            elif n.op == "gather_channels":
                axis = a.get("axis", -1)
                idx = jnp.asarray(np.asarray(a["idx"]))
                if a["mode"] == "gather":
                    y = jnp.take(x[0], idx, axis=axis)
                else:  # scatter back to width n along axis
                    xi = x[0]
                    if axis in (-1, xi.ndim - 1):
                        shp = xi.shape[:-1] + (a["n"],)
                        y = jnp.zeros(shp, xi.dtype).at[..., idx].set(xi)
                    elif axis == 1:
                        shp = (xi.shape[0], a["n"]) + xi.shape[2:]
                        y = jnp.zeros(shp, xi.dtype).at[:, idx].set(xi)
                    else:
                        raise NotImplementedError(axis)
            elif n.op == "global_avg_pool":
                y = x[0].mean(axis=(2, 3))
            elif n.op == "broadcast_spatial":
                # fuse a [N, C] global feature into a [N, C, H, W] map
                y = jnp.broadcast_to(
                    x[0][:, :, None, None],
                    (x[0].shape[0], x[0].shape[1], x[1].shape[2], x[1].shape[3]),
                )
            else:
                raise NotImplementedError(f"op {n.op}")
            env[n.name] = y
        outs = tuple(env[o] for o in g.outputs)
        return outs[0] if len(outs) == 1 else outs

    return fn
