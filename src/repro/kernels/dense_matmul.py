"""Tiled dense matmul with a fused epilogue *program* (Pallas TPU).

This is (a) the baseline against which the BSR kernel is compared and (b) the
execution engine for column-/channel-compacted weights (a strictly smaller
dense GEMM).  The fused epilogue is the TPU materialization of the paper's
DSL fusion passes: beyond the single ``activation`` string (Conv/Linear +
BatchNorm + Activation in one kernel), the epilogue now accepts a step
*program* -- ``("activation", fn)`` / ``("add", slot)`` / ``("mul", slot)``
over per-tile side operands -- so bias + activation + residual-add + scale
all run on the f32 accumulator in registers before the tile is written back
(the ``fuse_epilogue`` pass's kernel half; no HBM round-trip for any
intermediate).

Grid: ``(M/bm, N/bn, K/bk)`` with a VMEM f32 accumulator; K innermost so the
accumulator lives across the contraction.  Block shapes default to MXU-square
128 and must divide the (padded) operand shapes -- the ops.py wrapper pads.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = ["dense_matmul_kernel", "dense_matmul"]


_ACTIVATIONS = {
    None: lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def apply_epilogue_steps(acc, epilogue, side_refs):
    """Run an epilogue step program on the f32 accumulator tile -- the
    single in-kernel step interpreter shared by the dense, PBCSR, and INT8
    matmul kernels.  ``("add"|"mul", slot)`` streams ``side_refs[slot]``."""
    for step in epilogue:
        kind = step[0]
        if kind == "activation":
            acc = _ACTIVATIONS[step[1]](acc)
        elif kind in ("add", "mul"):
            s = side_refs[step[1]][...].astype(jnp.float32)
            acc = acc + s if kind == "add" else acc * s
        else:
            raise NotImplementedError(f"epilogue step {kind}")
    return acc


def validate_epilogue(epilogue, n_sides: int) -> None:
    """Wrapper-side validation shared by every epilogue-capable kernel."""
    for step in epilogue:
        if step[0] == "activation" and step[1] not in _ACTIVATIONS:
            raise ValueError(f"unknown epilogue activation {step[1]!r}")
        if step[0] in ("add", "mul") and not (0 <= step[1] < n_sides):
            raise ValueError(
                f"epilogue slot {step[1]} out of range ({n_sides} sides)"
            )


def dense_matmul_kernel(
    x_ref,
    w_ref,
    b_ref,
    side_refs,
    o_ref,
    acc_ref,
    *,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One (i, j, k) grid step: acc += x[i,k] @ w[k,j]; epilogue at last k.

    ``epilogue`` steps run on the f32 accumulator after bias + ``activation``;
    ``("add"|"mul", slot)`` streams side tile ``side_refs[slot]``.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        acc = _ACTIVATIONS[activation](acc)
        acc = apply_epilogue_steps(acc, epilogue, side_refs)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "epilogue", "block_m", "block_n", "block_k", "interpret", "out_dtype",
    ),
)
def dense_matmul(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *sides: jax.Array,
    activation: Optional[str] = None,
    epilogue: Tuple[Tuple, ...] = (),
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """``epilogue(act(x @ w + bias))`` -- 2-D operands, shapes multiples of
    the blocks; ``sides`` are [M, N] arrays streamed per-tile for the
    epilogue's add/mul slots.

    Use :func:`repro.kernels.ops.matmul` for the padded/raked public API.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        x.shape,
        w.shape,
        (block_m, block_n, block_k),
    )
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    validate_epilogue(epilogue, len(sides))
    for s in sides:
        assert s.shape == (m, n), (s.shape, (m, n))
    out_dtype = out_dtype or x.dtype
    grid = (m // block_m, n // block_n, k // block_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (n,), bias.shape
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        args.append(bias.reshape(1, n))
    out_tile = pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))
    in_specs.extend([out_tile] * len(sides))
    args.extend(sides)
    n_sides = len(sides)

    def kern(*refs):
        # refs: x, w, [bias], *sides, o, acc
        b_ref = refs[2] if has_bias else None
        first_side = 2 + int(has_bias)
        dense_matmul_kernel(
            refs[0],
            refs[1],
            b_ref,
            refs[first_side : first_side + n_sides],
            refs[-2],
            refs[-1],
            activation=activation,
            epilogue=epilogue,
        )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*args)
