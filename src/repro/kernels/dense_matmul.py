"""Tiled dense matmul with fused bias+activation epilogue (Pallas TPU).

This is (a) the baseline against which the BSR kernel is compared and (b) the
execution engine for column-/channel-compacted weights (a strictly smaller
dense GEMM).  The fused epilogue is the TPU materialization of the paper's
DSL fusion pass (Conv/Linear + BatchNorm + Activation in one kernel -- no
HBM round-trip for the intermediate).

Grid: ``(M/bm, N/bn, K/bk)`` with a VMEM f32 accumulator; K innermost so the
accumulator lives across the contraction.  Block shapes default to MXU-square
128 and must divide the (padded) operand shapes -- the ops.py wrapper pads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = ["dense_matmul_kernel", "dense_matmul"]


_ACTIVATIONS = {
    None: lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def dense_matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, activation: Optional[str]):
    """One (i, j, k) grid step: acc += x[i,k] @ w[k,j]; epilogue at last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[...] = _ACTIVATIONS[activation](acc).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def dense_matmul(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """``act(x @ w + bias)`` -- 2-D operands, shapes multiples of the blocks.

    Use :func:`repro.kernels.ops.matmul` for the padded/raked public API.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        x.shape,
        w.shape,
        (block_m, block_n, block_k),
    )
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    out_dtype = out_dtype or x.dtype
    grid = (m // block_m, n // block_n, k // block_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if bias is not None:
        assert bias.shape == (n,), bias.shape
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        args.append(bias.reshape(1, n))
        kern = functools.partial(dense_matmul_kernel, activation=activation)
    else:
        def kern(x_ref, w_ref, o_ref, acc_ref):
            return dense_matmul_kernel(
                x_ref, w_ref, None, o_ref, acc_ref, activation=activation
            )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*args)
