"""Tiled dense matmul with a fused epilogue *program* (Pallas TPU).

This is (a) the baseline against which the BSR kernel is compared and (b) the
execution engine for column-/channel-compacted weights (a strictly smaller
dense GEMM).  The fused epilogue is the TPU materialization of the paper's
DSL fusion passes: beyond the single ``activation`` string (Conv/Linear +
BatchNorm + Activation in one kernel), the epilogue now accepts a step
*program* -- ``("activation", fn)`` / ``("add", slot)`` / ``("mul", slot)``
over per-tile side operands -- so bias + activation + residual-add + scale
all run on the f32 accumulator in registers before the tile is written back
(the ``fuse_epilogue`` pass's kernel half; no HBM round-trip for any
intermediate).

Grid: ``(M/bm, N/bn, K/bk)`` with a VMEM f32 accumulator; K innermost so the
accumulator lives across the contraction.  Block shapes default to MXU-square
128 and must divide the (padded) operand shapes -- the ops.py wrapper pads.

``pipeline >= 2`` switches to the hand-rolled double-buffered variant: the
grid drops to ``(M/bm, N/bn)``, the x/w operands stay in HBM
(``memory_space=ANY``), and the kernel itself streams ``[bm, bk]`` /
``[bk, bn]`` K-slabs into a ``pipeline``-deep ring of VMEM scratch buffers
with explicit async DMAs -- the copy for K-step ``k + depth - 1`` is started
*before* waiting on step ``k``'s, so HBM transfer of the next slab overlaps
the MXU contraction of the current one.  This is the explicit form of what
the Pallas grid pipeline does automatically for the ``pipeline == 1`` path;
it exists so the tuning cache can choose between compiler-scheduled and
hand-scheduled K streaming per shape (the 4th ``matmul``-family block field).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = [
    "dense_matmul_kernel",
    "dense_matmul_pipelined_kernel",
    "dense_matmul",
]


_ACTIVATIONS = {
    None: lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def apply_epilogue_steps(acc, epilogue, side_refs):
    """Run an epilogue step program on the f32 accumulator tile -- the
    single in-kernel step interpreter shared by the dense, PBCSR, and INT8
    matmul kernels.  ``("add"|"mul", slot)`` streams ``side_refs[slot]``."""
    for step in epilogue:
        kind = step[0]
        if kind == "activation":
            acc = _ACTIVATIONS[step[1]](acc)
        elif kind in ("add", "mul"):
            s = side_refs[step[1]][...].astype(jnp.float32)
            acc = acc + s if kind == "add" else acc * s
        else:
            raise NotImplementedError(f"epilogue step {kind}")
    return acc


def validate_epilogue(epilogue, n_sides: int) -> None:
    """Wrapper-side validation shared by every epilogue-capable kernel."""
    for step in epilogue:
        if step[0] == "activation" and step[1] not in _ACTIVATIONS:
            raise ValueError(f"unknown epilogue activation {step[1]!r}")
        if step[0] in ("add", "mul") and not (0 <= step[1] < n_sides):
            raise ValueError(
                f"epilogue slot {step[1]} out of range ({n_sides} sides)"
            )


def dense_matmul_kernel(
    x_ref,
    w_ref,
    b_ref,
    side_refs,
    o_ref,
    acc_ref,
    *,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One (i, j, k) grid step: acc += x[i,k] @ w[k,j]; epilogue at last k.

    ``epilogue`` steps run on the f32 accumulator after bias + ``activation``;
    ``("add"|"mul", slot)`` streams side tile ``side_refs[slot]``.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        acc = _ACTIVATIONS[activation](acc)
        acc = apply_epilogue_steps(acc, epilogue, side_refs)
        o_ref[...] = acc.astype(o_ref.dtype)


def dense_matmul_pipelined_kernel(
    x_hbm,  # [bm, K] row panel, left in HBM (memory_space=ANY)
    w_hbm,  # [K, bn] column panel, left in HBM (memory_space=ANY)
    b_ref,
    side_refs,
    o_ref,
    x_slots,  # VMEM [depth, bm, bk] ring of streamed x K-slabs
    w_slots,  # VMEM [depth, bk, bn] ring of streamed w K-slabs
    sem,  # DMA semaphores [depth, 2] (slot x {x, w})
    *,
    block_k: int,
    n_steps: int,
    depth: int,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One (i, j) grid step of the hand-pipelined GEMM: K is contracted by
    an in-kernel loop over ``n_steps`` slabs streamed HBM->VMEM through a
    ``depth``-deep double-buffer ring.  Slab ``s + depth - 1``'s DMA starts
    before slab ``s``'s is awaited, so the copy of the next operands overlaps
    the MXU work on the current ones; the accumulator is the loop carry."""

    def copies(slot, step):
        return (
            pltpu.make_async_copy(
                x_hbm.at[:, pl.ds(step * block_k, block_k)],
                x_slots.at[slot],
                sem.at[slot, 0],
            ),
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(step * block_k, block_k), :],
                w_slots.at[slot],
                sem.at[slot, 1],
            ),
        )

    for p in range(min(depth - 1, n_steps)):  # warm-up: fill the ring
        for c in copies(p, p):
            c.start()

    def body(step, acc):
        ahead = step + depth - 1

        @pl.when(ahead < n_steps)
        def _prefetch():
            for c in copies(jax.lax.rem(ahead, depth), ahead):
                c.start()

        slot = jax.lax.rem(step, depth)
        for c in copies(slot, step):
            c.wait()
        return acc + jnp.dot(
            x_slots[slot], w_slots[slot], preferred_element_type=jnp.float32
        )

    acc = jax.lax.fori_loop(
        0, n_steps, body, jnp.zeros(o_ref.shape, jnp.float32)
    )
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    acc = _ACTIVATIONS[activation](acc)
    acc = apply_epilogue_steps(acc, epilogue, side_refs)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "epilogue", "block_m", "block_n", "block_k", "pipeline",
        "interpret", "out_dtype",
    ),
)
def dense_matmul(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *sides: jax.Array,
    activation: Optional[str] = None,
    epilogue: Tuple[Tuple, ...] = (),
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    pipeline: int = 1,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """``epilogue(act(x @ w + bias))`` -- 2-D operands, shapes multiples of
    the blocks; ``sides`` are [M, N] arrays streamed per-tile for the
    epilogue's add/mul slots.  ``pipeline >= 2`` selects the hand-rolled
    double-buffered K streaming path (that many VMEM slab slots in flight).

    Use :func:`repro.kernels.ops.matmul` for the padded/raked public API.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        x.shape,
        w.shape,
        (block_m, block_n, block_k),
    )
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    validate_epilogue(epilogue, len(sides))
    for s in sides:
        assert s.shape == (m, n), (s.shape, (m, n))
    out_dtype = out_dtype or x.dtype
    pipelined = pipeline >= 2
    if pipelined:
        grid = (m // block_m, n // block_n)
        any_space = pltpu.TPUMemorySpace.ANY
        in_specs = [
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0), memory_space=any_space),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j), memory_space=any_space),
        ]
        bias_tile = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
        out_tile = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
        scratch = [
            pltpu.VMEM((pipeline, block_m, block_k), x.dtype),
            pltpu.VMEM((pipeline, block_k, block_n), w.dtype),
            pltpu.SemaphoreType.DMA((pipeline, 2)),
        ]
        semantics = ("parallel", "parallel")
    else:
        grid = (m // block_m, n // block_n, k // block_k)
        in_specs = [
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ]
        bias_tile = pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j))
        out_tile = pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
        semantics = ("parallel", "parallel", "arbitrary")
    args = [x, w]
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (n,), bias.shape
        in_specs.append(bias_tile)
        args.append(bias.reshape(1, n))
    in_specs.extend([out_tile] * len(sides))
    args.extend(sides)
    n_sides = len(sides)

    def kern(*refs):
        # refs: x, w, [bias], *sides, o, then scratch
        b_ref = refs[2] if has_bias else None
        first_side = 2 + int(has_bias)
        side_refs = refs[first_side : first_side + n_sides]
        if pipelined:
            dense_matmul_pipelined_kernel(
                refs[0],
                refs[1],
                b_ref,
                side_refs,
                refs[-4],
                refs[-3],
                refs[-2],
                refs[-1],
                block_k=block_k,
                n_steps=k // block_k,
                depth=pipeline,
                activation=activation,
                epilogue=epilogue,
            )
        else:
            dense_matmul_kernel(
                refs[0],
                refs[1],
                b_ref,
                side_refs,
                refs[-2],
                refs[-1],
                activation=activation,
                epilogue=epilogue,
            )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=_tpu_compiler_params(
            dimension_semantics=semantics
        ),
        interpret=interpret,
    )(*args)
