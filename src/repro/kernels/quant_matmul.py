"""Tiled INT8 matmul kernels (Pallas TPU) with the fused epilogue program.

Two schemes share one kernel body (selected by the activation dtype):

* **W8A8** -- ``x`` arrives int8 (statically-scaled activations, calibrated
  offline), weights are int8: the MXU contracts int8 x int8 into an **int32**
  VMEM accumulator, and a single f32 rescale at the last K step applies the
  combined ``x_scale * w_scale[n]`` per output column (folded into ``ws``
  before the call, so the kernel sees one rescale vector).  Both operands
  stream from HBM at a quarter of the f32 bytes.
* **W8-only** -- ``x`` stays f32 (no activation calibration needed), weights
  are int8: each weight tile is **dequantized in VMEM** (cast to f32 inside
  the kernel; per-column scales applied at the epilogue since
  ``x @ (q * s[n]) == (x @ q) * s[n]``), accumulating in f32.  Weight HBM
  traffic drops 4x -- the win for memory-bound GEMMs -- while activations
  keep full precision.  The pruned colcompact/channelcompact formats ride
  this scheme when no activation calibration is available (their values are
  plain ``[K', N]`` matrices); with a calibrated input range they run W8A8
  like any other qlinear -- the gather preserves values, so the input's
  scale applies to the gathered activations unchanged.

Bias, the fused ``activation`` string, and the epilogue step *program*
(``("activation", fn)`` / ``("add"|"mul", slot)`` over per-tile side
operands) all run on the rescaled f32 accumulator before the tile is written
back, exactly as in :mod:`.dense_matmul`.

Grid: ``(M/bm, N/bn, K/bk)``, K innermost so the accumulator lives across the
contraction.  The :func:`repro.kernels.ops.qmatmul` wrapper pads/rakes and
resolves block sizes through the tuning cache under the ``qmatmul`` key
family.  int8 min tile is (32, 128) -- every candidate block is a multiple.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dense_matmul import _ACTIVATIONS, apply_epilogue_steps, validate_epilogue
from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = ["quant_matmul_kernel", "quant_matmul"]


def quant_matmul_kernel(
    x_ref,  # [bm, bk] int8 (W8A8) or f32 (W8-only)
    w_ref,  # [bk, bn] int8
    ws_ref,  # [1, bn] f32 combined rescale per output column
    b_ref,  # [1, bn] f32 bias tile or None
    side_refs,  # per-tile epilogue side operands, each [bm, bn]
    o_ref,  # [bm, bn] output tile
    acc_ref,  # VMEM accumulator: int32 (W8A8) or f32 (W8-only)
    *,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One (i, j, k) grid step; rescale + epilogue at the last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if jnp.issubdtype(x_ref.dtype, jnp.integer):
        # W8A8: int8 x int8 -> int32 on the MXU, exact integer accumulation
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.int32
        )
    else:
        # W8-only: dequantize the weight tile in VMEM (scale deferred to the
        # per-column rescale below), accumulate in f32
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32) * ws_ref[...].astype(jnp.float32)
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        acc = _ACTIVATIONS[activation](acc)
        acc = apply_epilogue_steps(acc, epilogue, side_refs)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "epilogue", "block_m", "block_n", "block_k", "interpret",
        "out_dtype",
    ),
)
def quant_matmul(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *sides: jax.Array,
    activation: Optional[str] = None,
    epilogue: Tuple[Tuple, ...] = (),
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``epilogue(act((x @ w_q) * w_scale + bias))`` over 2-D block-aligned
    operands.  ``x`` int8 selects the W8A8 int32 path (``w_scale`` must
    already fold the activation scale in); f32 ``x`` selects the W8-only
    per-tile-dequantize path.  ``w_q [K, N]`` int8, ``w_scale [N]`` f32.

    Use :func:`repro.kernels.ops.qmatmul` for the padded/raked public API.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, (x.shape, w_q.shape)
    assert w_q.dtype == jnp.int8, w_q.dtype
    assert w_scale.shape == (n,), (w_scale.shape, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        x.shape, w_q.shape, (block_m, block_n, block_k),
    )
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    validate_epilogue(epilogue, len(sides))
    for s in sides:
        assert s.shape == (m, n), (s.shape, (m, n))
    a8 = jnp.issubdtype(x.dtype, jnp.integer)
    grid = (m // block_m, n // block_n, k // block_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
    ]
    args = [x, w_q, w_scale.reshape(1, n).astype(jnp.float32)]
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (n,), bias.shape
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        args.append(bias.reshape(1, n))
    out_tile = pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))
    in_specs.extend([out_tile] * len(sides))
    args.extend(sides)
    n_sides = len(sides)

    def kern(*refs):
        # refs: x, w_q, ws, [bias], *sides, o, acc
        b_ref = refs[3] if has_bias else None
        first_side = 3 + int(has_bias)
        quant_matmul_kernel(
            refs[0],
            refs[1],
            refs[2],
            b_ref,
            refs[first_side : first_side + n_sides],
            refs[-2],
            refs[-1],
            activation=activation,
            epilogue=epilogue,
        )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.int32 if a8 else jnp.float32)
        ],
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*args)
