"""Tiled INT8 matmul kernels (Pallas TPU) with the fused epilogue program.

Two schemes share one kernel body (selected by the activation dtype):

* **W8A8** -- ``x`` arrives int8 (statically-scaled activations, calibrated
  offline), weights are int8: the MXU contracts int8 x int8 into an **int32**
  VMEM accumulator, and a single f32 rescale at the last K step applies the
  combined ``x_scale * w_scale[n]`` per output column (folded into ``ws``
  before the call, so the kernel sees one rescale vector).  Both operands
  stream from HBM at a quarter of the f32 bytes.
* **W8-only** -- ``x`` stays f32 (no activation calibration needed), weights
  are int8: each weight tile is **dequantized in VMEM** (cast to f32 inside
  the kernel; per-column scales applied at the epilogue since
  ``x @ (q * s[n]) == (x @ q) * s[n]``), accumulating in f32.  Weight HBM
  traffic drops 4x -- the win for memory-bound GEMMs -- while activations
  keep full precision.  The pruned colcompact/channelcompact formats ride
  this scheme when no activation calibration is available (their values are
  plain ``[K', N]`` matrices); with a calibrated input range they run W8A8
  like any other qlinear -- the gather preserves values, so the input's
  scale applies to the gathered activations unchanged.

Bias, the fused ``activation`` string, and the epilogue step *program*
(``("activation", fn)`` / ``("add"|"mul", slot)`` over per-tile side
operands) all run on the rescaled f32 accumulator before the tile is written
back, exactly as in :mod:`.dense_matmul`.

Grid: ``(M/bm, N/bn, K/bk)``, K innermost so the accumulator lives across the
contraction.  The :func:`repro.kernels.ops.qmatmul` wrapper pads/rakes and
resolves block sizes through the tuning cache under the ``qmatmul`` key
family.  int8 min tile is (32, 128) -- every candidate block is a multiple.

``pipeline >= 2`` selects the hand-rolled double-buffered variant (grid
``(M/bm, N/bn)``, x/w left in HBM, K-slabs streamed through a ring of VMEM
scratch buffers with explicit async DMAs, the next slab's copy overlapping
the current contraction) -- see :mod:`.dense_matmul` for the lifecycle; here
the loop carry is int32 for W8A8 and the int8 weight slab still dequantizes
in VMEM for W8-only.  The int8 streams make this the kernel where manual
staging matters most: a depth-2 ring holds ``2 * bk * (bm + bn)`` int8
bytes, a quarter of the f32 footprint.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dense_matmul import _ACTIVATIONS, apply_epilogue_steps, validate_epilogue
from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = [
    "quant_matmul_kernel",
    "quant_matmul_pipelined_kernel",
    "quant_matmul",
]


def quant_matmul_kernel(
    x_ref,  # [bm, bk] int8 (W8A8) or f32 (W8-only)
    w_ref,  # [bk, bn] int8
    ws_ref,  # [1, bn] f32 combined rescale per output column
    b_ref,  # [1, bn] f32 bias tile or None
    side_refs,  # per-tile epilogue side operands, each [bm, bn]
    o_ref,  # [bm, bn] output tile
    acc_ref,  # VMEM accumulator: int32 (W8A8) or f32 (W8-only)
    *,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One (i, j, k) grid step; rescale + epilogue at the last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if jnp.issubdtype(x_ref.dtype, jnp.integer):
        # W8A8: int8 x int8 -> int32 on the MXU, exact integer accumulation
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.int32
        )
    else:
        # W8-only: dequantize the weight tile in VMEM (scale deferred to the
        # per-column rescale below), accumulate in f32
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32) * ws_ref[...].astype(jnp.float32)
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        acc = _ACTIVATIONS[activation](acc)
        acc = apply_epilogue_steps(acc, epilogue, side_refs)
        o_ref[...] = acc.astype(o_ref.dtype)


def quant_matmul_pipelined_kernel(
    x_hbm,  # [bm, K] int8 (W8A8) or f32 (W8-only) row panel in HBM
    w_hbm,  # [K, bn] int8 column panel in HBM
    ws_ref,  # [1, bn] f32 combined rescale per output column
    b_ref,
    side_refs,
    o_ref,
    x_slots,  # VMEM [depth, bm, bk] ring of streamed x K-slabs
    w_slots,  # VMEM [depth, bk, bn] int8 ring of streamed w K-slabs
    sem,  # DMA semaphores [depth, 2] (slot x {x, w})
    *,
    block_k: int,
    n_steps: int,
    depth: int,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One (i, j) grid step of the hand-pipelined INT8 GEMM: K contracted by
    an in-kernel loop over slabs streamed through a ``depth``-deep ring, the
    DMA for slab ``s + depth - 1`` issued before slab ``s`` is awaited.  The
    accumulator is the loop carry (int32 for W8A8, f32 for W8-only); the
    per-column rescale + epilogue run once after the loop."""
    a8 = jnp.issubdtype(x_hbm.dtype, jnp.integer)

    def copies(slot, step):
        return (
            pltpu.make_async_copy(
                x_hbm.at[:, pl.ds(step * block_k, block_k)],
                x_slots.at[slot],
                sem.at[slot, 0],
            ),
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(step * block_k, block_k), :],
                w_slots.at[slot],
                sem.at[slot, 1],
            ),
        )

    for p in range(min(depth - 1, n_steps)):  # warm-up: fill the ring
        for c in copies(p, p):
            c.start()

    def body(step, acc):
        ahead = step + depth - 1

        @pl.when(ahead < n_steps)
        def _prefetch():
            for c in copies(jax.lax.rem(ahead, depth), ahead):
                c.start()

        slot = jax.lax.rem(step, depth)
        for c in copies(slot, step):
            c.wait()
        if a8:
            return acc + jnp.dot(
                x_slots[slot], w_slots[slot], preferred_element_type=jnp.int32
            )
        return acc + jnp.dot(
            x_slots[slot], w_slots[slot].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(
        0, n_steps, body,
        jnp.zeros(o_ref.shape, jnp.int32 if a8 else jnp.float32),
    )
    acc = acc.astype(jnp.float32) * ws_ref[...].astype(jnp.float32)
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)
    acc = _ACTIVATIONS[activation](acc)
    acc = apply_epilogue_steps(acc, epilogue, side_refs)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "epilogue", "block_m", "block_n", "block_k", "pipeline",
        "interpret", "out_dtype",
    ),
)
def quant_matmul(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *sides: jax.Array,
    activation: Optional[str] = None,
    epilogue: Tuple[Tuple, ...] = (),
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    pipeline: int = 1,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``epilogue(act((x @ w_q) * w_scale + bias))`` over 2-D block-aligned
    operands.  ``x`` int8 selects the W8A8 int32 path (``w_scale`` must
    already fold the activation scale in); f32 ``x`` selects the W8-only
    per-tile-dequantize path.  ``w_q [K, N]`` int8, ``w_scale [N]`` f32.
    ``pipeline >= 2`` selects the hand-rolled double-buffered K streaming
    path (that many VMEM slab slots in flight).

    Use :func:`repro.kernels.ops.qmatmul` for the padded/raked public API.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, (x.shape, w_q.shape)
    assert w_q.dtype == jnp.int8, w_q.dtype
    assert w_scale.shape == (n,), (w_scale.shape, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        x.shape, w_q.shape, (block_m, block_n, block_k),
    )
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    validate_epilogue(epilogue, len(sides))
    for s in sides:
        assert s.shape == (m, n), (s.shape, (m, n))
    a8 = jnp.issubdtype(x.dtype, jnp.integer)
    pipelined = pipeline >= 2
    if pipelined:
        grid = (m // block_m, n // block_n)
        any_space = pltpu.TPUMemorySpace.ANY
        in_specs = [
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0), memory_space=any_space),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j), memory_space=any_space),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ]
        bias_tile = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
        out_tile = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
        scratch = [
            pltpu.VMEM((pipeline, block_m, block_k), x.dtype),
            pltpu.VMEM((pipeline, block_k, block_n), w_q.dtype),
            pltpu.SemaphoreType.DMA((pipeline, 2)),
        ]
        semantics = ("parallel", "parallel")
    else:
        grid = (m // block_m, n // block_n, k // block_k)
        in_specs = [
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ]
        bias_tile = pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j))
        out_tile = pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))
        scratch = [pltpu.VMEM((block_m, block_n), jnp.int32 if a8 else jnp.float32)]
        semantics = ("parallel", "parallel", "arbitrary")
    args = [x, w_q, w_scale.reshape(1, n).astype(jnp.float32)]
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (n,), bias.shape
        in_specs.append(bias_tile)
        args.append(bias.reshape(1, n))
    in_specs.extend([out_tile] * len(sides))
    args.extend(sides)
    n_sides = len(sides)

    def kern(*refs):
        # refs: x, w_q, ws, [bias], *sides, o, then scratch
        b_ref = refs[3] if has_bias else None
        first_side = 3 + int(has_bias)
        side_refs = refs[first_side : first_side + n_sides]
        if pipelined:
            quant_matmul_pipelined_kernel(
                refs[0],
                refs[1],
                refs[2],
                b_ref,
                side_refs,
                refs[-4],
                refs[-3],
                refs[-2],
                refs[-1],
                block_k=block_k,
                n_steps=k // block_k,
                depth=pipeline,
                activation=activation,
                epilogue=epilogue,
            )
        else:
            quant_matmul_kernel(
                refs[0],
                refs[1],
                refs[2],
                b_ref,
                side_refs,
                refs[-2],
                refs[-1],
                activation=activation,
                epilogue=epilogue,
            )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=_tpu_compiler_params(
            dimension_semantics=semantics
        ),
        interpret=interpret,
    )(*args)
