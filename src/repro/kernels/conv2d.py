"""Tiled implicit-GEMM conv2d (Pallas TPU) with the fused epilogue program.

The paper's three demo apps are convolution-dominated, and until this kernel
every ``conv2d`` node lowered through dense ``lax.conv_general_dilated`` --
outside the Pallas/tuning/epilogue system the matmul family already rides.
This kernel closes that gap: the convolution is executed as a GEMM whose
``A`` operand (the im2col patch matrix) is **materialized tile-by-tile in
VMEM and never in HBM**.

GEMM view (per batch image)::

    M = OH x OW   (output pixels)       N = O  (output channels)
    K = C x kh x kw                     acc[M, N] += patch[M, K] @ W[K, N]

Tiling: grid ``(N_batch, OH/block_h, O/block_o)`` and -- with ``block_c``
set -- a fourth tiled-K axis ``C/block_c``.  Each grid step owns a
``[block_h * OW, block_o]`` output tile.  The input image arrives as an NHWC
VMEM block per batch element (the wrapper transposes + zero-pads once in
HBM -- that is *padding*, not im2col); the kernel then walks the ``kh x kw``
filter taps, slicing a ``[block_h, OW, block_c]`` patch per tap out of the
resident slab (strided rows/cols for ``stride > 1``), reshaping it to
``[block_h * OW, block_c]`` and feeding the MXU.

``block_c == 0`` keeps the legacy resident-image contraction: all of
``K = C * kh * kw`` inside one grid step, no accumulator scratch.  With
``block_c > 0`` the contraction is *tiled over K*: the innermost grid axis
walks channel blocks, a cross-step VMEM accumulator scratch (f32, or int32
for W8A8) carries partial sums, and bias/rescale/activation/epilogue run
once on the **last** K step -- exactly ``dense_matmul``'s (i, j, k) grid
shape, with the per-step K slab being ``block_k = block_c * kh * kw`` of the
GEMM's K.  VMEM pressure then scales with ``block_c``, not ``C``, so
wide-channel layers stop tripping the ``lax.conv`` VMEM fallback; the
Pallas TPU grid pipeline streams the next step's image/filter blocks
HBM->VMEM while the current step computes (automatic double-buffering --
the explicit hand-rolled variant lives in ``dense_matmul``'s /
``quant_matmul``'s ``pipeline=2`` path).

Three schemes share the kernel body, selected by operand dtypes:

* **dense f32** -- f32 patches x f32 filters, f32 accumulation (``ws=None``).
* **channel-pruned** -- identical body; the ``ops.conv2d`` wrapper gathers
  the surviving input channels (channelcompact/colcompact masks) *before*
  the layout transform, so K shrinks by the pruned ratio and the kernel
  contracts only live channels.
* **INT8** -- int8 filters.  With int8 patches (W8A8: activations quantized
  by the calibrated static scale) the MXU contracts int8 x int8 into an
  **int32** accumulator; with f32 patches (W8-only) the filter tile is
  dequantized in VMEM (cast; per-output-channel scales deferred to ``ws``
  since ``x (*) (q * s[o]) == (x (*) q) * s[o]``).  ``ws`` carries the
  combined per-output-channel rescale (``w_scale`` or
  ``x_scale * w_scale``), applied once on the f32 accumulator.

Bias, the fused ``activation`` string, and the epilogue step *program*
(``("activation", fn)`` / ``("add"|"mul", slot)`` over per-tile side
operands, :func:`~.dense_matmul.apply_epilogue_steps`) all run on the f32
accumulator before the tile is written back -- the ``fuse_epilogue`` pass's
conv half, replacing the old post-``lax.conv`` jnp tail.

Use :func:`repro.kernels.ops.conv2d` for the public NCHW/OIHW API (layout,
padding, scheme selection, tuning-cache block resolution, and the
``lax.conv`` fallback matrix for unsupported configs).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dense_matmul import _ACTIVATIONS, apply_epilogue_steps, validate_epilogue
from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = [
    "conv2d_gemm_kernel",
    "conv2d_gemm",
    "conv_out_hw",
    "conv_pad_hw",
    "conv_padding_token",
    "conv_vmem_workspace",
]


def _explicit_pads(padding) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Normalize lax-style explicit padding ``((ph_lo, ph_hi), (pw_lo, pw_hi))``."""
    (a, b), (c, d) = padding
    return (int(a), int(b)), (int(c), int(d))


def conv_out_hw(h: int, w: int, kh: int, kw: int, stride: int, padding) -> Tuple[int, int]:
    """Output spatial dims of a stride-``stride`` conv: ``"SAME"``,
    ``"VALID"``, or lax-style explicit pad pairs."""
    if isinstance(padding, str):
        if padding == "SAME":
            return -(-h // stride), -(-w // stride)
        if padding == "VALID":
            return (h - kh) // stride + 1, (w - kw) // stride + 1
        raise ValueError(f"unsupported padding {padding!r} (SAME, VALID, or pad pairs)")
    (a, b), (c, d) = _explicit_pads(padding)
    return (h + a + b - kh) // stride + 1, (w + c + d - kw) // stride + 1


def conv_pad_hw(h: int, w: int, kh: int, kw: int, stride: int, padding) -> Tuple[int, int]:
    """(top, left) zero padding the implicit-GEMM input carries (XLA SAME
    semantics: total pad split low-heavy; explicit pairs pass through)."""
    if not isinstance(padding, str):
        (a, _), (c, _) = _explicit_pads(padding)
        return a, c
    if padding == "VALID":
        return 0, 0
    oh, ow = conv_out_hw(h, w, kh, kw, stride, padding)
    ph = max((oh - 1) * stride + kh - h, 0)
    pw = max((ow - 1) * stride + kw - w, 0)
    return ph // 2, pw // 2


def conv_padding_token(padding) -> str:
    """Tuning-key suffix distinguishing padding geometries (SAME -- the
    canonical case -- stays suffix-free; VALID and explicit pairs alias
    neither it nor each other)."""
    if isinstance(padding, str):
        return "" if padding == "SAME" else f"+{padding.lower()}"
    (a, b), (c, d) = _explicit_pads(padding)
    return f"+p{a}.{b}.{c}.{d}"


def conv_vmem_workspace(
    c: int,
    h: int,
    w: int,
    kh: int,
    kw: int,
    stride: int,
    padding: str,
    block_h: int,
    block_o: int,
    block_c: int = 0,
    x_itemsize: int = 4,
    w_itemsize: int = 4,
) -> dict:
    """Per-grid-step VMEM working set of the implicit-GEMM kernel: the
    resident image slab, one filter tile, the in-flight im2col patch tile,
    and the f32 accumulator/output tile.  ``block_c == 0`` means the legacy
    resident-image path (all ``C`` channels in VMEM at once); ``block_c > 0``
    is the tiled-K contraction, where only a ``block_c``-channel slab is
    resident per grid step (plus the cross-step accumulator scratch).
    Shared by the ``ops.conv2d`` fallback guard and
    :meth:`ExecutionPlan.memory_estimate` (the im2col scratch never touches
    HBM, so it must be accounted as VMEM-side peak working memory, not
    activation bytes)."""
    oh, ow = conv_out_hw(h, w, kh, kw, stride, padding)
    ohp = -(-max(oh, 1) // block_h) * block_h
    hp = (ohp - 1) * stride + kh
    wp = (max(ow, 1) - 1) * stride + kw
    bm = block_h * max(ow, 1)
    c_eff = min(c, block_c) if block_c else c
    image = hp * wp * c_eff * x_itemsize
    weights = kh * kw * c_eff * block_o * w_itemsize
    patch = bm * c_eff * x_itemsize  # one (ki, kj) im2col tile resident at a time
    acc = bm * block_o * 4
    out = bm * block_o * 4
    return {
        "image": int(image),
        "weights": int(weights),
        "im2col_patch": int(patch),
        "acc": int(acc),
        "out": int(out),
        "total": int(image + weights + patch + acc + out),
    }


def conv2d_gemm_kernel(
    x_ref,  # [1, Hp, Wp, C or block_c] image slab (f32, or int8 for W8A8)
    w_ref,  # [kh*kw, C or block_c, block_o] filter taps (f32 or int8)
    ws_ref,  # [1, block_o] combined per-output-channel rescale, or None (f32)
    b_ref,  # [1, block_o] bias tile, or None
    side_refs,  # per-tile epilogue side operands, each [block_h*OW, block_o]
    o_ref,  # [block_h*OW, block_o] output tile
    acc_ref=None,  # cross-step VMEM accumulator (tiled-K only): f32 or int32
    *,
    stride: int,
    kh: int,
    kw: int,
    block_h: int,
    out_w: int,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    """One grid step of the implicit GEMM.

    ``acc_ref is None`` (legacy resident path): an (n, i, j) step contracts
    all ``C*kh*kw`` of K for one output tile, materializing one im2col patch
    tile per filter tap in VMEM.  With ``acc_ref`` (tiled-K path) this is an
    (n, i, j, kc) step: it contracts one ``block_c``-channel slab of K into
    the cross-step accumulator -- zeroed at ``kc == 0``, finished (rescale /
    bias / activation / epilogue + output write) at the last ``kc``."""
    i = pl.program_id(1)
    c = x_ref.shape[3]
    bm = block_h * out_w
    a8 = jnp.issubdtype(x_ref.dtype, jnp.integer)
    acc = jnp.zeros((bm, o_ref.shape[1]), jnp.int32 if a8 else jnp.float32)
    row_span = stride * (block_h - 1) + 1
    col_span = stride * (out_w - 1) + 1
    for ki in range(kh):
        for kj in range(kw):
            rows = x_ref[0, pl.ds(i * (block_h * stride) + ki, row_span), pl.ds(kj, col_span), :]
            if stride > 1:
                rows = rows[::stride, ::stride, :]
            patch = rows.reshape(bm, c)  # the im2col tile -- VMEM only
            wk = w_ref[ki * kw + kj]  # [C, block_o]
            if a8:
                # W8A8: int8 x int8 -> int32 on the MXU, exact accumulation
                acc += jnp.dot(patch, wk, preferred_element_type=jnp.int32)
            else:
                # dense f32, or W8-only (int8 filter tile dequantized in
                # VMEM; per-channel scales deferred to ws)
                acc += jnp.dot(
                    patch.astype(jnp.float32),
                    wk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )

    def _finish(a):
        a = a.astype(jnp.float32)
        if ws_ref is not None:
            a = a * ws_ref[...].astype(jnp.float32)
        if b_ref is not None:
            a = a + b_ref[...].astype(jnp.float32)
        a = _ACTIVATIONS[activation](a)
        a = apply_epilogue_steps(a, epilogue, side_refs)
        o_ref[...] = a.astype(o_ref.dtype)

    if acc_ref is None:
        _finish(acc)
        return
    kc = pl.program_id(3)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += acc

    @pl.when(kc == pl.num_programs(3) - 1)
    def _epilogue():
        _finish(acc_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "kh", "kw", "activation", "epilogue", "block_h", "block_o",
        "block_c", "interpret", "out_dtype",
    ),
)
def conv2d_gemm(
    x: jax.Array,
    w: jax.Array,
    ws: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    *sides: jax.Array,
    stride: int = 1,
    kh: int,
    kw: int,
    activation: Optional[str] = None,
    epilogue: Tuple[Tuple, ...] = (),
    block_h: int = 8,
    block_o: int = 128,
    block_c: int = 0,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Implicit-GEMM conv over pre-laid-out operands.

    ``x [N, Hp, Wp, C]`` NHWC, already zero-padded so that
    ``Hp == (OHp - 1) * stride + kh`` (``OHp`` a ``block_h`` multiple) and
    ``Wp == (OW - 1) * stride + kw``; ``w [kh*kw, C, Op]`` tap-major filters
    with ``Op`` a ``block_o`` multiple; ``ws``/``bias`` per-output-channel
    ``[Op]`` vectors; ``sides`` epilogue operands in the flattened output
    layout ``[N * OHp * OW, Op]``.  Returns ``[N * OHp * OW, Op]``.

    ``block_c == 0`` contracts all of K per grid step with the whole padded
    image VMEM-resident; ``block_c > 0`` (must divide ``C``) adds the tiled-K
    grid axis with the cross-step accumulator scratch -- the per-step K slab
    is ``block_k = block_c * kh * kw``.

    Use :func:`repro.kernels.ops.conv2d` for the NCHW/OIHW public API.
    """
    n, hp, wp, c = x.shape
    kk, c2, op = w.shape
    assert kk == kh * kw and c2 == c, (w.shape, (kh, kw, c))
    assert (hp - kh) % stride == 0, (hp, kh, stride)
    out_h = (hp - kh) // stride + 1
    out_w = (wp - kw) // stride + 1
    assert wp == (out_w - 1) * stride + kw, (wp, out_w, kw, stride)
    assert out_h % block_h == 0, (out_h, block_h)
    assert op % block_o == 0, (op, block_o)
    assert block_c >= 0 and (not block_c or c % block_c == 0), (c, block_c)
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    validate_epilogue(epilogue, len(sides))
    bm = block_h * out_w
    m = n * out_h * out_w
    for s in sides:
        assert s.shape == (m, op), (s.shape, (m, op))
    a8 = jnp.issubdtype(x.dtype, jnp.integer)
    out_dtype = out_dtype or (jnp.float32 if jnp.issubdtype(w.dtype, jnp.integer) else x.dtype)
    n_h_tiles = out_h // block_h
    tiled_k = bool(block_c)
    bc = block_c or c
    if tiled_k:
        grid = (n, n_h_tiles, op // block_o, c // block_c)
        in_specs = [
            pl.BlockSpec((1, hp, wp, bc), lambda nn, i, j, kc: (nn, 0, 0, kc)),
            pl.BlockSpec((kk, bc, block_o), lambda nn, i, j, kc: (0, kc, j)),
        ]
        vec_tile = pl.BlockSpec((1, block_o), lambda nn, i, j, kc: (0, j))
        out_tile = pl.BlockSpec(
            (bm, block_o), lambda nn, i, j, kc: (nn * n_h_tiles + i, j)
        )
        scratch = [pltpu.VMEM((bm, block_o), jnp.int32 if a8 else jnp.float32)]
        # kc is the contraction: it must stay sequential so the accumulator
        # scratch lives across it (the grid pipeline still double-buffers the
        # streamed image/filter blocks underneath)
        semantics = ("parallel", "parallel", "parallel", "arbitrary")
    else:
        grid = (n, n_h_tiles, op // block_o)
        in_specs = [
            pl.BlockSpec((1, hp, wp, c), lambda nn, i, j: (nn, 0, 0, 0)),
            pl.BlockSpec((kk, c, block_o), lambda nn, i, j: (0, 0, j)),
        ]
        vec_tile = pl.BlockSpec((1, block_o), lambda nn, i, j: (0, j))
        out_tile = pl.BlockSpec(
            (bm, block_o), lambda nn, i, j: (nn * n_h_tiles + i, j)
        )
        scratch = []
        semantics = ("parallel", "parallel", "parallel")
    args = [x, w]
    has_ws = ws is not None
    if has_ws:
        assert ws.shape == (op,), (ws.shape, op)
        in_specs.append(vec_tile)
        args.append(ws.reshape(1, op).astype(jnp.float32))
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (op,), (bias.shape, op)
        in_specs.append(vec_tile)
        args.append(bias.reshape(1, op))
    in_specs.extend([out_tile] * len(sides))
    args.extend(sides)
    n_sides = len(sides)

    def kern(*refs):
        # refs: x, w, [ws], [bias], *sides, o, [acc]
        pos = 2
        ws_ref = refs[pos] if has_ws else None
        pos += int(has_ws)
        b_ref = refs[pos] if has_bias else None
        pos += int(has_bias)
        conv2d_gemm_kernel(
            refs[0],
            refs[1],
            ws_ref,
            b_ref,
            refs[pos : pos + n_sides],
            refs[-1 - len(scratch)],
            refs[-1] if tiled_k else None,
            stride=stride,
            kh=kh,
            kw=kw,
            block_h=block_h,
            out_w=out_w,
            activation=activation,
            epilogue=epilogue,
        )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((m, op), out_dtype),
        scratch_shapes=scratch,
        compiler_params=_tpu_compiler_params(
            dimension_semantics=semantics
        ),
        interpret=interpret,
    )(*args)
