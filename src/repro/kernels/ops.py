"""Public jit'd wrappers around the Pallas kernels.

These handle the unglamorous parts -- leading-batch flattening, padding to
block multiples, interpret-mode selection (CPU container vs real TPU), band
dispatch for reordered BSR weights -- so models call one function per op.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .bsr_matmul import bsr_matmul as _bsr_matmul
from .dense_matmul import dense_matmul as _dense_matmul
from .flash_attention import flash_attention as _flash_attention
from .fused_ffn import ffn_gateup as _ffn_gateup

__all__ = ["interpret_default", "matmul", "bsr_matmul", "col_matmul", "ffn_gateup", "attention"]


def interpret_default() -> bool:
    """Pallas interpret mode: forced via REPRO_PALLAS_INTERPRET, else on
    whenever we are not running on real TPU hardware."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _flatten_batch(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def matmul(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``act(x @ w + bias)`` for arbitrary leading batch dims via the fused
    dense Pallas kernel; pads M/N/K to block multiples and slices back."""
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    n = w.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w, block_k, 0), block_n, 1)
    bp = None if bias is None else _pad_axis(bias, block_n, 0)
    out = _dense_matmul(
        xp,
        wp,
        bp,
        activation=activation,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :n]
    return out.reshape(*lead, n)


def bsr_matmul(
    x: jax.Array,
    values: jax.Array,
    block_rows: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    block_m: int = 128,
    bands: Optional[Sequence[Tuple[int, int, int]]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Block-sparse ``act(x @ W + bias)`` over PBCSR-packed weights.

    ``bands`` (from the reorder pass): sequence of ``(start, stop, count)``
    over output block-columns; one pallas_call per band with exact trip count
    ``count``.  Without bands, a single call pads every column to the global
    max count.
    """
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    nb, s, bm, bn = values.shape
    n = nb * bn
    assert k == block_rows.shape[0] * 0 + k  # k checked in kernel
    xp = _pad_axis(x2, block_m, 0)

    def run(vals, rows, bias_slice):
        return _bsr_matmul(
            xp,
            vals,
            rows,
            bias_slice,
            activation=activation,
            block_m=block_m,
            interpret=interpret,
        )

    if not bands:
        out = run(values, block_rows, bias)
    else:
        pieces = []
        for start, stop, count in bands:
            if stop <= start:
                continue
            cols = slice(start, stop)
            if count == 0:
                # empty band: output is pure epilogue (bias/activation of 0)
                z = jnp.zeros((xp.shape[0], (stop - start) * bn), x.dtype)
                if bias is not None:
                    z = z + bias[start * bn : stop * bn].astype(x.dtype)
                if activation is not None:
                    z = _ref._ACT[activation](z.astype(jnp.float32)).astype(x.dtype)
                pieces.append(z)
                continue
            pieces.append(
                run(
                    values[cols, :count],
                    block_rows[cols, :count],
                    None if bias is None else bias[start * bn : stop * bn],
                )
            )
        out = jnp.concatenate(pieces, axis=-1)
    return out[:m].reshape(*lead, n)


def col_matmul(
    x: jax.Array,
    values: jax.Array,
    kept: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Column-pruned ``act(x @ W + bias)``: static input gather (XLA) + the
    strictly smaller fused dense GEMM (Pallas).  ``values [K_kept, N]``."""
    xg = jnp.take(x, kept, axis=-1)
    return matmul(xg, values, bias, activation=activation, interpret=interpret)


def ffn_gateup(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    *,
    activation: str = "silu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused ``act(x@Wg) * (x@Wu)`` with padding handling."""
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    f = w_gate.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wgp = _pad_axis(_pad_axis(w_gate, block_k, 0), block_n, 1)
    wup = _pad_axis(_pad_axis(w_up, block_k, 0), block_n, 1)
    out = _ffn_gateup(
        xp,
        wgp,
        wup,
        activation=activation,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :f]
    return out.reshape(*lead, f)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    scale=None, block_q: int = 128, block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention over [B, H, S, d] (pads S to block multiples)."""
    interpret = interpret_default() if interpret is None else interpret
    sq, skv = q.shape[2], k.shape[2]
    qp = _pad_axis(q, block_q, 2)
    kp = _pad_axis(k, block_k, 2)
    vp = _pad_axis(v, block_k, 2)
    # padded KV columns must not attract probability mass: causal masking
    # handles the tail whenever sq == skv; for cross/kv-padded cases pad K
    # with -inf-producing zeros is insufficient -> require causal here.
    assert causal or (sq % block_q == 0 and skv % block_k == 0), (
        "non-causal attention requires block-aligned shapes")
    out = _flash_attention(
        qp, kp, vp, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :, :sq]
