"""Public jit'd wrappers around the Pallas kernels.

These handle the unglamorous parts -- leading-batch flattening, padding to
block multiples, interpret-mode selection (CPU container vs real TPU), band
dispatch for reordered BSR weights -- so models call one function per op.

Block sizes are no longer frozen at 128: when a call does not pin them
explicitly, they come from the :class:`TuningCache` -- keyed by
``(op, M, N, K, dtype, format)``, seeded with sane defaults (so tests never
pay a sweep), and able to sweep a small candidate grid once per shape when
tuning is enabled (``REPRO_TUNE=1`` or :func:`set_tuning`).  The cache
persists to JSON (``REPRO_TUNE_CACHE=path`` or ``save``/``load``) -- the
paper's compiler "parameter auto-tuning" applied to Pallas tiling.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .bsr_matmul import bsr_matmul as _bsr_matmul
from .dense_matmul import dense_matmul as _dense_matmul
from .flash_attention import flash_attention as _flash_attention
from .fused_elementwise import fused_elementwise as _fused_elementwise
from .fused_ffn import ffn_gateup as _ffn_gateup
from .pallas_compat import interpret_default
from .quant_matmul import quant_matmul as _quant_matmul

__all__ = [
    "interpret_default",
    "matmul",
    "bsr_matmul",
    "col_matmul",
    "fused_elementwise",
    "ffn_gateup",
    "qmatmul",
    "attention",
    "TuningCache",
    "tuning_cache",
    "set_tuning",
]


def _flatten_batch(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------- #
# block-size tuning cache                                                      #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class TuneEntry:
    blocks: Tuple[int, ...]
    source: str  # "default" | "swept" | "loaded"
    ms: Optional[float] = None


class TuningCache:
    """Per-shape kernel block-size cache, keyed by
    ``(op, M, N, K, dtype, format)``.

    ``resolve`` returns cached blocks when the key is known; otherwise, with
    tuning enabled *and* a runner supplied (concrete arrays, not tracers), it
    sweeps the candidate grid once, stores the winner, and returns it.  With
    tuning disabled it records + returns the seeded default, so test suites
    never pay a sweep.
    """

    #: default blocks per op: matmul family is (block_m, block_n, block_k);
    #: bsr_matmul tunes only block_m (block_n/k come from the packed format);
    #: fused_elementwise tunes block_m (full feature dim is tile-resident)
    DEFAULTS: Dict[str, Tuple[int, ...]] = {
        "matmul": (128, 128, 128),
        "bsr_matmul": (128,),
        "fused_elementwise": (128,),
        "qmatmul": (128, 128, 128),
    }
    #: small sweep grids; TPU lanes want the minor dims at 128 multiples
    #: (pallas_guide: f32 min tile 8x128, MXU 128x128)
    CANDIDATES: Dict[str, Tuple[Tuple[int, ...], ...]] = {
        "matmul": (
            (128, 128, 128),
            (64, 128, 128),
            (256, 128, 128),
            (128, 256, 128),
            (128, 128, 256),
        ),
        "bsr_matmul": ((64,), (128,), (256,)),
        "fused_elementwise": ((64,), (128,), (256,), (512,)),
        # int8 tiles are (32, 128)-granular; larger K blocks amortize the
        # rescale and exploit the 4x smaller weight stream
        "qmatmul": (
            (128, 128, 128),
            (64, 128, 128),
            (256, 128, 128),
            (128, 256, 128),
            (128, 128, 256),
            (128, 128, 512),
        ),
    }

    def __init__(self, enabled: Optional[bool] = None, path: Optional[str] = None):
        env = os.environ.get("REPRO_TUNE")
        self.enabled = (env not in (None, "0", "false", "False")) if enabled is None else enabled
        self.entries: Dict[str, TuneEntry] = {}
        self.sweeps = 0  # number of grid sweeps actually executed
        self.path = path or os.environ.get("REPRO_TUNE_CACHE")
        if self.path and os.path.exists(self.path):
            try:
                self.load(self.path)
            except (json.JSONDecodeError, KeyError, TypeError, OSError) as e:
                # a stale/corrupt cache must never brick the import; sweeps
                # or defaults will repopulate it on the next save
                import warnings

                warnings.warn(f"ignoring unreadable tuning cache {self.path}: {e}")

    # -- keying -------------------------------------------------------------- #
    @staticmethod
    def key(op: str, m: int, n: int, k: int, dtype: Any, fmt: str, interpret: bool) -> str:
        # interpret-mode timings measure Python, not silicon: never let them
        # masquerade as (or shadow) real-hardware winners
        mode = "interpret" if interpret else "hw"
        return f"{op}|{int(m)}x{int(n)}x{int(k)}|{jnp.dtype(dtype).name}|{fmt}|{mode}"

    # -- lookup / sweep ------------------------------------------------------ #
    def lookup(self, op, m, n, k, dtype, fmt, interpret) -> Optional[Tuple[int, ...]]:
        e = self.entries.get(self.key(op, m, n, k, dtype, fmt, interpret))
        return None if e is None else e.blocks

    def resolve(
        self,
        op: str,
        m: int,
        n: int,
        k: int,
        dtype: Any,
        fmt: str,
        interpret: bool,
        runner: Optional[Callable[..., Any]] = None,
        reps: int = 3,
    ) -> Tuple[int, ...]:
        key = self.key(op, m, n, k, dtype, fmt, interpret)
        hit = self.entries.get(key)
        can_sweep = self.enabled and runner is not None
        # seeded-default entries are placeholders, not measurements: re-tune
        # them the first time a sweep is actually possible
        if hit is not None and not (can_sweep and hit.source == "default"):
            return hit.blocks
        if can_sweep:
            best, best_ms = None, float("inf")
            for cand in self.CANDIDATES[op]:
                try:
                    jax.block_until_ready(runner(*cand))  # compile + warm
                    ts = []
                    for _ in range(reps):
                        t0 = time.perf_counter()
                        jax.block_until_ready(runner(*cand))
                        ts.append(time.perf_counter() - t0)
                    ms = float(np.median(ts)) * 1e3
                except Exception:
                    continue  # candidate invalid for this shape/backend
                if ms < best_ms:
                    best, best_ms = cand, ms
            self.sweeps += 1
            if best is not None:
                self.entries[key] = TuneEntry(best, "swept", best_ms)
                return best
        default = self.DEFAULTS[op]
        self.entries[key] = TuneEntry(default, "default")
        return default

    # -- persistence --------------------------------------------------------- #
    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("no cache path given (arg or REPRO_TUNE_CACHE)")
        payload = {
            "version": 1,
            # defaults are placeholders (never measured): persisting them
            # would block future sweeps of those shapes in other processes
            "entries": {
                k: {"blocks": list(e.blocks), "source": e.source, "ms": e.ms}
                for k, e in self.entries.items()
                if e.source != "default"
            },
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        return path

    def load(self, path: str) -> "TuningCache":
        with open(path) as f:
            payload = json.load(f)
        for k, e in payload["entries"].items():
            self.entries[k] = TuneEntry(tuple(e["blocks"]), "loaded", e.get("ms"))
        return self

    def clear(self) -> None:
        self.entries.clear()
        self.sweeps = 0

    def report(self) -> str:
        lines = ["op,shape,dtype,format,mode,blocks,source,ms"]
        for k in sorted(self.entries):
            op, shape, dt, fmt, mode = k.split("|")
            e = self.entries[k]
            ms = "" if e.ms is None else f"{e.ms:.3f}"
            lines.append(
                f"{op},{shape},{dt},{fmt},{mode},{'x'.join(map(str, e.blocks))},{e.source},{ms}"
            )
        return "\n".join(lines)


_TUNING = TuningCache()


def tuning_cache() -> TuningCache:
    """The process-wide block-size cache consulted by matmul/bsr_matmul/
    col_matmul when block sizes are not pinned explicitly."""
    return _TUNING


def set_tuning(enabled: bool) -> TuningCache:
    _TUNING.enabled = enabled
    return _TUNING


def _concrete(*arrays) -> bool:
    """True when no argument is a tracer (sweeping requires real timing)."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _matmul_blocked(
    x2, w, bias, activation, block_m, block_n, block_k, interpret,
    epilogue=(), sides=(),
):
    m, k = x2.shape
    n = w.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w, block_k, 0), block_n, 1)
    bp = None if bias is None else _pad_axis(bias, block_n, 0)
    sp = [_pad_axis(_pad_axis(s, block_m, 0), block_n, 1) for s in sides]
    return _dense_matmul(
        xp,
        wp,
        bp,
        *sp,
        activation=activation,
        epilogue=tuple(epilogue),
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :n]


def matmul(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
    _format: str = "dense",
) -> jax.Array:
    """``epilogue(act(x @ w + bias))`` for arbitrary leading batch dims via
    the fused dense Pallas kernel; pads M/N/K to block multiples and slices
    back.  ``epilogue`` is a step program (``("activation", fn)`` /
    ``("add"|"mul", slot)`` into ``epilogue_sides``, each shaped like the
    output) run on the f32 accumulator inside the kernel.

    Block sizes left as ``None`` are resolved through the tuning cache
    (cached winner for this shape if one exists, else the seeded default;
    a one-off candidate sweep when tuning is enabled on concrete arrays).
    """
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    n = w.shape[1]
    sides2 = []
    for s in epilogue_sides:
        assert s.shape == (*lead, n) or s.shape == (m, n), (s.shape, (*lead, n))
        sides2.append(s.reshape(m, n))
    if block_m is None and block_n is None and block_k is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, w, bias, *sides2):
            runner = lambda bm, bn, bk: _matmul_blocked(
                x2, w, bias, activation, bm, bn, bk, interpret, epilogue, sides2
            )
        # an epilogue'd GEMM streams extra per-tile sides (different VMEM
        # pressure): never let its swept winner alias the plain GEMM's
        fmt = (
            f"{_format}+e{len(epilogue)}s{len(sides2)}" if epilogue else _format
        )
        block_m, block_n, block_k = _TUNING.resolve(
            "matmul", m, n, k, x2.dtype, fmt, interpret, runner
        )
    elif block_m is None or block_n is None or block_k is None:
        # partially pinned: fill from defaults, never from the cache -- a
        # swept winner for the free dims was timed with different pins
        dm, dn, dk = TuningCache.DEFAULTS["matmul"]
        block_m, block_n, block_k = block_m or dm, block_n or dn, block_k or dk
    out = _matmul_blocked(
        x2, w, bias, activation, block_m, block_n, block_k, interpret,
        epilogue, sides2,
    )
    return out.reshape(*lead, n)


def _qmatmul_blocked(
    x2, w_q, w_scale, bias, activation, block_m, block_n, block_k, interpret,
    epilogue=(), sides=(),
):
    m, k = x2.shape
    n = w_q.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w_q, block_k, 0), block_n, 1)
    wsp = _pad_axis(w_scale, block_n, 0)
    bp = None if bias is None else _pad_axis(bias, block_n, 0)
    sp = [_pad_axis(_pad_axis(s, block_m, 0), block_n, 1) for s in sides]
    return _quant_matmul(
        xp,
        wp,
        wsp,
        bp,
        *sp,
        activation=activation,
        epilogue=tuple(epilogue),
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :n]


def qmatmul(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    x_scale: Optional[float] = None,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
    _format: str = "dense",
) -> jax.Array:
    """Quantized ``epilogue(act((x @ w_q) * scales + bias))`` for arbitrary
    leading batch dims via the INT8 Pallas kernel.

    ``w_q [K, N]`` int8 with per-output-channel ``w_scale [N]`` f32.  With
    ``x_scale`` (the calibrated static activation scale, a Python float) the
    f32 activations are quantized to int8 here and the kernel contracts
    int8 x int8 into int32 (**W8A8**; the activation scale is folded into the
    per-column rescale).  Without it, activations stay f32 and only the
    weight stream is int8, dequantized per-tile in VMEM (**W8-only** -- the
    scheme the colcompact/channelcompact pruned formats use).

    Tuned under the ``qmatmul`` cache key family: the format string carries
    the storage format *and* the scheme (``dense+w8a8``, ``colcompact+w8``,
    ...) plus the usual ``+e{steps}s{sides}`` epilogue suffix -- int8 streams
    change VMEM residency and arithmetic width, so a winner never aliases the
    f32 ``matmul`` family.
    """
    from ..quant.qtensor import quantize_array  # local: quant layer is optional

    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    n = w_q.shape[1]
    sides2 = []
    for s in epilogue_sides:
        assert s.shape == (*lead, n) or s.shape == (m, n), (s.shape, (*lead, n))
        sides2.append(s.reshape(m, n))
    w_scale = w_scale.astype(jnp.float32)
    if x_scale is not None:
        # W8A8: statically-scaled int8 activations; kernel sees one combined
        # per-column rescale (x_scale * w_scale[n])
        x2 = quantize_array(x2, jnp.float32(x_scale))
        w_scale = w_scale * jnp.float32(x_scale)
    scheme = "w8" if x_scale is None else "w8a8"
    if block_m is None and block_n is None and block_k is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, w_q, w_scale, bias, *sides2):
            runner = lambda bm, bn, bk: _qmatmul_blocked(
                x2, w_q, w_scale, bias, activation, bm, bn, bk, interpret,
                epilogue, sides2,
            )
        fmt = f"{_format}+{scheme}"
        if epilogue:
            fmt += f"+e{len(epilogue)}s{len(sides2)}"
        block_m, block_n, block_k = _TUNING.resolve(
            "qmatmul", m, n, k, x2.dtype, fmt, interpret, runner
        )
    elif block_m is None or block_n is None or block_k is None:
        dm, dn, dk = TuningCache.DEFAULTS["qmatmul"]
        block_m, block_n, block_k = block_m or dm, block_n or dn, block_k or dk
    out = _qmatmul_blocked(
        x2, w_q, w_scale, bias, activation, block_m, block_n, block_k,
        interpret, epilogue, sides2,
    )
    return out.reshape(*lead, n)


def fused_elementwise(
    x: jax.Array,
    sides: Sequence[jax.Array] = (),
    steps: Sequence[Tuple] = (),
    norm_params: Sequence[Tuple[jax.Array, jax.Array]] = (),
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Run a fused elementwise step program over ``x`` in one Pallas pass.

    ``x`` has any leading batch dims; steps operate on the flattened
    ``[M, D]`` view (D = last dim, the layer-norm axis).  ``sides`` must
    match ``x``'s shape exactly (the tiled kernel streams them per-block);
    ``norm_params`` is one (scale[D], bias[D]) pair per ``("norm", slot,
    eps)`` step.  One HBM read + write total instead of one per step.

    ``block_m=None`` consults the tuning cache under the
    ``fused_elementwise`` op key (M x D x n_steps).
    """
    interpret = interpret_default() if interpret is None else interpret
    d = x.shape[-1]
    for s in sides:
        assert s.shape == x.shape, (s.shape, x.shape)
    x2, lead = _flatten_batch(x)
    m = x2.shape[0]
    steps = tuple(tuple(s) for s in steps)

    def run(bm):
        xp = _pad_axis(_pad_axis(x2, bm, 0), 128, 1)
        sp = [_pad_axis(_pad_axis(s.reshape(m, d), bm, 0), 128, 1) for s in sides]
        nps = []
        for scale, bias in norm_params:
            nps.append(_pad_axis(scale, 128, 0).reshape(1, -1))
            nps.append(_pad_axis(bias, 128, 0).reshape(1, -1))
        return _fused_elementwise(
            xp,
            *sp,
            *nps,
            steps=steps,
            n_norms=len(norm_params),
            d_true=d,
            block_m=bm,
            interpret=interpret,
        )[:m, :d]

    if block_m is None:
        runner = None
        flat_norms = [a for pair in norm_params for a in pair]
        if _TUNING.enabled and _concrete(x2, *sides, *flat_norms):
            runner = lambda bm: run(bm)
        # side/norm counts change per-tile VMEM residency: same-shape
        # programs with different operand counts must not share a winner
        fmt = f"ew+s{len(sides)}n{len(norm_params)}"
        (block_m,) = _TUNING.resolve(
            "fused_elementwise", m, d, len(steps), x2.dtype, fmt, interpret, runner
        )
    return run(block_m).reshape(x.shape)


def bsr_matmul(
    x: jax.Array,
    values: jax.Array,
    block_rows: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_m: Optional[int] = None,
    bands: Optional[Sequence[Tuple[int, int, int]]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Block-sparse ``epilogue(act(x @ W + bias))`` over PBCSR-packed weights.

    ``bands`` (from the reorder pass): sequence of ``(start, stop, count)``
    over output block-columns; one pallas_call per band with exact trip count
    ``count``.  Without bands, a single call pads every column to the global
    max count.  ``epilogue`` is the same step program as :func:`matmul`,
    executed on the f32 accumulator inside each band's kernel (sides are
    sliced per band and streamed per output tile).  ``block_m=None``
    consults the tuning cache -- an epilogue'd call keys separately
    (``pbcsr+e{steps}s{sides}``) since the extra side streams change VMEM
    residency.
    """
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    nb, s, bm, bn = values.shape
    n = nb * bn
    epilogue = tuple(tuple(st) for st in epilogue)
    sides2 = []
    for sv in epilogue_sides:
        assert sv.shape == (*lead, n) or sv.shape == (m, n), (sv.shape, (*lead, n))
        sides2.append(sv.reshape(m, n))

    def compute(block_m):
        xp = _pad_axis(x2, block_m, 0)
        sp = [_pad_axis(sv, block_m, 0) for sv in sides2]

        def run(vals, rows, bias_slice, side_slices):
            return _bsr_matmul(
                xp,
                vals,
                rows,
                bias_slice,
                *side_slices,
                activation=activation,
                epilogue=epilogue,
                block_m=block_m,
                interpret=interpret,
            )

        if not bands:
            return run(values, block_rows, bias, sp)
        pieces = []
        for start, stop, count in bands:
            if stop <= start:
                continue
            cols = slice(start, stop)
            side_slices = [sv[:, start * bn : stop * bn] for sv in sp]
            if count == 0:
                # empty band: output is pure epilogue (bias/activation of 0)
                z = jnp.zeros((xp.shape[0], (stop - start) * bn), jnp.float32)
                if bias is not None:
                    z = z + bias[start * bn : stop * bn].astype(jnp.float32)
                z = _ref._ACT[activation](z)
                if epilogue:
                    z = _ref.apply_steps_ref(
                        z, epilogue, [sl.astype(jnp.float32) for sl in side_slices]
                    )
                pieces.append(z.astype(x.dtype))
                continue
            pieces.append(
                run(
                    values[cols, :count],
                    block_rows[cols, :count],
                    None if bias is None else bias[start * bn : stop * bn],
                    side_slices,
                )
            )
        return jnp.concatenate(pieces, axis=-1)

    if block_m is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, values, block_rows, bias, *sides2):
            runner = compute
        fmt = "pbcsr"
        if epilogue:
            fmt += f"+e{len(epilogue)}s{len(sides2)}"
        (block_m,) = _TUNING.resolve(
            "bsr_matmul", m, n, k, x2.dtype, fmt, interpret, runner
        )
    out = compute(block_m)
    return out[:m].reshape(*lead, n)


def col_matmul(
    x: jax.Array,
    values: jax.Array,
    kept: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Column-pruned ``act(x @ W + bias)``: static input gather (XLA) + the
    strictly smaller fused dense GEMM (Pallas), with the same fused
    ``epilogue`` program as :func:`matmul`.  ``values [K_kept, N]``.
    Tuned under its own ``colcompact`` cache key (the gathered K differs
    from the dense layer's)."""
    xg = jnp.take(x, kept, axis=-1)
    return matmul(
        xg, values, bias, activation=activation,
        epilogue=epilogue, epilogue_sides=epilogue_sides, interpret=interpret,
        _format="colcompact",
    )


def ffn_gateup(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    *,
    activation: str = "silu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused ``act(x@Wg) * (x@Wu)`` with padding handling."""
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    f = w_gate.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wgp = _pad_axis(_pad_axis(w_gate, block_k, 0), block_n, 1)
    wup = _pad_axis(_pad_axis(w_up, block_k, 0), block_n, 1)
    out = _ffn_gateup(
        xp,
        wgp,
        wup,
        activation=activation,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :f]
    return out.reshape(*lead, f)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    scale=None, block_q: int = 128, block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention over [B, H, S, d] (pads S to block multiples)."""
    interpret = interpret_default() if interpret is None else interpret
    sq, skv = q.shape[2], k.shape[2]
    qp = _pad_axis(q, block_q, 2)
    kp = _pad_axis(k, block_k, 2)
    vp = _pad_axis(v, block_k, 2)
    # padded KV columns must not attract probability mass: causal masking
    # handles the tail whenever sq == skv; for cross/kv-padded cases pad K
    # with -inf-producing zeros is insufficient -> require causal here.
    assert causal or (sq % block_q == 0 and skv % block_k == 0), (
        "non-causal attention requires block-aligned shapes")
    out = _flash_attention(
        qp, kp, vp, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :, :sq]
