"""Public jit'd wrappers around the Pallas kernels.

These handle the unglamorous parts -- leading-batch flattening, padding to
block multiples, interpret-mode selection (CPU container vs real TPU), band
dispatch for reordered BSR weights -- so models call one function per op.

Block sizes are no longer frozen at 128: when a call does not pin them
explicitly, they come from the :class:`TuningCache` -- keyed by
``(op, M, N, K, dtype, format)``, seeded with sane defaults (so tests never
pay a sweep), and able to sweep a small candidate grid once per shape when
tuning is enabled (``REPRO_TUNE=1`` or :func:`set_tuning`).  The cache
persists to JSON (``REPRO_TUNE_CACHE=path`` or ``save``/``load``) -- the
paper's compiler "parameter auto-tuning" applied to Pallas tiling.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics
from . import ref as _ref
from .bsr_matmul import bsr_matmul as _bsr_matmul
from .conv2d import conv2d_gemm as _conv2d_gemm
from .conv2d import (
    conv_out_hw,
    conv_pad_hw,
    conv_padding_token,
    conv_vmem_workspace,
)
from .dense_matmul import dense_matmul as _dense_matmul
from .flash_attention import flash_attention as _flash_attention
from .fused_elementwise import fused_elementwise as _fused_elementwise
from .fused_ffn import ffn_gateup as _ffn_gateup
from .pallas_compat import interpret_default
from .quant_matmul import quant_matmul as _quant_matmul

__all__ = [
    "interpret_default",
    "matmul",
    "bsr_matmul",
    "col_matmul",
    "conv2d",
    "conv_out_hw",
    "conv_padding_token",
    "conv_vmem_workspace",
    "conv_fallback_counts",
    "conv_fallback_reason",
    "reset_conv_fallbacks",
    "conv_fastpath_counts",
    "conv_gemm1x1_elected",
    "reset_conv_fastpaths",
    "fused_elementwise",
    "ffn_gateup",
    "qmatmul",
    "attention",
    "TuningCache",
    "tuning_cache",
    "set_tuning",
]


def _flatten_batch(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------- #
# block-size tuning cache                                                      #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class TuneEntry:
    blocks: Tuple[int, ...]
    source: str  # "default" | "swept" | "loaded"
    ms: Optional[float] = None


class TuningCache:
    """Per-shape kernel block-size cache, keyed by
    ``(op, M, N, K, dtype, format)``.

    ``resolve`` returns cached blocks when the key is known; otherwise, with
    tuning enabled *and* a runner supplied (concrete arrays, not tracers), it
    sweeps the candidate grid once, stores the winner, and returns it.  With
    tuning disabled it records + returns the seeded default, so test suites
    never pay a sweep.
    """

    #: default blocks per op: matmul family is (block_m, block_n, block_k,
    #: pipeline_depth) -- depth 1 is the compiler-scheduled grid-K path,
    #: depth >= 2 the hand-rolled double-buffered K streaming ring;
    #: bsr_matmul tunes only block_m (block_n/k come from the packed format);
    #: fused_elementwise tunes block_m (full feature dim is tile-resident)
    DEFAULTS: Dict[str, Tuple[int, ...]] = {
        "matmul": (128, 128, 128, 1),
        "bsr_matmul": (128,),
        "fused_elementwise": (128,),
        "qmatmul": (128, 128, 128, 1),
        # conv2d tunes (block_h, block_o, block_c): output rows per tile (the
        # GEMM M block is block_h * OW), output-channel lanes per tile, and
        # the tiled-K channel granularity (0 = resident full-K contraction;
        # block_c > 0 streams block_k = block_c*kh*kw K-slabs per grid step)
        "conv2d": (8, 128, 0),
    }
    #: small sweep grids; TPU lanes want the minor dims at 128 multiples
    #: (pallas_guide: f32 min tile 8x128, MXU 128x128)
    CANDIDATES: Dict[str, Tuple[Tuple[int, ...], ...]] = {
        "matmul": (
            (128, 128, 128, 1),
            (64, 128, 128, 1),
            (256, 128, 128, 1),
            (128, 256, 128, 1),
            (128, 128, 256, 1),
            # hand-pipelined double-buffered K streaming (depth-2 ring)
            (128, 128, 128, 2),
            (128, 128, 256, 2),
        ),
        "bsr_matmul": ((64,), (128,), (256,)),
        "fused_elementwise": ((64,), (128,), (256,), (512,), (1024,)),
        # int8 tiles are (32, 128)-granular; larger K blocks amortize the
        # rescale and exploit the 4x smaller weight stream
        "qmatmul": (
            (128, 128, 128, 1),
            (64, 128, 128, 1),
            (256, 128, 128, 1),
            (128, 256, 128, 1),
            (128, 128, 256, 1),
            (128, 128, 512, 1),
            # hand-pipelined ring: int8 slabs are 4x smaller, deeper K pays
            (128, 128, 128, 2),
            (128, 128, 512, 2),
        ),
        # more rows per tile amortizes the per-tap patch slicing; larger
        # block_o amortizes image residency across output channels; non-zero
        # block_c trades image residency for the tiled-K accumulator
        "conv2d": (
            (1, 128, 0),
            (2, 128, 0),
            (4, 128, 0),
            (8, 128, 0),
            (16, 128, 0),
            (4, 256, 0),
            (8, 256, 0),
            (8, 128, 64),
            (8, 128, 128),
            (4, 256, 128),
        ),
    }

    def __init__(self, enabled: Optional[bool] = None, path: Optional[str] = None):
        env = os.environ.get("REPRO_TUNE")
        self.enabled = (env not in (None, "0", "false", "False")) if enabled is None else enabled
        self.entries: Dict[str, TuneEntry] = {}
        self.sweeps = 0  # number of grid sweeps actually executed
        #: restrict sweeping to these op families (None = all); lookups and
        #: defaults still serve every family (the tune CLI's --ops filter)
        self.ops_filter: Optional[frozenset] = None
        #: per-key-family resolve accounting: hits (cached winner returned),
        #: misses (no usable entry -- default recorded or sweep triggered),
        #: sweeps (candidate grids actually timed)
        self.stats: Dict[str, Dict[str, int]] = {}
        self.path = path or os.environ.get("REPRO_TUNE_CACHE")
        if self.path and os.path.exists(self.path):
            try:
                self.load(self.path)
            except (json.JSONDecodeError, KeyError, TypeError, OSError) as e:
                # a stale/corrupt cache must never brick the import; sweeps
                # or defaults will repopulate it on the next save
                import warnings

                warnings.warn(f"ignoring unreadable tuning cache {self.path}: {e}")

    # -- keying -------------------------------------------------------------- #
    @staticmethod
    def key_nd(op: str, shape: Sequence[int], dtype: Any, fmt: str, interpret: bool) -> str:
        """Key over an arbitrary-rank shape signature: the GEMM family keys
        on ``MxNxK``, ``conv2d`` on ``NxCxHxWxOxKHxKWxS`` (batch, contracted
        input channels, spatial dims, output channels, filter taps, stride).
        interpret-mode timings measure Python, not silicon: never let them
        masquerade as (or shadow) real-hardware winners."""
        mode = "interpret" if interpret else "hw"
        dims = "x".join(str(int(d)) for d in shape)
        return f"{op}|{dims}|{jnp.dtype(dtype).name}|{fmt}|{mode}"

    @staticmethod
    def key(op: str, m: int, n: int, k: int, dtype: Any, fmt: str, interpret: bool) -> str:
        return TuningCache.key_nd(op, (m, n, k), dtype, fmt, interpret)

    # -- lookup / sweep ------------------------------------------------------ #
    def lookup(self, op, m, n, k, dtype, fmt, interpret) -> Optional[Tuple[int, ...]]:
        return self.lookup_nd(op, (m, n, k), dtype, fmt, interpret)

    def lookup_nd(self, op, shape, dtype, fmt, interpret) -> Optional[Tuple[int, ...]]:
        e = self.entries.get(self.key_nd(op, shape, dtype, fmt, interpret))
        return None if e is None else e.blocks

    def resolve(
        self,
        op: str,
        m: int,
        n: int,
        k: int,
        dtype: Any,
        fmt: str,
        interpret: bool,
        runner: Optional[Callable[..., Any]] = None,
        reps: int = 3,
        default: Optional[Tuple[int, ...]] = None,
    ) -> Tuple[int, ...]:
        return self.resolve_nd(
            op, (m, n, k), dtype, fmt, interpret, runner, reps, default
        )

    def resolve_nd(
        self,
        op: str,
        shape: Sequence[int],
        dtype: Any,
        fmt: str,
        interpret: bool,
        runner: Optional[Callable[..., Any]] = None,
        reps: int = 3,
        default: Optional[Tuple[int, ...]] = None,
    ) -> Tuple[int, ...]:
        """Cached winner for the key if one exists; else sweep (tuning
        enabled + concrete runner + op not excluded by ``ops_filter``) or
        fall back to ``default`` (the caller's shape/mode-aware seed) or the
        op family's static ``DEFAULTS`` entry."""
        key = self.key_nd(op, shape, dtype, fmt, interpret)
        stat = self.stats.setdefault(op, {"hits": 0, "misses": 0, "sweeps": 0})
        hit = self.entries.get(key)
        can_sweep = (
            self.enabled
            and runner is not None
            and (self.ops_filter is None or op in self.ops_filter)
        )
        # seeded-default entries are placeholders, not measurements: re-tune
        # them the first time a sweep is actually possible
        if hit is not None and not (can_sweep and hit.source == "default"):
            stat["hits"] += 1
            return hit.blocks
        stat["misses"] += 1
        if can_sweep:
            best, best_ms = None, float("inf")
            for cand in self.CANDIDATES[op]:
                try:
                    jax.block_until_ready(runner(*cand))  # compile + warm
                    ts = []
                    for _ in range(reps):
                        t0 = time.perf_counter()
                        jax.block_until_ready(runner(*cand))
                        ts.append(time.perf_counter() - t0)
                    ms = float(np.median(ts)) * 1e3
                except Exception:
                    continue  # candidate invalid for this shape/backend
                if ms < best_ms:
                    best, best_ms = cand, ms
            self.sweeps += 1
            stat["sweeps"] += 1
            if best is not None:
                self.entries[key] = TuneEntry(best, "swept", best_ms)
                return best
        default = default or self.DEFAULTS[op]
        self.entries[key] = TuneEntry(default, "default")
        return default

    # -- persistence --------------------------------------------------------- #
    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("no cache path given (arg or REPRO_TUNE_CACHE)")
        payload = {
            "version": 1,
            # defaults are placeholders (never measured): persisting them
            # would block future sweeps of those shapes in other processes
            "entries": {
                k: {"blocks": list(e.blocks), "source": e.source, "ms": e.ms}
                for k, e in self.entries.items()
                if e.source != "default"
            },
        }
        # crash-safe (utils.fileio): temp file in the target directory,
        # fsync, atomic rename -- a reader can never observe a truncated
        # JSON and an interrupted save leaves the previous file intact
        from ..utils.fileio import atomic_write_json

        return atomic_write_json(path, payload, prefix=".tune-")

    def load(self, path: str) -> "TuningCache":
        with open(path) as f:
            payload = json.load(f)
        for k, e in payload["entries"].items():
            self.entries[k] = TuneEntry(tuple(e["blocks"]), "loaded", e.get("ms"))
        return self

    def clear(self) -> None:
        self.entries.clear()
        self.sweeps = 0
        self.stats.clear()

    def stats_report(self) -> str:
        """Per-key-family resolve accounting (hits / misses / sweeps) --
        printed by the ``launch.tune`` CLI after a pre-warm pass."""
        lines = ["family,hits,misses,sweeps"]
        for op in sorted(self.stats):
            s = self.stats[op]
            lines.append(f"{op},{s['hits']},{s['misses']},{s['sweeps']}")
        return "\n".join(lines)

    def report(self) -> str:
        lines = ["op,shape,dtype,format,mode,blocks,source,ms"]
        for k in sorted(self.entries):
            op, shape, dt, fmt, mode = k.split("|")
            e = self.entries[k]
            ms = "" if e.ms is None else f"{e.ms:.3f}"
            lines.append(
                f"{op},{shape},{dt},{fmt},{mode},{'x'.join(map(str, e.blocks))},{e.source},{ms}"
            )
        return "\n".join(lines)


_TUNING = TuningCache()


def tuning_cache() -> TuningCache:
    """The process-wide block-size cache consulted by matmul/bsr_matmul/
    col_matmul when block sizes are not pinned explicitly."""
    return _TUNING


def set_tuning(enabled: bool) -> TuningCache:
    _TUNING.enabled = enabled
    return _TUNING


def _concrete(*arrays) -> bool:
    """True when no argument is a tracer (sweeping requires real timing)."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _blocks4(blocks: Sequence[int]) -> Tuple[int, int, int, int]:
    """Normalize a matmul-family blocks tuple: legacy 3-field entries (from
    pre-pipeline cache files) mean the compiler-scheduled grid-K path
    (pipeline depth 1)."""
    t = tuple(int(b) for b in blocks)
    return t if len(t) == 4 else (*t[:3], 1)


def _conv_blocks3(blocks: Sequence[int]) -> Tuple[int, int, int]:
    """Normalize a conv2d blocks tuple: legacy 2-field entries mean the
    resident full-K contraction (block_c == 0)."""
    t = tuple(int(b) for b in blocks)
    return t if len(t) == 3 else (*t[:2], 0)


def _matmul_blocked(
    x2, w, bias, activation, block_m, block_n, block_k, interpret,
    epilogue=(), sides=(), pipeline=1,
):
    m, k = x2.shape
    n = w.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w, block_k, 0), block_n, 1)
    bp = None if bias is None else _pad_axis(bias, block_n, 0)
    sp = [_pad_axis(_pad_axis(s, block_m, 0), block_n, 1) for s in sides]
    return _dense_matmul(
        xp,
        wp,
        bp,
        *sp,
        activation=activation,
        epilogue=tuple(epilogue),
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        pipeline=pipeline,
        interpret=interpret,
    )[:m, :n]


def matmul(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    pipeline: Optional[int] = None,
    interpret: Optional[bool] = None,
    _format: str = "dense",
) -> jax.Array:
    """``epilogue(act(x @ w + bias))`` for arbitrary leading batch dims via
    the fused dense Pallas kernel; pads M/N/K to block multiples and slices
    back.  ``epilogue`` is a step program (``("activation", fn)`` /
    ``("add"|"mul", slot)`` into ``epilogue_sides``, each shaped like the
    output) run on the f32 accumulator inside the kernel.

    Block sizes left as ``None`` are resolved through the tuning cache
    (cached winner for this shape if one exists, else the seeded default;
    a one-off candidate sweep when tuning is enabled on concrete arrays).
    The cached tuple's 4th field is the pipeline depth: 1 = grid-K (the
    compiler's automatic double-buffering), >= 2 = the hand-rolled DMA ring
    in :func:`~.dense_matmul.dense_matmul_pipelined_kernel`; ``pipeline``
    pins it explicitly.
    """
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    n = w.shape[1]
    sides2 = []
    for s in epilogue_sides:
        assert s.shape == (*lead, n) or s.shape == (m, n), (s.shape, (*lead, n))
        sides2.append(s.reshape(m, n))
    if block_m is None and block_n is None and block_k is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, w, bias, *sides2):
            runner = lambda bm, bn, bk, depth=1: _matmul_blocked(
                x2, w, bias, activation, bm, bn, bk, interpret, epilogue,
                sides2, pipeline if pipeline is not None else depth,
            )
        # an epilogue'd GEMM streams extra per-tile sides (different VMEM
        # pressure): never let its swept winner alias the plain GEMM's
        fmt = (
            f"{_format}+e{len(epilogue)}s{len(sides2)}" if epilogue else _format
        )
        block_m, block_n, block_k, depth = _blocks4(_TUNING.resolve(
            "matmul", m, n, k, x2.dtype, fmt, interpret, runner
        ))
        pipeline = depth if pipeline is None else pipeline
    elif block_m is None or block_n is None or block_k is None:
        # partially pinned: fill from defaults, never from the cache -- a
        # swept winner for the free dims was timed with different pins
        dm, dn, dk, _ = TuningCache.DEFAULTS["matmul"]
        block_m, block_n, block_k = block_m or dm, block_n or dn, block_k or dk
    out = _matmul_blocked(
        x2, w, bias, activation, block_m, block_n, block_k, interpret,
        epilogue, sides2, pipeline or 1,
    )
    return out.reshape(*lead, n)


def _qmatmul_blocked(
    x2, w_q, w_scale, bias, activation, block_m, block_n, block_k, interpret,
    epilogue=(), sides=(), pipeline=1,
):
    m, k = x2.shape
    n = w_q.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w_q, block_k, 0), block_n, 1)
    wsp = _pad_axis(w_scale, block_n, 0)
    bp = None if bias is None else _pad_axis(bias, block_n, 0)
    sp = [_pad_axis(_pad_axis(s, block_m, 0), block_n, 1) for s in sides]
    return _quant_matmul(
        xp,
        wp,
        wsp,
        bp,
        *sp,
        activation=activation,
        epilogue=tuple(epilogue),
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        pipeline=pipeline,
        interpret=interpret,
    )[:m, :n]


def qmatmul(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    x_scale: Optional[float] = None,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    pipeline: Optional[int] = None,
    interpret: Optional[bool] = None,
    _format: str = "dense",
) -> jax.Array:
    """Quantized ``epilogue(act((x @ w_q) * scales + bias))`` for arbitrary
    leading batch dims via the INT8 Pallas kernel.

    ``w_q [K, N]`` int8 with per-output-channel ``w_scale [N]`` f32.  With
    ``x_scale`` (the calibrated static activation scale, a Python float) the
    f32 activations are quantized to int8 here and the kernel contracts
    int8 x int8 into int32 (**W8A8**; the activation scale is folded into the
    per-column rescale).  Without it, activations stay f32 and only the
    weight stream is int8, dequantized per-tile in VMEM (**W8-only** -- the
    scheme the colcompact/channelcompact pruned formats use).

    Tuned under the ``qmatmul`` cache key family: the format string carries
    the storage format *and* the scheme (``dense+w8a8``, ``colcompact+w8``,
    ...) plus the usual ``+e{steps}s{sides}`` epilogue suffix -- int8 streams
    change VMEM residency and arithmetic width, so a winner never aliases the
    f32 ``matmul`` family.
    """
    from ..quant.qtensor import quantize_array  # local: quant layer is optional

    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    n = w_q.shape[1]
    sides2 = []
    for s in epilogue_sides:
        assert s.shape == (*lead, n) or s.shape == (m, n), (s.shape, (*lead, n))
        sides2.append(s.reshape(m, n))
    w_scale = w_scale.astype(jnp.float32)
    if x_scale is not None:
        # W8A8: statically-scaled int8 activations; kernel sees one combined
        # per-column rescale (x_scale * w_scale[n])
        x2 = quantize_array(x2, jnp.float32(x_scale))
        w_scale = w_scale * jnp.float32(x_scale)
    scheme = "w8" if x_scale is None else "w8a8"
    if block_m is None and block_n is None and block_k is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, w_q, w_scale, bias, *sides2):
            runner = lambda bm, bn, bk, depth=1: _qmatmul_blocked(
                x2, w_q, w_scale, bias, activation, bm, bn, bk, interpret,
                epilogue, sides2, pipeline if pipeline is not None else depth,
            )
        fmt = f"{_format}+{scheme}"
        if epilogue:
            fmt += f"+e{len(epilogue)}s{len(sides2)}"
        block_m, block_n, block_k, depth = _blocks4(_TUNING.resolve(
            "qmatmul", m, n, k, x2.dtype, fmt, interpret, runner
        ))
        pipeline = depth if pipeline is None else pipeline
    elif block_m is None or block_n is None or block_k is None:
        dm, dn, dk, _ = TuningCache.DEFAULTS["qmatmul"]
        block_m, block_n, block_k = block_m or dm, block_n or dn, block_k or dk
    out = _qmatmul_blocked(
        x2, w_q, w_scale, bias, activation, block_m, block_n, block_k,
        interpret, epilogue, sides2, pipeline or 1,
    )
    return out.reshape(*lead, n)


# --------------------------------------------------------------------------- #
# implicit-GEMM conv2d                                                          #
# --------------------------------------------------------------------------- #

#: per-grid-step VMEM working-set ceiling for the implicit-GEMM conv on real
#: hardware (the whole padded image is tile-resident); interpret mode has no
#: VMEM, so the guard only arms on TPUs
_CONV_VMEM_LIMIT = 12 * 2**20

#: conv2d lowering decisions live in the metrics registry, counted at trace
#: time under jit:
#:
#: * ``conv_fallback_total{reason}`` -- calls lowered through lax.conv
#:   instead of the Pallas kernel (the documented fallback matrix: groups /
#:   dilation / degenerate output / VMEM overflow).
#: * ``conv_fastpath_total{scheme}`` -- calls elected onto the 1x1
#:   direct-GEMM fast path (im2col bypassed, lowered to dense/quant
#:   matmul); an election is a lowering decision, not a fallback.
#:
#: The accessors below are back-compat *views* over those families.
_CONV_FALLBACK_METRIC = "conv_fallback_total"
_CONV_FASTPATH_METRIC = "conv_fastpath_total"


def conv_fallback_counts() -> Dict[str, int]:
    """The conv2d fallback counters (reason -> count) -- the "no lax.conv
    except documented fallbacks" acceptance probe.  A view over the
    ``conv_fallback_total`` registry family."""
    counts = _metrics.registry().label_counts(_CONV_FALLBACK_METRIC, "reason")
    return {k: int(v) for k, v in counts.items()}


def reset_conv_fallbacks() -> None:
    _metrics.registry().reset(_CONV_FALLBACK_METRIC)


def conv_fastpath_counts() -> Dict[str, int]:
    """The 1x1 direct-GEMM election counters (scheme -> count) -- a view
    over the ``conv_fastpath_total`` registry family."""
    counts = _metrics.registry().label_counts(_CONV_FASTPATH_METRIC, "scheme")
    return {k: int(v) for k, v in counts.items()}


def reset_conv_fastpaths() -> None:
    _metrics.registry().reset(_CONV_FASTPATH_METRIC)


def conv_gemm1x1_elected(kh: int, kw: int, groups: int, padding, c: int) -> bool:
    """True when a conv lowers through the 1x1 direct-GEMM fast path: unit
    taps, ungrouped, live input channels, and padding that adds no border
    (SAME == VALID for 1x1 taps; explicit pads must be all-zero).  Dilation
    is irrelevant for a unit tap, so it never blocks election.  Shared by
    :func:`conv2d` and :meth:`ExecutionPlan.memory_estimate` (an elected
    step owns no conv-kernel VMEM workspace)."""
    if kh != 1 or kw != 1 or groups != 1 or c <= 0:
        return False
    if isinstance(padding, str):
        return padding in ("SAME", "VALID")
    try:
        (a, b), (c2, d) = padding
        return int(a) == int(b) == int(c2) == int(d) == 0
    except (TypeError, ValueError):
        return False


def conv_fallback_reason(
    c: int,
    h: int,
    w: int,
    kh: int,
    kw: int,
    stride: int,
    padding,
    *,
    groups: int = 1,
    dilation: int = 1,
    interpret: bool,
    x_itemsize: int = 4,
    w_itemsize: int = 4,
    block_h: Optional[int] = None,
    block_o: Optional[int] = None,
    block_c: Optional[int] = None,
) -> Optional[str]:
    """The conv2d fallback matrix, shared by the :func:`conv2d` wrapper and
    :meth:`ExecutionPlan.memory_estimate` (a step that lowers through
    lax.conv has no Pallas VMEM workspace).  ``c`` is the *contracted*
    channel count.  The VMEM guard asks whether any resolvable configuration
    fits: pinned blocks are honored verbatim; otherwise it evaluates the
    default (block_h, block_o) at the most frugal K granularity available --
    the smallest non-zero ``block_c`` sweep candidate (tiled-K caps the
    resident slab, so wide-channel layers no longer trip the guard; sweep
    candidates that individually overflow fail to compile and are skipped
    by the sweep's try/except)."""
    if groups != 1:
        return "groups"
    if dilation != 1:
        return "dilation"
    if not isinstance(padding, str):
        try:
            (a, b), (c2, d) = padding
            if min(int(a), int(b), int(c2), int(d)) < 0:
                return "padding"  # lax allows negative (cropping) pads; we don't
        except (TypeError, ValueError):
            return "padding"
    try:
        oh, ow = conv_out_hw(h, w, kh, kw, stride, padding)
    except (TypeError, ValueError):
        return "padding"
    if oh < 1 or ow < 1:
        return "degenerate"
    if not interpret:
        dh, do_, _ = TuningCache.DEFAULTS["conv2d"]
        bh = block_h or dh
        bo = block_o or do_
        if block_c is not None:
            c_options = [block_c]
        else:
            # resident first (cheapest when it fits), then the smallest
            # tiled-K granularity the sweep could resolve
            tiled = [
                cand[2] for cand in TuningCache.CANDIDATES["conv2d"]
                if len(cand) > 2 and cand[2]
            ]
            c_options = [0] + ([min(tiled)] if tiled else [])
        fits = any(
            conv_vmem_workspace(
                c, h, w, kh, kw, stride, padding, bh, bo, bc,
                x_itemsize=x_itemsize, w_itemsize=w_itemsize,
            )["total"] <= _CONV_VMEM_LIMIT
            for bc in c_options
        )
        if not fits:
            return "vmem"
    return None


def _conv_default_blocks(
    c: int,
    h: int,
    w: int,
    kh: int,
    kw: int,
    stride: int,
    padding,
    x_itemsize: int,
    w_itemsize: int,
    interpret: bool,
) -> Tuple[int, int, int]:
    """Shape-aware conv default: the seeded (block_h, block_o) with the
    cheapest K granularity that fits VMEM -- resident when possible, else
    the largest fitting tiled-K candidate (fewer grid steps), else the
    smallest.  Interpret mode has no VMEM, so it always stays resident."""
    dh, do_, _ = TuningCache.DEFAULTS["conv2d"]
    if interpret:
        return (dh, do_, 0)
    tiled = sorted(
        {
            cand[2] for cand in TuningCache.CANDIDATES["conv2d"]
            if len(cand) > 2 and cand[2]
        },
        reverse=True,
    )
    for bc in (0, *tiled):
        total = conv_vmem_workspace(
            c, h, w, kh, kw, stride, padding, dh, do_, bc,
            x_itemsize=x_itemsize, w_itemsize=w_itemsize,
        )["total"]
        if total <= _CONV_VMEM_LIMIT:
            return (dh, do_, bc)
    return (dh, do_, min(tiled) if tiled else 0)  # guard rejects this case


def _conv2d_fallback(
    x, w, bias, *, stride, padding, kept, w_scale, x_scale, groups, dilation,
    activation, epilogue, sides,
):
    """lax.conv path for configs outside the kernel's matrix -- same math as
    the reference handlers (dequant / fake-quant / channel gather / jnp
    epilogue), so a fallback never changes results, only the engine."""
    if kept is not None:
        x = jnp.take(x, kept, axis=1)
    if w.dtype == jnp.int8:
        w = w.astype(jnp.float32) * w_scale.astype(jnp.float32)[:, None, None, None]
        if x_scale is not None:
            from ..quant.qtensor import fake_quant  # local: quant is optional

            x = fake_quant(x.astype(jnp.float32), jnp.float32(x_scale))
    y = _ref.conv2d_ref(
        x, w, bias, stride=stride, padding=padding, groups=groups,
        dilation=dilation, activation=activation, out_dtype=jnp.float32,
    )
    if epilogue:
        y = _ref.apply_steps_ref(y, epilogue, [s.astype(jnp.float32) for s in sides])
    return y.astype(x.dtype)


def _conv2d_1x1_gemm(
    x, w, bias, *, stride, kept, w_scale, x_scale, activation, epilogue,
    sides, interpret, fmt, is_q,
):
    """The 1x1 direct-GEMM fast path: a unit-tap conv with no border padding
    is ``y[n, :, i, j] = W @ x[n, :, i*s, j*s]`` -- a plain GEMM over the
    ``N*OH*OW`` pixel axis.  The NCHW tensor is reshaped NHWC -> [pixels, C]
    (strides subsample the grid first; SAME and VALID coincide for 1x1
    taps), the OIHW filter collapses to [C, O], and the conv's whole fused
    program -- bias, activation, epilogue steps with their side operands --
    rides the dense/quant matmul kernel unchanged.  Keys under the
    ``conv1x1.{fmt}`` matmul-family format, never aliasing a plain GEMM's
    winner (the pixel-axis M has different tuning pressure)."""
    if kept is not None:
        x = jnp.take(x, kept, axis=1)
    if stride > 1:
        x = x[:, :, ::stride, ::stride]
    nb, c, oh, ow = x.shape
    o = w.shape[0]
    assert w.shape[1] == c, (w.shape, c)
    for s in sides:
        assert s.shape == (nb, o, oh, ow), (s.shape, (nb, o, oh, ow))
    xm = x.transpose(0, 2, 3, 1).reshape(nb * oh * ow, c)
    wm = w.reshape(o, c).T  # OIHW unit taps -> [C, O]
    sm = [s.transpose(0, 2, 3, 1).reshape(nb * oh * ow, o) for s in sides]
    if is_q:
        y = qmatmul(
            xm, wm, w_scale, bias, x_scale=x_scale, activation=activation,
            epilogue=epilogue, epilogue_sides=sm, interpret=interpret,
            _format=f"conv1x1.{fmt}",
        )
    else:
        y = matmul(
            xm, wm, bias, activation=activation, epilogue=epilogue,
            epilogue_sides=sm, interpret=interpret, _format=f"conv1x1.{fmt}",
        )
    return y.reshape(nb, oh, ow, o).transpose(0, 3, 1, 2)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    kept: Optional[jax.Array] = None,
    w_scale: Optional[jax.Array] = None,
    x_scale: Optional[float] = None,
    groups: int = 1,
    dilation: int = 1,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_h: Optional[int] = None,
    block_o: Optional[int] = None,
    block_c: Optional[int] = None,
    gemm_1x1: bool = True,
    interpret: Optional[bool] = None,
    _format: Optional[str] = None,
) -> jax.Array:
    """``epilogue(act(conv2d(x, w) + bias))`` through the tiled Pallas
    implicit-GEMM kernel.  ``x [N, C, H, W]`` NCHW, ``w [O, C', kh, kw]``
    OIHW, SAME/VALID ``padding``, square ``stride``.

    Scheme selection (at lowering time, reflected in the tuning key):

    * f32 ``w`` -> **dense** f32 accumulation.
    * ``kept`` (surviving-input-channel indices from channel/column pruning)
      -> **channel-pruned**: ``x`` is gathered to the live channels first, so
      the implicit GEMM contracts only ``C' = len(kept)`` of K.
    * int8 ``w`` + ``w_scale[O]`` -> **INT8**: with ``x_scale`` (calibrated
      static activation scale) activations quantize to int8 and the MXU
      contracts int8 x int8 into int32 (**W8A8**); without it the weight
      tiles dequantize in VMEM against f32 activations (**W8-only**).

    ``epilogue`` is the usual step program (``("activation", fn)`` /
    ``("add"|"mul", slot)`` into ``epilogue_sides``, each shaped like the
    NCHW output), run on the f32 accumulator inside the kernel.

    **1x1 fast path** (:func:`conv_gemm1x1_elected`, counted per scheme in
    :func:`conv_fastpath_counts`): a unit-tap ungrouped conv with no border
    padding is exactly a GEMM over the pixel axis -- im2col is bypassed and
    the call lowers to :func:`matmul` / :func:`qmatmul` (NHWC reshape;
    strides become a spatial subsample) with the conv's full epilogue
    program, keyed under the ``conv1x1.{fmt}`` matmul-family format.
    Election happens at lowering time, before the fallback matrix; pinning
    any conv block size or ``gemm_1x1=False`` opts back into the im2col
    kernel.

    Fallback matrix (auto-routed through ``lax.conv``, bit-identical math,
    counted in :func:`conv_fallback_counts`): ``groups != 1``,
    ``dilation != 1``, malformed/negative explicit padding, degenerate
    output (``OH*OW < 1``), or -- on real hardware only -- a per-step VMEM
    working set above ~12 MB at every resolvable K granularity (tiled-K
    caps the resident slab at ``block_c`` channels, so only pathological
    spatial extents still trip this).

    Block sizes left as ``None`` resolve through the tuning cache under the
    ``conv2d|NxCxHxWxOxKHxKWxS|{dtype}|{fmt}+{scheme}[+valid|+p..][+e..s..]|{mode}``
    key family (``(block_h, block_o, block_c)``: output rows x output
    channels per tile, plus the tiled-K channel granularity -- 0 keeps the
    whole image resident, else ``block_k = block_c*kh*kw`` of the GEMM K
    streams per grid step; SAME -- the canonical geometry -- keys without a
    padding suffix).  The default is shape-aware: resident when the working
    set fits VMEM, else the largest fitting ``block_c`` candidate.
    """
    interpret = interpret_default() if interpret is None else interpret
    epilogue = tuple(tuple(s) for s in epilogue)
    sides = tuple(epilogue_sides)
    nb, c_in, h, w_in = x.shape
    o, cw, kh, kw_ = w.shape
    is_q = w.dtype == jnp.int8
    if is_q and w_scale is None:
        raise ValueError("int8 conv weights need w_scale")
    if x_scale is not None and not is_q:
        raise ValueError("x_scale (W8A8) requires int8 weights")
    scheme = "f32" if not is_q else ("w8a8" if x_scale is not None else "w8")
    fmt = _format or ("channelcompact" if kept is not None else "dense")
    c_live = int(kept.shape[0]) if kept is not None else c_in
    if (
        gemm_1x1
        and block_h is None and block_o is None and block_c is None
        and conv_gemm1x1_elected(kh, kw_, groups, padding, c_live)
    ):
        _metrics.registry().counter(_CONV_FASTPATH_METRIC, scheme=scheme).inc()
        return _conv2d_1x1_gemm(
            x, w, bias, stride=stride, kept=kept, w_scale=w_scale,
            x_scale=x_scale, activation=activation, epilogue=epilogue,
            sides=sides, interpret=interpret, fmt=fmt, is_q=is_q,
        )
    reason = conv_fallback_reason(
        c_live,
        h, w_in, kh, kw_, stride, padding,
        groups=groups, dilation=dilation, interpret=interpret,
        x_itemsize=1 if scheme == "w8a8" else x.dtype.itemsize,
        w_itemsize=w.dtype.itemsize, block_h=block_h, block_o=block_o,
        block_c=block_c,
    )
    if reason is not None:
        _metrics.registry().counter(_CONV_FALLBACK_METRIC, reason=reason).inc()
        return _conv2d_fallback(
            x, w, bias, stride=stride, padding=padding, kept=kept,
            w_scale=w_scale, x_scale=x_scale, groups=groups, dilation=dilation,
            activation=activation, epilogue=epilogue, sides=sides,
        )

    oh, ow = conv_out_hw(h, w_in, kh, kw_, stride, padding)
    for s in sides:
        assert s.shape == (nb, o, oh, ow), (s.shape, (nb, o, oh, ow))
    if kept is not None:
        x = jnp.take(x, kept, axis=1)
    c = x.shape[1]
    assert c == cw, (x.shape, w.shape)
    if c == 0:
        # every input channel pruned away: the output is pure epilogue math
        # over the bias (the empty contraction contributes zeros)
        y = jnp.zeros((nb, o, oh, ow), jnp.float32)
        if bias is not None:
            y = y + bias.astype(jnp.float32)[None, :, None, None]
        y = _ref._ACT[activation](y)
        if epilogue:
            y = _ref.apply_steps_ref(y, epilogue, [s.astype(jnp.float32) for s in sides])
        return y.astype(x.dtype)

    x2 = x
    out_dtype = x.dtype
    if scheme == "w8a8":
        from ..quant.qtensor import quantize_array  # local: quant is optional

        x2 = quantize_array(x2.astype(jnp.float32), jnp.float32(x_scale))
        out_dtype = jnp.float32
    ws_vec = None
    if is_q:
        ws_vec = w_scale.astype(jnp.float32)
        if scheme == "w8a8":
            ws_vec = ws_vec * jnp.float32(x_scale)
        out_dtype = jnp.float32
    pt, pl_ = conv_pad_hw(h, w_in, kh, kw_, stride, padding)

    def run(bh, bo, bc=0):
        ohp = -(-oh // bh) * bh
        hpad = (ohp - 1) * stride + kh
        wpad = (ow - 1) * stride + kw_
        # one HBM layout pass: NCHW -> NHWC + crop/zero-pad to the exact
        # span the taps touch (this is *padding*, never the im2col matrix --
        # patches materialize in VMEM only).  A VALID conv may leave an
        # unconsumed input tail, so crop before padding.
        h_used = min(h, hpad - pt)
        w_used = min(w_in, wpad - pl_)
        xt = jnp.pad(
            x2.transpose(0, 2, 3, 1)[:, :h_used, :w_used],
            ((0, 0), (pt, hpad - pt - h_used), (pl_, wpad - pl_ - w_used), (0, 0)),
        )
        wt = w.transpose(2, 3, 1, 0).reshape(kh * kw_, c, o)
        if bc:
            # tiled-K: zero-pad channels to a block_c multiple (zero slabs
            # contribute nothing to the accumulator, int8 included)
            xt = _pad_axis(xt, bc, 3)
            wt = _pad_axis(wt, bc, 1)
        wt = _pad_axis(wt, bo, 2)
        op_ = wt.shape[2]
        wsp = None if ws_vec is None else _pad_axis(ws_vec, bo, 0)
        bp = None if bias is None else _pad_axis(bias, bo, 0)
        sp = []
        for s in sides:
            st = jnp.pad(
                s.transpose(0, 2, 3, 1),
                ((0, 0), (0, ohp - oh), (0, 0), (0, op_ - o)),
            )
            sp.append(st.reshape(nb * ohp * ow, op_))
        out2 = _conv2d_gemm(
            xt, wt, wsp, bp, *sp,
            stride=stride, kh=kh, kw=kw_,
            activation=activation, epilogue=epilogue,
            block_h=bh, block_o=bo, block_c=bc,
            interpret=interpret, out_dtype=out_dtype,
        )
        return (
            out2.reshape(nb, ohp, ow, op_)[:, :oh, :, :o].transpose(0, 3, 1, 2)
        )

    if block_h is None and block_o is None and block_c is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, w, bias, w_scale, *sides):
            runner = run
        # SAME (canonical) keys bare; VALID / explicit pads suffix the fmt --
        # same dims, different output geometry must never share a winner
        fmtkey = f"{fmt}+{scheme}" + conv_padding_token(padding)
        if epilogue:
            fmtkey += f"+e{len(epilogue)}s{len(sides)}"
        x_item = 1 if scheme == "w8a8" else x.dtype.itemsize
        block_h, block_o, block_c = _conv_blocks3(_TUNING.resolve_nd(
            "conv2d", (nb, c, h, w_in, o, kh, kw_, stride), x2.dtype, fmtkey,
            interpret, runner,
            default=_conv_default_blocks(
                c, h, w_in, kh, kw_, stride, padding, x_item,
                w.dtype.itemsize, interpret,
            ),
        ))
    elif block_h is None or block_o is None or block_c is None:
        dh, do_, dc = TuningCache.DEFAULTS["conv2d"]
        block_h, block_o = block_h or dh, block_o or do_
        block_c = dc if block_c is None else block_c
    return run(block_h, block_o, block_c)


def fused_elementwise(
    x: jax.Array,
    sides: Sequence[jax.Array] = (),
    steps: Sequence[Tuple] = (),
    norm_params: Sequence[Tuple[jax.Array, jax.Array]] = (),
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Run a fused elementwise step program over ``x`` in one Pallas pass.

    ``x`` has any leading batch dims; steps operate on the flattened
    ``[M, D]`` view (D = last dim, the layer-norm axis).  ``sides`` must
    match ``x``'s shape exactly (the tiled kernel streams them per-block);
    ``norm_params`` is one (scale[D], bias[D]) pair per ``("norm", slot,
    eps)`` step.  One HBM read + write total instead of one per step.

    ``block_m=None`` consults the tuning cache under the
    ``fused_elementwise`` op key (M x D x n_steps).
    """
    interpret = interpret_default() if interpret is None else interpret
    d = x.shape[-1]
    for s in sides:
        assert s.shape == x.shape, (s.shape, x.shape)
    x2, lead = _flatten_batch(x)
    m = x2.shape[0]
    steps = tuple(tuple(s) for s in steps)

    def run(bm):
        xp = _pad_axis(_pad_axis(x2, bm, 0), 128, 1)
        sp = [_pad_axis(_pad_axis(s.reshape(m, d), bm, 0), 128, 1) for s in sides]
        nps = []
        for scale, bias in norm_params:
            nps.append(_pad_axis(scale, 128, 0).reshape(1, -1))
            nps.append(_pad_axis(bias, 128, 0).reshape(1, -1))
        return _fused_elementwise(
            xp,
            *sp,
            *nps,
            steps=steps,
            n_norms=len(norm_params),
            d_true=d,
            block_m=bm,
            interpret=interpret,
        )[:m, :d]

    if block_m is None:
        runner = None
        flat_norms = [a for pair in norm_params for a in pair]
        if _TUNING.enabled and _concrete(x2, *sides, *flat_norms):
            runner = lambda bm: run(bm)
        # side/norm counts change per-tile VMEM residency: same-shape
        # programs with different operand counts must not share a winner
        fmt = f"ew+s{len(sides)}n{len(norm_params)}"
        # interpret mode pays ~1 ms of Python per grid step, which swamps
        # this memory-bound kernel at the 128-row default (the 0.13x/0.50x
        # regression profiled in BENCH_fusion.json): seed a single full-M
        # tile there -- one grid step -- and keep the VMEM-sized 128-row
        # default for real hardware
        default = ((-(-m // 8) * 8,) if interpret else None)
        (block_m,) = _TUNING.resolve(
            "fused_elementwise", m, d, len(steps), x2.dtype, fmt, interpret,
            runner, default=default,
        )
    return run(block_m).reshape(x.shape)


def bsr_matmul(
    x: jax.Array,
    values: jax.Array,
    block_rows: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    block_m: Optional[int] = None,
    bands: Optional[Sequence[Tuple[int, int, int]]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Block-sparse ``epilogue(act(x @ W + bias))`` over PBCSR-packed weights.

    ``bands`` (from the reorder pass): sequence of ``(start, stop, count)``
    over output block-columns; one pallas_call per band with exact trip count
    ``count``.  Without bands, a single call pads every column to the global
    max count.  ``epilogue`` is the same step program as :func:`matmul`,
    executed on the f32 accumulator inside each band's kernel (sides are
    sliced per band and streamed per output tile).  ``block_m=None``
    consults the tuning cache -- an epilogue'd call keys separately
    (``pbcsr+e{steps}s{sides}``) since the extra side streams change VMEM
    residency.
    """
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    nb, s, bm, bn = values.shape
    n = nb * bn
    epilogue = tuple(tuple(st) for st in epilogue)
    sides2 = []
    for sv in epilogue_sides:
        assert sv.shape == (*lead, n) or sv.shape == (m, n), (sv.shape, (*lead, n))
        sides2.append(sv.reshape(m, n))

    def compute(block_m):
        xp = _pad_axis(x2, block_m, 0)
        sp = [_pad_axis(sv, block_m, 0) for sv in sides2]

        def run(vals, rows, bias_slice, side_slices):
            return _bsr_matmul(
                xp,
                vals,
                rows,
                bias_slice,
                *side_slices,
                activation=activation,
                epilogue=epilogue,
                block_m=block_m,
                interpret=interpret,
            )

        if not bands:
            return run(values, block_rows, bias, sp)
        pieces = []
        for start, stop, count in bands:
            if stop <= start:
                continue
            cols = slice(start, stop)
            side_slices = [sv[:, start * bn : stop * bn] for sv in sp]
            if count == 0:
                # empty band: output is pure epilogue (bias/activation of 0)
                z = jnp.zeros((xp.shape[0], (stop - start) * bn), jnp.float32)
                if bias is not None:
                    z = z + bias[start * bn : stop * bn].astype(jnp.float32)
                z = _ref._ACT[activation](z)
                if epilogue:
                    z = _ref.apply_steps_ref(
                        z, epilogue, [sl.astype(jnp.float32) for sl in side_slices]
                    )
                pieces.append(z.astype(x.dtype))
                continue
            pieces.append(
                run(
                    values[cols, :count],
                    block_rows[cols, :count],
                    None if bias is None else bias[start * bn : stop * bn],
                    side_slices,
                )
            )
        return jnp.concatenate(pieces, axis=-1)

    if block_m is None:
        runner = None
        if _TUNING.enabled and _concrete(x2, values, block_rows, bias, *sides2):
            runner = compute
        fmt = "pbcsr"
        if epilogue:
            fmt += f"+e{len(epilogue)}s{len(sides2)}"
        (block_m,) = _TUNING.resolve(
            "bsr_matmul", m, n, k, x2.dtype, fmt, interpret, runner
        )
    out = compute(block_m)
    return out[:m].reshape(*lead, n)


def col_matmul(
    x: jax.Array,
    values: jax.Array,
    kept: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    epilogue: Sequence[Tuple] = (),
    epilogue_sides: Sequence[jax.Array] = (),
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Column-pruned ``act(x @ W + bias)``: static input gather (XLA) + the
    strictly smaller fused dense GEMM (Pallas), with the same fused
    ``epilogue`` program as :func:`matmul`.  ``values [K_kept, N]``.
    Tuned under its own ``colcompact`` cache key (the gathered K differs
    from the dense layer's)."""
    xg = jnp.take(x, kept, axis=-1)
    return matmul(
        xg, values, bias, activation=activation,
        epilogue=epilogue, epilogue_sides=epilogue_sides, interpret=interpret,
        _format="colcompact",
    )


def ffn_gateup(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    *,
    activation: str = "silu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused ``act(x@Wg) * (x@Wu)`` with padding handling."""
    interpret = interpret_default() if interpret is None else interpret
    x2, lead = _flatten_batch(x)
    m, k = x2.shape
    f = w_gate.shape[1]
    xp = _pad_axis(_pad_axis(x2, block_m, 0), block_k, 1)
    wgp = _pad_axis(_pad_axis(w_gate, block_k, 0), block_n, 1)
    wup = _pad_axis(_pad_axis(w_up, block_k, 0), block_n, 1)
    out = _ffn_gateup(
        xp,
        wgp,
        wup,
        activation=activation,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :f]
    return out.reshape(*lead, f)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    kv_lengths: Optional[jax.Array] = None,
    *, causal: bool = True,
    scale=None, block_q: int = 128, block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention over [B, H, S, d] (pads S to block multiples).

    ``kv_lengths [B]`` masks each row to its valid KV prefix (slots >= length
    never attract probability mass) -- the paged-KV path, where Skv is the
    gathered page span, not the live length.
    """
    interpret = interpret_default() if interpret is None else interpret
    sq, skv = q.shape[2], k.shape[2]
    qp = _pad_axis(q, block_q, 2)
    kp = _pad_axis(k, block_k, 2)
    vp = _pad_axis(v, block_k, 2)
    # padded KV columns must not attract probability mass: causal masking
    # handles the tail whenever sq == skv; kv_lengths masks explicitly; for
    # the remaining cross/kv-padded cases require block-aligned shapes.
    assert causal or kv_lengths is not None or (
        sq % block_q == 0 and skv % block_k == 0
    ), "non-causal attention requires block-aligned shapes or kv_lengths"
    out = _flash_attention(
        qp, kp, vp, kv_lengths, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :, :sq]
