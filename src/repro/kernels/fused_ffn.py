"""Fused gated-FFN (SwiGLU/GeGLU) first half: ``act(x@Wg) * (x@Wu)``.

The DSL-fusion pass (paper section 3) merges elementwise ops into their GEMM
producer; for gated FFNs two GEMMs share the same x tile, so one kernel pass
streams x once, keeps *two* VMEM accumulators, and applies the gate without
materializing either projection in HBM -- halving x traffic and removing two
HBM round-trips for the [M, F] intermediates.

Grid ``(M/bm, F/bn, K/bk)``; Wg/Wu blocks ride the same (k, j) schedule.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

from .dense_matmul import _ACTIVATIONS

__all__ = ["ffn_gateup_kernel", "ffn_gateup"]


def ffn_gateup_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *, activation):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...]
    accg_ref[...] += jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        gated = _ACTIVATIONS[activation](accg_ref[...]) * accu_ref[...]
        o_ref[...] = gated.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k", "interpret"),
)
def ffn_gateup(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    *,
    activation: str = "silu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """``act(x @ w_gate) * (x @ w_up)`` with fused gating.  2-D, block-divisible."""
    m, k = x.shape
    kg, f = w_gate.shape
    assert w_up.shape == (kg, f) and kg == k
    assert m % block_m == 0 and f % block_n == 0 and k % block_k == 0
    grid = (m // block_m, f // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(ffn_gateup_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, f), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, block_n), jnp.float32),
        ],
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w_gate, w_up)
