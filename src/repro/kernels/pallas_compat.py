"""Version/backend shims for Pallas TPU kernels.

* jax >= 0.5 exposes ``pltpu.CompilerParams``; 0.4.x (this container ships
  0.4.37) calls the same dataclass ``TPUCompilerParams``.  Kernels import
  :func:`tpu_compiler_params` so they compile against either.
* :func:`interpret_default` is the CPU-CI guard shared by every kernel
  wrapper: Pallas interpret mode is forced on whenever we are not on real
  TPU hardware (overridable via ``REPRO_PALLAS_INTERPRET``), so the fused
  kernels stay exercisable -- and parity-testable -- in CPU-only containers.
"""

from __future__ import annotations

import os

import jax
from jax.experimental.pallas import tpu as pltpu

_CP = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(**kw):
    return _CP(**kw)


def interpret_default() -> bool:
    """Pallas interpret mode: forced via REPRO_PALLAS_INTERPRET, else on
    whenever we are not running on real TPU hardware."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"
