"""Version shim for Pallas TPU compiler params.

jax >= 0.5 exposes ``pltpu.CompilerParams``; 0.4.x (this container ships
0.4.37) calls the same dataclass ``TPUCompilerParams``.  Kernels import the
helper so they compile against either.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CP = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(**kw):
    return _CP(**kw)
