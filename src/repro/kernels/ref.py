"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<kernel>_ref`` takes the *same logical arguments* as its kernel wrapper
and computes the answer with plain jnp ops at f32 accumulation.  Tests sweep
shapes/dtypes and ``assert_allclose(kernel, ref)``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "matmul_ref",
    "bsr_matmul_ref",
    "qmatmul_ref",
    "conv2d_ref",
    "qconv2d_ref",
    "ffn_gateup_ref",
    "pbcsr_to_dense_ref",
    "flash_attention_ref",
    "fused_elementwise_ref",
    "apply_steps_ref",
    "rope_ref",
]

_ACT = {
    None: lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def apply_steps_ref(y, steps, sides=(), norm_params=()):
    """Run a kernel-local step program with plain jnp: the fused-kernel
    oracle *and* the single source of truth for step math (the executor's
    epilogue/fused-node jnp paths delegate here).  ``("add"|"mul", slot)``
    indexes ``sides``; ``("norm", slot, eps)`` (layer norm over the last
    dim) and ``("norm_instance", slot, eps)`` (per-(N, C) over NCHW spatial
    dims) index ``norm_params`` -- a sequence of (scale, bias) pairs.
    ``("norm_rms", slot, eps)`` is the decoder RMSNorm (scale-only, f32
    compute cast back before the scale -- exactly ``layers.rmsnorm``);
    ``("rope", slot, heads, theta)`` rotates a flattened [..., S, H*dh]
    tensor by the position ids in ``sides[slot]``."""
    for step in steps:
        kind = step[0]
        if kind == "activation":
            y = _ACT[step[1]](y)
        elif kind == "add":
            y = y + sides[step[1]]
        elif kind == "mul":
            y = y * sides[step[1]]
        elif kind == "norm_rms":
            scale, _ = norm_params[step[1]]
            yf = y.astype(jnp.float32)
            var = jnp.mean(yf * yf, axis=-1, keepdims=True)
            y = (yf * jax.lax.rsqrt(var + step[2])).astype(y.dtype) * scale
        elif kind == "rope":
            y = rope_ref(y, sides[step[1]], step[2], step[3])
        elif kind in ("norm", "norm_instance"):
            scale, bias = norm_params[step[1]]
            if kind == "norm":
                mu = y.mean(axis=-1, keepdims=True)
                var = y.var(axis=-1, keepdims=True)
            else:
                mu = y.mean(axis=(2, 3), keepdims=True)
                var = y.var(axis=(2, 3), keepdims=True)
                scale = scale[None, :, None, None]
                bias = bias[None, :, None, None]
            y = (y - mu) / jnp.sqrt(var + step[2]) * scale + bias
        else:
            raise NotImplementedError(f"step {kind}")
    return y


def fused_elementwise_ref(x, sides, steps, norm_params=(), *, out_dtype=None):
    """f32 oracle for the fused elementwise Pallas kernel."""
    y = apply_steps_ref(
        x.astype(jnp.float32),
        steps,
        [s.astype(jnp.float32) for s in sides],
        [(s.astype(jnp.float32), b.astype(jnp.float32)) for s, b in norm_params],
    )
    return y.astype(out_dtype or x.dtype)


def matmul_ref(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    acc = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    return _ACT[activation](acc).astype(out_dtype or x.dtype)


def qmatmul_ref(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    x_scale: Optional[float] = None,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """f32 oracle for the quantized matmul kernel (both schemes).

    ``x`` is always the *float* activation; ``x_scale`` (the calibrated
    static activation scale) selects W8A8 -- the activation is fake-quantized
    with the same round/clip the kernel applies, so
    ``(q_x * sx) @ (q_w * sw)`` reproduces the kernel's
    ``(q_x @ q_w) * sx * sw`` integer math up to f32 summation order.
    Without ``x_scale`` this is the W8-only path: full-precision activations
    against the dequantized int8 weight.
    """
    from ..quant.qtensor import fake_quant  # no cycle: quant is jnp-only

    w = w_q.astype(jnp.float32) * w_scale.astype(jnp.float32)[None, :]
    xf = x.astype(jnp.float32)
    if x_scale is not None:
        xf = fake_quant(xf, jnp.float32(x_scale))
    return matmul_ref(
        xf, w, bias, activation=activation, out_dtype=out_dtype or jnp.float32
    )


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    groups: int = 1,
    dilation: int = 1,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """f32 oracle for the implicit-GEMM conv kernel: ``x [N, C, H, W]``
    NCHW, ``w [O, C/groups, kh, kw]`` OIHW, XLA conv semantics."""
    from jax import lax

    dn = lax.conv_dimension_numbers(x.shape, w.shape, ("NCHW", "OIHW", "NCHW"))
    y = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=dn,
        feature_group_count=groups,
    )
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :, None, None]
    return _ACT[activation](y).astype(out_dtype or x.dtype)


def qconv2d_ref(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    x_scale: Optional[float] = None,
    stride: int = 1,
    padding: str = "SAME",
    groups: int = 1,
    dilation: int = 1,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """f32 oracle for the quantized conv kernel (both schemes), mirroring
    :func:`qmatmul_ref`: ``w_q [O, C, kh, kw]`` int8 with per-output-channel
    ``w_scale [O]``; ``x_scale`` selects W8A8 (activations fake-quantized
    with the kernel's round/clip), else W8-only (f32 activations against the
    dequantized weight)."""
    from ..quant.qtensor import fake_quant  # no cycle: quant is jnp-only

    w = w_q.astype(jnp.float32) * w_scale.astype(jnp.float32)[:, None, None, None]
    xf = x.astype(jnp.float32)
    if x_scale is not None:
        xf = fake_quant(xf, jnp.float32(x_scale))
    return conv2d_ref(
        xf, w, bias, stride=stride, padding=padding, groups=groups,
        dilation=dilation, activation=activation,
        out_dtype=out_dtype or jnp.float32,
    )


def pbcsr_to_dense_ref(
    values: jax.Array, block_rows: jax.Array, k: int
) -> jax.Array:
    """Rebuild the dense [K, N] weight from packed blocks (jnp, jit-safe)."""
    nb, s, bm, bn = values.shape
    kb = k // bm
    dense_blocks = jnp.zeros((kb, nb, bm, bn), values.dtype)
    rows = jnp.maximum(block_rows, 0)
    valid = (block_rows >= 0)[..., None, None]
    # scatter-add each packed slot into its block-row (pads add zeros at row 0)
    for si in range(s):  # s is small and static
        dense_blocks = dense_blocks.at[rows[:, si], jnp.arange(nb)].add(
            jnp.where(valid[:, si], values[:, si], 0)
        )
    return dense_blocks.transpose(0, 2, 1, 3).reshape(kb * bm, nb * bn)


def bsr_matmul_ref(
    x: jax.Array,
    values: jax.Array,
    block_rows: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    w = pbcsr_to_dense_ref(values, block_rows, x.shape[-1])
    return matmul_ref(x, w, bias, activation=activation, out_dtype=out_dtype or x.dtype)


def ffn_gateup_ref(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *, activation: str = "silu"
) -> jax.Array:
    xf = x.astype(jnp.float32)
    g = _ACT[activation](xf @ w_gate.astype(jnp.float32))
    u = xf @ w_up.astype(jnp.float32)
    return (g * u).astype(x.dtype)


def rope_ref(
    x: jax.Array, positions: jax.Array, heads: int, theta: float = 10000.0
) -> jax.Array:
    """Split-half RoPE oracle over a flattened head axis.

    ``x``: [..., S, heads*dh]; ``positions``: [..., S] int32.  Matches
    ``models.layers.apply_rope`` (f32 compute, cast back) without importing
    the model stack into the kernel layer.
    """
    *lead, s, hd = x.shape
    dh = hd // heads
    xh = x.reshape(*lead, s, heads, dh).astype(jnp.float32)
    freqs = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(xh, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype).reshape(*lead, s, hd)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    kv_lengths: Optional[jax.Array] = None,  # [B] int32 valid KV prefix
    *, causal: bool = True,
    scale=None,
) -> jax.Array:
    """Naive softmax attention oracle.  q/k/v: [B, H, S, d]."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    sq, skv = s.shape[-2:]
    if causal:
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    if kv_lengths is not None:
        valid = jnp.arange(skv)[None, :] < kv_lengths[:, None]  # [B, Skv]
        s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
