from . import ref
from .ops import (
    attention,
    bsr_matmul,
    col_matmul,
    conv2d,
    ffn_gateup,
    fused_elementwise,
    interpret_default,
    matmul,
    qmatmul,
)
