from . import ref
from .ops import (
    attention,
    bsr_matmul,
    col_matmul,
    ffn_gateup,
    fused_elementwise,
    interpret_default,
    matmul,
    qmatmul,
)
