"""Flash attention (forward) as a Pallas TPU kernel.

The models' ``attention.sdpa(impl='chunked')`` is the jnp expression of this
algorithm (used for sharded lowering); this kernel is the TPU hot path: one
pass over KV blocks with the online-softmax (m, l, acc) recurrence held in
VMEM scratch -- no [Sq, Skv] score matrix ever touches HBM.

Grid: ``(B*H, Sq/bq, Skv/bk)`` with the KV axis innermost ("arbitrary") so
scratch carries across it.  Causal masking happens in-kernel from block
coordinates; fully-masked KV blocks still execute (Pallas grids are dense) --
the standard cost of the simple schedule, ~2x over the triangle.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG_INF = -1e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, causal, bq, bk, len_ref=None,
):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_idx = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)  # [bk, d]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]
    cols = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        rows = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        s = jnp.where(cols <= rows, s, NEG_INF)
    if len_ref is not None:
        # valid-prefix mask: only KV slots < length attend (paged decode where
        # Skv is padded out to a page multiple past the live cache entries)
        s = jnp.where(cols < len_ref[0, 0], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _flash_attention_kernel_len(
    q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, causal, bq, bk,
):
    flash_attention_kernel(
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
        scale=scale, causal=causal, bq=bq, bk=bk, len_ref=len_ref,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret", "scale")
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, d]
    k: jax.Array,  # [B, H, Skv, d]
    v: jax.Array,  # [B, H, Skv, d]
    kv_lengths: Optional[jax.Array] = None,  # [B] int32 valid KV prefix per row
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    skv = k.shape[2]
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    scale = float(scale) if scale is not None else 1.0 / (d ** 0.5)
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, skv, d)
    vf = v.reshape(bh, skv, d)
    grid = (bh, sq // block_q, skv // block_k)
    q_spec = pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0))
    scratch = [
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, d), jnp.float32),
    ]
    params = _tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
    if kv_lengths is None:
        out = pl.pallas_call(
            functools.partial(
                flash_attention_kernel,
                scale=scale, causal=causal, bq=block_q, bk=block_k,
            ),
            grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=q_spec,
            out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            scratch_shapes=scratch,
            compiler_params=params,
            interpret=interpret,
        )(qf, kf, vf)
    else:
        # lengths ride as a [bh, 1] int32 scalar block in SMEM (2D: TPU
        # scalars must be at least rank 2 -- see pallas guide)
        lens = jnp.repeat(
            jnp.asarray(kv_lengths, jnp.int32).reshape(b), h
        ).reshape(bh, 1)
        out = pl.pallas_call(
            functools.partial(
                _flash_attention_kernel_len,
                scale=scale, causal=causal, bq=block_q, bk=block_k,
            ),
            grid=grid,
            in_specs=[
                q_spec, kv_spec, kv_spec,
                pl.BlockSpec((1, 1), lambda g, i, j: (g, 0),
                             memory_space=pltpu.SMEM),
            ],
            out_specs=q_spec,
            out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            scratch_shapes=scratch,
            compiler_params=params,
            interpret=interpret,
        )(qf, kf, vf, lens)
    return out.reshape(b, h, sq, d)
