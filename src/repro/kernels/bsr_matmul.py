"""Block-sparse matmul over PBCSR weights (the paper's sparse execution engine,
TPU-native form -- DESIGN.md section 2).

``y[M, N] = x[M, K] @ W`` where W survives structured block pruning.  Weights
arrive *packed*: only surviving ``(bm, bn)`` blocks are stored
(``values[Nb, S, bm, bn]``), with one scalar-prefetched int32 block-row index
per block (``block_rows[Nb, S]``, -1 = padding).  Properties:

* pruned blocks are never read from HBM and never touch the MXU -- compute
  and memory scale with density, not with the dense shape;
* the index table lives in SMEM via ``PrefetchScalarGridSpec`` (scalar
  prefetch), so the x-tile address for step ``s`` is known before the DMA --
  no data-dependent stalls on the datapath (the paper's "irregular memory
  access" fix);
* the grid is output-stationary ``(M/bmx, Nb, S)`` with equal trip count S
  everywhere -- the load-balance contract established by the balanced
  projection or by the matrix-reorder bands (one call per band, exact S);
* padding blocks (index -1) clamp to x-block 0 and add zeros: exact, merely
  wasted work, which the reorder pass minimizes.

The bias+activation epilogue is fused exactly as in dense_matmul.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

from .dense_matmul import _ACTIVATIONS

__all__ = ["bsr_matmul_kernel", "bsr_matmul"]


def bsr_matmul_kernel(
    rows_ref,  # scalar-prefetch: [Nb, S] int32 block-row per step
    x_ref,  # [bmx, bm] tile of x (block-row selected via rows_ref)
    v_ref,  # [1, 1, bm, bn] packed weight block
    b_ref,  # [1, bn] bias tile or None
    o_ref,  # [bmx, bn] output tile
    acc_ref,  # VMEM f32 accumulator
    *,
    activation: Optional[str],
):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    is_pad = rows_ref[j, s] < 0
    blk = jnp.dot(
        x_ref[...], v_ref[0, 0], preferred_element_type=jnp.float32
    )
    # padded steps contribute zero even if values were garbage (they are zero
    # by construction; the select also guards clamped x reads).
    acc_ref[...] += jnp.where(is_pad, 0.0, 1.0) * blk

    @pl.when(s == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[...] = _ACTIVATIONS[activation](acc).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "interpret", "out_dtype", "n_out"),
)
def bsr_matmul(
    x: jax.Array,
    values: jax.Array,
    block_rows: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    n_out: Optional[int] = None,
    activation: Optional[str] = None,
    block_m: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Block-sparse ``act(x @ W + bias)``.

    Args:
      x: ``[M, K]`` with M % block_m == 0, K % bm == 0.
      values: ``[Nb, S, bm, bn]`` packed surviving blocks (zeros at pads).
      block_rows: ``[Nb, S]`` int32 block-row index per packed block, -1 pad.
      bias: optional ``[Nb*bn]``.
      n_out: output width override (defaults to Nb*bn).
    """
    m, k = x.shape
    nb, s_steps, bm, bn = values.shape
    assert k % bm == 0, (k, bm)
    assert m % block_m == 0, (m, block_m)
    n = n_out or nb * bn
    assert n == nb * bn
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    out_dtype = out_dtype or x.dtype

    grid = (m // block_m, nb, s_steps)

    def x_index(i, j, s, rows):
        # pads (-1) clamp to x-block 0; their contribution is masked in-kernel
        return (i, jnp.maximum(rows[j, s], 0))

    in_specs = [
        pl.BlockSpec((block_m, bm), x_index),
        pl.BlockSpec((1, 1, bm, bn), lambda i, j, s, rows: (j, s, 0, 0)),
    ]
    args = [x, values]
    if bias is not None:
        assert bias.shape == (n,), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s, rows: (0, j)))
        args.append(bias.reshape(1, n))
        kern = functools.partial(bsr_matmul_kernel, activation=activation)
    else:
        def kern(rows_ref, x_ref, v_ref, o_ref, acc_ref):
            return bsr_matmul_kernel(
                rows_ref, x_ref, v_ref, None, o_ref, acc_ref, activation=activation
            )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, bn), lambda i, j, s, rows: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(block_rows, *args)
