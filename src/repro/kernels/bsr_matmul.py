"""Block-sparse matmul over PBCSR weights (the paper's sparse execution engine,
TPU-native form -- DESIGN.md section 2).

``y[M, N] = x[M, K] @ W`` where W survives structured block pruning.  Weights
arrive *packed*: only surviving ``(bm, bn)`` blocks are stored
(``values[Nb, S, bm, bn]``), with one scalar-prefetched int32 block-row index
per block (``block_rows[Nb, S]``, -1 = padding).  Properties:

* pruned blocks are never read from HBM and never touch the MXU -- compute
  and memory scale with density, not with the dense shape;
* the index table lives in SMEM via ``PrefetchScalarGridSpec`` (scalar
  prefetch), so the x-tile address for step ``s`` is known before the DMA --
  no data-dependent stalls on the datapath (the paper's "irregular memory
  access" fix);
* the grid is output-stationary ``(M/bmx, Nb, S)`` with equal trip count S
  everywhere -- the load-balance contract established by the balanced
  projection or by the matrix-reorder bands (one call per band, exact S);
* padding blocks (index -1) clamp to x-block 0 and add zeros: exact, merely
  wasted work, which the reorder pass minimizes.

The bias+activation epilogue is fused exactly as in dense_matmul.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params as _tpu_compiler_params

from .dense_matmul import _ACTIVATIONS, apply_epilogue_steps, validate_epilogue

__all__ = ["bsr_matmul_kernel", "bsr_matmul"]


def bsr_matmul_kernel(
    rows_ref,  # scalar-prefetch: [Nb, S] int32 block-row per step
    x_ref,  # [bmx, bm] tile of x (block-row selected via rows_ref)
    v_ref,  # [1, 1, bm, bn] packed weight block
    b_ref,  # [1, bn] bias tile or None
    side_refs,  # per-tile epilogue side operands, each [bmx, bn]
    o_ref,  # [bmx, bn] output tile
    acc_ref,  # VMEM f32 accumulator
    *,
    activation: Optional[str],
    epilogue: Tuple[Tuple, ...] = (),
):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)
    is_pad = rows_ref[j, s] < 0
    blk = jnp.dot(
        x_ref[...], v_ref[0, 0], preferred_element_type=jnp.float32
    )
    # padded steps contribute zero even if values were garbage (they are zero
    # by construction; the select also guards clamped x reads).
    acc_ref[...] += jnp.where(is_pad, 0.0, 1.0) * blk

    @pl.when(s == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        acc = _ACTIVATIONS[activation](acc)
        # epilogue step program on the f32 accumulator (same vocabulary as
        # dense_matmul): sides stream per output tile, one per band slice
        acc = apply_epilogue_steps(acc, epilogue, side_refs)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "epilogue", "block_m", "interpret", "out_dtype", "n_out",
    ),
)
def bsr_matmul(
    x: jax.Array,
    values: jax.Array,
    block_rows: jax.Array,
    bias: Optional[jax.Array] = None,
    *sides: jax.Array,
    n_out: Optional[int] = None,
    activation: Optional[str] = None,
    epilogue: Tuple[Tuple, ...] = (),
    block_m: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Block-sparse ``epilogue(act(x @ W + bias))``.

    Args:
      x: ``[M, K]`` with M % block_m == 0, K % bm == 0.
      values: ``[Nb, S, bm, bn]`` packed surviving blocks (zeros at pads).
      block_rows: ``[Nb, S]`` int32 block-row index per packed block, -1 pad.
      bias: optional ``[Nb*bn]``.
      sides: ``[M, Nb*bn]`` epilogue side operands streamed per output tile.
      epilogue: step program (dense_matmul vocabulary) run on the f32
        accumulator at the last packed step -- the in-tile half of the
        ``fuse_epilogue`` pass for the PBCSR format.
      n_out: output width override (defaults to Nb*bn).
    """
    m, k = x.shape
    nb, s_steps, bm, bn = values.shape
    assert k % bm == 0, (k, bm)
    assert m % block_m == 0, (m, block_m)
    n = n_out or nb * bn
    assert n == nb * bn
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    validate_epilogue(epilogue, len(sides))
    for sv in sides:
        assert sv.shape == (m, n), (sv.shape, (m, n))
    out_dtype = out_dtype or x.dtype

    grid = (m // block_m, nb, s_steps)

    def x_index(i, j, s, rows):
        # pads (-1) clamp to x-block 0; their contribution is masked in-kernel
        return (i, jnp.maximum(rows[j, s], 0))

    out_tile = pl.BlockSpec((block_m, bn), lambda i, j, s, rows: (i, j))
    in_specs = [
        pl.BlockSpec((block_m, bm), x_index),
        pl.BlockSpec((1, 1, bm, bn), lambda i, j, s, rows: (j, s, 0, 0)),
    ]
    args = [x, values]
    has_bias = bias is not None
    if has_bias:
        assert bias.shape == (n,), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s, rows: (0, j)))
        args.append(bias.reshape(1, n))
    in_specs.extend([out_tile] * len(sides))
    args.extend(sides)
    n_sides = len(sides)

    def kern(*refs):
        # refs: rows, x, v, [bias], *sides, o, acc
        b_ref = refs[3] if has_bias else None
        first_side = 3 + int(has_bias)
        bsr_matmul_kernel(
            refs[0],
            refs[1],
            refs[2],
            b_ref,
            refs[first_side : first_side + n_sides],
            refs[-2],
            refs[-1],
            activation=activation,
            epilogue=epilogue,
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_tile,
        scratch_shapes=[pltpu.VMEM((block_m, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(block_rows, *args)
