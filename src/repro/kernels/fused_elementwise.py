"""Fused elementwise step-program kernel (Pallas TPU).

Executes a ``fused_elementwise`` graph node's ``steps`` program in a single
VMEM-resident pass over a 2-D ``[M, D]`` view of the tensor: the primary
operand is read from HBM once, every step (activation / add / mul / layer
norm) runs on the VMEM tile, and the result is written back once.  The jnp
interpreter in ``core/graph/executor.py`` pays one HBM read+write *per step*;
this kernel pays one total, which is the whole point of the fusion pass for
memory-bound glue (paper section 3, "DSL related optimization").

Step encoding (kernel-local, translated from graph steps by the executor):

* ``("activation", fn)``      -- apply ``fn`` to the running value
* ``("add", slot)``           -- add side operand ``slot`` (same [M, D] view)
* ``("mul", slot)``           -- multiply by side operand ``slot``
* ``("norm", slot, eps)``     -- layer norm over D with scale/bias pair
  ``slot``; statistics mask out the lane padding (``d_true``), so odd
  (non-128-multiple) feature dims normalize exactly.

Grid: ``(M/block_m,)`` with the full (padded) D per tile -- layer norm needs
whole rows resident.  The ``ops.fused_elementwise`` wrapper handles padding,
flattening, and block-size resolution through the tuning cache.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _ACT

__all__ = ["fused_elementwise_kernel", "fused_elementwise"]


def fused_elementwise_kernel(
    x_ref,
    side_refs,
    norm_refs,  # flat (scale0, bias0, scale1, bias1, ...)
    o_ref,
    *,
    steps: Tuple[Tuple, ...],
    d_true: int,
):
    """One grid step: run the whole step program on a [block_m, D] tile."""
    y = x_ref[...].astype(jnp.float32)
    for step in steps:
        kind = step[0]
        if kind == "activation":
            y = _ACT[step[1]](y)
        elif kind in ("add", "mul"):
            s = side_refs[step[1]][...].astype(jnp.float32)
            y = y + s if kind == "add" else y * s
        elif kind == "norm":
            slot, eps = step[1], step[2]
            scale = norm_refs[2 * slot][...].astype(jnp.float32)
            bias = norm_refs[2 * slot + 1][...].astype(jnp.float32)
            d_pad = y.shape[-1]
            if d_pad == d_true:
                mu = jnp.mean(y, axis=-1, keepdims=True)
                var = jnp.mean((y - mu) ** 2, axis=-1, keepdims=True)
            else:
                # lane padding must not pollute the statistics
                cols = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
                valid = cols < d_true
                ym = jnp.where(valid, y, 0.0)
                mu = jnp.sum(ym, axis=-1, keepdims=True) / d_true
                dy = jnp.where(valid, y - mu, 0.0)
                var = jnp.sum(dy * dy, axis=-1, keepdims=True) / d_true
            y = (y - mu) / jnp.sqrt(var + eps) * scale + bias
        else:
            raise NotImplementedError(f"fused step {kind}")
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("steps", "n_norms", "d_true", "block_m", "interpret", "out_dtype"),
)
def fused_elementwise(
    x: jax.Array,
    *operands: jax.Array,
    steps: Tuple[Tuple, ...],
    n_norms: int,
    d_true: int,
    block_m: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Run ``steps`` over ``x [M, D]`` -- 2-D, M a block_m multiple, D a lane
    multiple.  ``operands`` are the side arrays (same [M, D]) followed by
    ``n_norms`` (scale, bias) pairs shaped [1, D].

    Use :func:`repro.kernels.ops.fused_elementwise` for the padded public API.
    """
    m, d = x.shape
    assert m % block_m == 0, (x.shape, block_m)
    n_sides = len(operands) - 2 * n_norms
    sides, norms = operands[:n_sides], operands[n_sides:]
    for s in sides:
        assert s.shape == x.shape, (s.shape, x.shape)
    for nv in norms:
        assert nv.shape == (1, d), (nv.shape, d)
    out_dtype = out_dtype or x.dtype
    grid = (m // block_m,)

    row = pl.BlockSpec((block_m, d), lambda i: (i, 0))
    vec = pl.BlockSpec((1, d), lambda i: (0, 0))
    in_specs = [row] + [row] * n_sides + [vec] * (2 * n_norms)

    def kern(*refs):
        fused_elementwise_kernel(
            refs[0],
            refs[1 : 1 + n_sides],
            refs[1 + n_sides : 1 + n_sides + 2 * n_norms],
            refs[-1],
            steps=steps,
            d_true=d_true,
        )

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((m, d), out_dtype),
        interpret=interpret,
    )(x, *sides, *norms)
