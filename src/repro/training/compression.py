"""Gradient compression for the data-parallel all-reduce (distributed-
optimization trick, DESIGN.md section 3).

``int8 + error feedback``: each DP worker quantizes its local gradient to
int8 with a per-tensor f32 scale, the int8 payload is exchanged
(all-gather), dequantized and averaged locally; the quantization residual is
*carried* to the next step (error feedback, Seide et al. 2014 / Karimireddy
et al. 2019) so the compression bias vanishes over time.

Wire accounting vs the baseline fp32 ring all-reduce (2 x N bytes/device):
all-gather moves (d-1)/d x N int8 bytes/device ~= N/4 bytes -> ~8x less
traffic for d >= 8.  Implemented with shard_map so the collective is explicit
in the HLO (visible to the roofline's collective-byte parser).

``topk + error feedback`` (sparsification) is provided as a second policy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..utils.jax_compat import shard_map

__all__ = ["CompressionConfig", "init_error_feedback", "quantize_int8", "dequantize_int8",
           "compressed_mean_grads", "make_compressed_allreduce"]

PyTree = Any
Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    policy: str = "int8"  # int8 | topk | none
    topk_frac: float = 0.01
    error_feedback: bool = True


def init_error_feedback(grads_template: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def _topk_sparsify(x: Array, frac: float) -> Array:
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compressed_mean_grads(
    local_grad: Array,
    err: Array,
    *,
    axis_name: str,
    cfg: CompressionConfig,
) -> Tuple[Array, Array]:
    """Inside shard_map: compress local grad (+error), exchange, average.

    Returns (mean_grad f32, new_error).  Must be called with ``local_grad``
    already *device-local* (shard_map body).
    """
    g = local_grad.astype(jnp.float32)
    if cfg.policy == "none":
        return jax.lax.pmean(g, axis_name), err
    if cfg.error_feedback:
        g = g + err
    if cfg.policy == "topk":
        sent = _topk_sparsify(g, cfg.topk_frac)
        new_err = g - sent
        mean = jax.lax.pmean(sent, axis_name)
        return mean, new_err
    # int8
    q, scale = quantize_int8(g)
    sent = dequantize_int8(q, scale)
    new_err = g - sent
    # exchange the int8 payload: all_gather int8 + local dequant-average.
    qs = jax.lax.all_gather(q, axis_name)  # [d, ...] int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)  # [d] f32 (negligible)
    mean = jnp.tensordot(ss, qs.astype(jnp.float32), axes=([0], [0])) / qs.shape[0]
    return mean, new_err


def make_compressed_allreduce(
    mesh: Mesh,
    grads_template: PyTree,
    *,
    axis_name: str = "data",
    cfg: CompressionConfig = CompressionConfig(),
) -> Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]]:
    """Builds ``f(per_device_grads, err) -> (mean_grads, err')`` via shard_map.

    ``per_device_grads`` leaves must carry a leading sharded axis of size
    ``mesh.shape[axis_name]`` (one gradient per DP group), i.e. the caller
    computes grads with pjit out-sharded over data and *without* the implicit
    mean -- see examples/train_lm_100m.py for the wiring.
    """

    def body(grads, err):
        return jax.tree.map(
            lambda g, e: compressed_mean_grads(g, e, axis_name=axis_name, cfg=cfg),
            grads,
            err,
        )

    def split_pairs(tree):
        means = jax.tree.map(lambda t: t[0], tree, is_leaf=lambda x: isinstance(x, tuple))
        errs = jax.tree.map(lambda t: t[1], tree, is_leaf=lambda x: isinstance(x, tuple))
        return means, errs

    in_spec = jax.tree.map(lambda _: P(axis_name), grads_template)
    err_spec = jax.tree.map(lambda _: P(axis_name), grads_template)
    out_spec = jax.tree.map(lambda _: (P(), P(axis_name)), grads_template)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(in_spec, err_spec),
        out_specs=out_spec,
        check_vma=False,
    )

    def apply(per_device_grads, err):
        means, errs = split_pairs(fn(per_device_grads, err))
        # body outputs keep the device-local leading axis of length 1
        means = jax.tree.map(lambda m: m[0], means)
        return means, errs

    return apply
