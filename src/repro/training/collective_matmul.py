"""Overlapped collective matmul (compute/communication overlap,
DESIGN.md section 3).

``ag_matmul``: computes ``all_gather(x) @ w`` without ever materializing the
gathered operand: each of the N ring steps multiplies the currently-resident
x-chunk while the next chunk is in flight on a ``ppermute``.  On TPU the
collective-permute DMA runs async to the MXU, hiding (N-1)/N of the
communication behind compute — the standard Wang et al. / Megatron-style
decomposition, expressed in shard_map so XLA sees the explicit ring.

``rs_matmul``: the reverse (matmul + reduce-scatter fused): each step
computes the partial product destined for one shard and ships the running
partial around the ring — communication again hides behind the next step's
matmul.  Together they form the overlapped TP pair
(column-parallel in, row-parallel out).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from ..utils.jax_compat import axis_size, shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["ag_matmul", "rs_matmul", "make_overlapped_tp_matmuls"]

Array = jax.Array


def ag_matmul(x_local: Array, w_local: Array, axis_name: str) -> Array:
    """Inside shard_map: ``concat_i(x_i) @ w_local`` via a compute/permute ring.

    x_local: [m_loc, k] (this device's row shard of X)
    w_local: [k, n_loc] (this device's column shard of W)
    returns: [m_loc * N, n_loc] (all X rows against the local W columns)
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m_loc = x_local.shape[0]
    out = jnp.zeros((n * m_loc, w_local.shape[1]), x_local.dtype)
    perm = [(j, (j + 1) % n) for j in range(n)]
    chunk = x_local
    src = idx
    for _ in range(n):
        # the matmul of the resident chunk overlaps the in-flight ppermute
        piece = jnp.dot(chunk, w_local, preferred_element_type=jnp.float32)
        out = jax.lax.dynamic_update_slice(
            out, piece.astype(out.dtype), (src * m_loc, 0)
        )
        chunk = jax.lax.ppermute(chunk, axis_name, perm)
        src = (src - 1) % n
    return out


def rs_matmul(x_local: Array, w_local: Array, axis_name: str) -> Array:
    """Inside shard_map: ``reduce_scatter(x_full_rows @ w_local, rows)``.

    x_local: [m, k_loc] (full rows, K sharded)  w_local: [k_loc, n]
    returns: [m / N, n]  (this device's row shard of the summed product)

    Ring schedule: at each step, add the partial for the shard the running
    buffer is about to visit, then permute the buffer.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x_local.shape[0]
    m_loc = m // n
    perm = [(j, (j + 1) % n) for j in range(n)]
    acc = jnp.zeros((m_loc, w_local.shape[1]), jnp.float32)
    for i in range(n):
        # which output shard does this step contribute to?  The buffer ends
        # at device d after the remaining (n-1-i) hops: target = idx + n-1-i
        tgt = (idx + (n - 1 - i)) % n
        rows = jax.lax.dynamic_slice(
            x_local, (tgt * m_loc, 0), (m_loc, x_local.shape[1])
        )
        acc = acc + jnp.dot(rows, w_local, preferred_element_type=jnp.float32)
        if i != n - 1:
            acc = jax.lax.ppermute(acc, axis_name, perm)
    return acc.astype(x_local.dtype)


def make_overlapped_tp_matmuls(mesh: Mesh, axis_name: str = "model"):
    """shard_map-wrapped pair for testing / drop-in TP layers.

    ag(x [M, K] sharded P(axis, None), w [K, N] sharded P(None, axis))
        -> y [M, N] sharded P(None, axis)
    rs(x [M, K] sharded P(None, axis), w [K, N] sharded P(axis, None))
        -> y [M, N] sharded P(axis, None)
    """

    ag = shard_map(
        lambda x, w: ag_matmul(x, w, axis_name),
        mesh=mesh,
        in_specs=(P(axis_name, None), P(None, axis_name)),
        out_specs=P(None, axis_name),
        check_vma=False,
    )
    rs = shard_map(
        lambda x, w: rs_matmul(x, w, axis_name),
        mesh=mesh,
        in_specs=(P(None, axis_name), P(axis_name, None)),
        out_specs=P(axis_name, None),
        check_vma=False,
    )
    return ag, rs
