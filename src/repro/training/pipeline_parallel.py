"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The model's layer stack is cut into P contiguous stages; M microbatches
stream through a (M + P - 1)-tick schedule.  Stage handoff is a
``jax.lax.ppermute`` (differentiable -- the backward pass ppermutes the
cotangents the other way, giving the 1F1B-equivalent reverse schedule for
free under ``jax.grad``).

This is the documented alternative for the cross-pod axis when DCN bandwidth
makes pure DP gradient sync the binding constraint (DESIGN.md section 5); the
assigned production mesh keeps ``pod`` as DP, so pipeline runs are opt-in
(``launch/train.py --pipeline``).

Shapes inside shard_map (per stage device):
  params_stacked: [Lp, ...]    (Lp = layers per stage)
  x:              [M, mb, ...] (all microbatches resident; simple GPipe)
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..utils.jax_compat import shard_map

__all__ = ["pipeline_forward", "make_pipelined_loss"]

PyTree = Any
Array = jax.Array


def _stage_scan(layer_fn, stage_params, x):
    """Apply this stage's Lp layers sequentially to x."""

    def body(h, lp):
        return layer_fn(lp, h), None

    out, _ = jax.lax.scan(body, x, stage_params)
    return out


def pipeline_forward(
    layer_fn: Callable[[PyTree, Array], Array],
    params_stacked: PyTree,  # [L, ...] leaves, L = P * Lp
    x_micro: Array,  # [M, mb, ...]
    *,
    mesh: Mesh,
    axis_name: str = "pipe",
) -> Array:
    """Run the pipeline; returns outputs [M, mb, ...] (valid on all stages).

    GPipe schedule: at tick t, the stage holds microbatch (t - stage_id) if
    0 <= t - stage_id < M.  After the loop the final activations have exited
    the last stage; we ppermute them back to all stages via all_gather of the
    last stage's buffer.
    """
    n_stages = mesh.shape[axis_name]
    m = x_micro.shape[0]
    n_ticks = m + n_stages - 1

    def body(stage_params, xm):
        stage = jax.lax.axis_index(axis_name)
        mb_shape = xm.shape[1:]
        outputs = jnp.zeros_like(xm)
        carry = jnp.zeros(mb_shape, xm.dtype)  # incoming activation buffer

        def tick(t, state):
            carry, outputs = state
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < m)
            # stage 0 reads its own microbatch; later stages read the carry
            inp = jnp.where(
                stage == 0,
                jax.lax.dynamic_index_in_dim(xm, jnp.clip(t, 0, m - 1), keepdims=False),
                carry,
            )
            out = _stage_scan(layer_fn, stage_params, inp)
            out = jnp.where(active, out, carry)
            # record finished microbatch on the last stage
            outputs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.clip(mb_idx, 0, m - 1), axis=0
                ),
                lambda o: o,
                outputs,
            )
            # hand off to the next stage (ring; last->first slot unused)
            nxt = jax.lax.ppermute(
                out, axis_name, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return nxt, outputs

        carry, outputs = jax.lax.fori_loop(0, n_ticks, tick, (carry, outputs))
        # broadcast the last stage's outputs to every stage (psum of one-hot)
        is_last = (stage == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * is_last, axis_name)
        return outputs

    # params: layer dim sharded over pipe; x replicated
    p_specs = jax.tree.map(lambda _: P(axis_name), params_stacked)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(p_specs, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(params_stacked, x_micro)


def make_pipelined_loss(
    layer_fn: Callable[[PyTree, Array], Array],
    head_fn: Callable[[Array, Array], Array],  # (activations, labels) -> loss
    *,
    mesh: Mesh,
    axis_name: str = "pipe",
):
    """loss(params_stacked, x_micro, labels_micro) -> scalar (differentiable)."""

    def loss(params_stacked, x_micro, labels_micro):
        out = pipeline_forward(
            layer_fn, params_stacked, x_micro, mesh=mesh, axis_name=axis_name
        )
        return head_fn(out, labels_micro)

    return loss
