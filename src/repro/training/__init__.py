from .checkpoint import CheckpointManager, restore, save
from .compression import CompressionConfig, make_compressed_allreduce
from .fault_tolerance import Heartbeat, PreemptionHandler, StragglerMonitor, retry
from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule, zero1_pspecs
from .train_loop import TrainState, init_train_state, make_train_step
from .collective_matmul import ag_matmul, make_overlapped_tp_matmuls, rs_matmul
