"""Checkpointing: atomic, keep-N, step-resumable, mesh-elastic.

Layout (one directory per step)::

    <dir>/step_000123/
        arrays.npz        # every leaf, key = sanitized keystr path
        meta.json         # step, paths, shapes/dtypes, user metadata

Writes go to ``step_XXXX.tmp`` then ``os.replace`` (atomic on POSIX), so a
preemption mid-save never corrupts the latest checkpoint.  Restore takes a
*template* pytree (from ``jax.eval_shape`` of the init) and returns arrays
placed with the template's shardings -- because the saved arrays are full
(host-gathered), restoring onto a *different mesh shape* is automatic: elastic
re-scaling = restore with new shardings.  (A production deployment would
write per-shard files; single-host full-array writes keep this container
honest while preserving the same interface.)
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps", "CheckpointManager"]

PyTree = Any


def _sanitize(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.]+", "_", path).strip("_")


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    seen = {}
    for path, leaf in flat:
        key = _sanitize(jax.tree_util.keystr(path))
        if key in seen:  # disambiguate collisions deterministically
            seen[key] += 1
            key = f"{key}__{seen[key]}"
        else:
            seen[key] = 0
        out.append((key, leaf))
    return out


def save(
    directory: str,
    step: int,
    tree: PyTree,
    *,
    extra_meta: Optional[Dict[str, Any]] = None,
) -> str:
    """Atomic full-tree save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "keys": [k for k, _ in flat],
        "shapes": {k: list(np.shape(a)) for k, a in arrays.items()},
        "dtypes": {k: str(np.asarray(a).dtype) for k, a in arrays.items()},
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore(
    directory: str,
    template: PyTree,
    *,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, int]:
    """Restore into the structure of ``template``; returns (tree, step).

    ``shardings`` (optional pytree of NamedSharding) places each restored
    array -- pass shardings for a *different* mesh to elastically re-scale.
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    keys = [k for k, _ in _flatten(template)]
    if set(keys) != set(arrays.keys()):
        missing = set(keys) - set(arrays)
        extra = set(arrays) - set(keys)
        raise ValueError(f"checkpoint/template mismatch: missing={missing} extra={extra}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (k, tmpl), sh in zip(_flatten(template), shard_leaves):
        arr = arrays[k]
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"{k}: saved {arr.shape} vs template {np.shape(tmpl)}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype if hasattr(tmpl, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, out), step


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


class CheckpointManager:
    """save-every-K + keep-N retention + resume, with a save hook for the
    preemption handler (fault_tolerance.PreemptionHandler)."""

    def __init__(self, directory: str, *, save_every: int = 100, keep: int = 3):
        self.directory = directory
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, tree: PyTree, *, force: bool = False, **meta) -> Optional[str]:
        if not force and (step % self.save_every) != 0:
            return None
        path = save(self.directory, step, tree, extra_meta=meta)
        self._gc()
        return path

    def restore_latest(self, template: PyTree, shardings=None) -> Optional[Tuple[PyTree, int]]:
        if latest_step(self.directory) is None:
            return None
        return restore(self.directory, template, shardings=shardings)

    def _gc(self) -> None:
        steps = all_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
