"""Fault tolerance for long multi-pod runs.

* :class:`PreemptionHandler` -- SIGTERM/SIGINT turn into a flag the train
  loop polls; the loop checkpoints and exits cleanly instead of dying
  mid-step (maps to Borg/GKE preemption notices and TPU maintenance events).
* :func:`retry` -- exponential-backoff wrapper for transient infrastructure
  errors (checkpoint FS hiccups, collective timeouts surfaced as XlaRuntime
  errors at real scale).
* :class:`StragglerMonitor` -- per-step wall-time tracker; steps slower than
  ``threshold x`` running median raise a hook (at scale: trigger hot-spare
  swap / re-shard; here: logged + counted, and the hook is injectable so the
  launcher can act).
* :class:`Heartbeat` -- background thread touching a file every interval;
  an external watchdog restarting dead workers is the standard companion.
"""

from __future__ import annotations

import os
import signal
import statistics
import threading
import time
from typing import Any, Callable, List, Optional

from ..utils.retry import retry_call

__all__ = ["PreemptionHandler", "retry", "retry_call", "StragglerMonitor", "Heartbeat"]


class PreemptionHandler:
    """Context manager installing signal handlers that set ``should_stop``."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._previous = {}
        self.should_stop = False
        self.received: Optional[int] = None

    def _handler(self, signum, frame):
        self.should_stop = True
        self.received = signum

    def __enter__(self) -> "PreemptionHandler":
        for s in self._signals:
            self._previous[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> None:
        for s, prev in self._previous.items():
            signal.signal(s, prev)


def retry(
    fn: Callable[[], Any],
    *,
    retries: int = 3,
    backoff: float = 1.0,
    backoff_factor: float = 2.0,
    retry_on: tuple = (OSError, IOError, RuntimeError),
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
) -> Any:
    """Run ``fn`` with exponential backoff on transient errors.

    Back-compat shim: the implementation now lives in
    :func:`repro.utils.retry.retry_call` (which adds jitter and injectable
    sleep/rng); this keeps the original signature and behavior."""
    return retry_call(
        fn, retries=retries, backoff=backoff, backoff_factor=backoff_factor,
        retry_on=retry_on, on_retry=on_retry,
    )


class StragglerMonitor:
    """Detects slow steps against a running median.

    At 1000+ node scale the same signal (per-host step time, collected via
    the coordination service) drives hot-spare replacement; the ``on_straggler``
    hook is where that action plugs in.
    """

    def __init__(
        self,
        threshold: float = 2.0,
        window: int = 50,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
    ):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.straggler_steps: List[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self) -> Optional[float]:
        if self._t0 is None:
            return None
        dt = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        history = self.times[-self.window :]
        if len(history) >= 5:
            med = statistics.median(history)
            # ignore noise around sub-100ms steps: absolute + relative gate
            if dt > self.threshold * med and dt - med > 0.1:
                self.straggler_steps.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, dt, med)
        self.times.append(dt)
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class Heartbeat:
    """Touches ``path`` every ``interval`` seconds from a daemon thread."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            self.beat()

    def beat(self) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def __enter__(self) -> "Heartbeat":
        self.beat()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2 * self.interval)
