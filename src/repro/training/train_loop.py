"""Train-step factory: task loss + ADMM augment + gradient accumulation +
AdamW, all as one pjit-able pure function over a TrainState pytree.

The ADMM machinery (the paper's pruning) is a first-class member of the
train state: the Z/U trees shard like the params, the penalty joins the loss
every step, and the Z/U (projection/dual) update runs every
``admm.update_every`` steps inside the jitted step via ``lax.cond`` -- no
host round-trip, so the procedure scales to the production mesh unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.pruning.admm import (
    AdmmConfig,
    AdmmState,
    admm_init,
    admm_penalty,
    admm_update,
    convergence_metrics,
)
from ..core.pruning.masks import apply_masks, mask_gradients
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

PyTree = Any
Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: AdamWState
    admm: Optional[AdmmState] = None
    #: mask tree for masked fine-tuning after hard prune (None = dense phase)
    masks: Optional[PyTree] = None


def init_train_state(
    params: PyTree,
    opt_cfg: AdamWConfig,
    *,
    admm_cfg: Optional[AdmmConfig] = None,
    prune_plan=None,
    masks: Optional[PyTree] = None,
) -> TrainState:
    admm = None
    if admm_cfg is not None and prune_plan is not None:
        admm = admm_init(params, prune_plan, admm_cfg)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg), admm=admm, masks=masks)


def make_train_step(
    loss_fn: Callable[[PyTree, Dict[str, Array]], Tuple[Array, Dict]],
    opt_cfg: AdamWConfig,
    *,
    admm_cfg: Optional[AdmmConfig] = None,
    accum: int = 1,
) -> Callable[[TrainState, Dict[str, Array]], Tuple[TrainState, Dict[str, Array]]]:
    """Build ``step(state, batch) -> (state, metrics)``.

    ``accum > 1`` splits the batch leading dim into microbatches and
    accumulates gradients with ``lax.scan`` (compute stays per-microbatch;
    the optimizer sees the mean gradient).
    """

    def total_loss(params, state: TrainState, batch):
        p_eff = apply_masks(params, state.masks) if state.masks is not None else params
        loss, metrics = loss_fn(p_eff, batch)
        if state.admm is not None:
            loss = loss + admm_penalty(params, state.admm)
        return loss, metrics

    grad_fn = jax.value_and_grad(total_loss, has_aux=True)

    def compute_grads(state: TrainState, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(state.params, state, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc_grads, acc_loss = carry
            (loss, metrics), grads = grad_fn(state.params, state, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
            return (acc, acc_loss + loss), metrics

        def split(x):
            return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        (grads, loss_sum), metrics = jax.lax.scan(micro, (zeros, 0.0), mbs)
        grads = jax.tree.map(lambda g: (g / accum).astype(jnp.float32), grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / accum, metrics, grads

    def step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state, batch)
        if state.masks is not None:
            grads = mask_gradients(grads, state.masks)
        new_params, opt, opt_metrics = adamw_update(grads, state.opt, state.params, opt_cfg)

        admm = state.admm
        admm_metrics: Dict[str, Array] = {}
        if admm is not None and admm_cfg is not None:
            do_update = (opt.step % admm_cfg.update_every) == 0

            admm = jax.lax.cond(
                do_update,
                lambda a: admm_update(new_params, a, admm_cfg),
                lambda a: a,
                admm,
            )
            admm_metrics = convergence_metrics(new_params, admm)

        out = {"loss": loss, **metrics, **opt_metrics, **admm_metrics}
        return TrainState(params=new_params, opt=opt, admm=admm, masks=state.masks), out

    return step
