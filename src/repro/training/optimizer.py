"""Pure-JAX optimizers (no optax in this container): AdamW + schedules +
global-norm clipping, with optional ZeRO-1 state sharding.

The optimizer state is a pytree mirroring the params, so it shards under
pjit exactly like them; :func:`zero1_pspecs` additionally spreads the m/v
moments over the data axis (ZeRO-1) for memory-bound configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "linear_warmup",
    "global_norm",
    "clip_by_global_norm",
    "zero1_pspecs",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    #: keep Adam moments in this dtype (bf16 halves optimizer HBM; the
    #: update math still runs in f32)
    state_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree, config: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(config.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def linear_warmup(step, warmup: int):
    return jnp.minimum(1.0, (step + 1) / max(warmup, 1))


def cosine_schedule(step, config: AdamWConfig):
    warm = linear_warmup(step, config.warmup_steps)
    t = jnp.clip(
        (step - config.warmup_steps)
        / max(config.total_steps - config.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = config.min_lr_frac + (1 - config.min_lr_frac) * cos
    return config.lr * warm * frac


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    config: AdamWConfig,
) -> Tuple[PyTree, AdamWState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    if config.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, config.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(state.step, config)
    b1, b2 = config.b1, config.b2
    sdt = jnp.dtype(config.state_dtype)

    def upd(p, g, m, v):
        if g.dtype == jax.dtypes.float0:  # non-differentiable leaf (indices)
            return p, m, v
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / (1 - b1 ** step.astype(jnp.float32))
        vhat = vf / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + config.eps)
        if config.weight_decay and p.ndim >= 2:  # decay matrices only
            delta = delta + config.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        jax.tree.unflatten(treedef, new_p),
        AdamWState(step, jax.tree.unflatten(treedef, new_m), jax.tree.unflatten(treedef, new_v)),
        {"grad_norm": gnorm, "lr": lr},
    )


def zero1_pspecs(
    param_pspecs: PyTree,
    params: Optional[PyTree] = None,
    *,
    data_axis: str = "data",
    data_size: int = 0,
) -> PyTree:
    """ZeRO-1: shard optimizer moments along the first axis the param spec
    leaves replicated (classic moment-sharding over data).

    When ``params``/``data_size`` are given, only dims divisible by the data
    axis are sharded (uneven leaves like positional tables stay replicated).
    """

    def shard(spec: P, leaf=None) -> P:
        shape = getattr(leaf, "shape", None)
        parts = list(spec) if len(spec) else ([None] * (len(shape) if shape else 0))
        # axis already consumed by the param sharding (e.g. FSDP rules)?
        used = set()
        for p in parts:
            for a in (p if isinstance(p, tuple) else (p,)):
                used.add(a)
        if data_axis in used:
            return spec
        for i, p in enumerate(parts):
            if p is None:
                if shape is not None and data_size and shape[i] % data_size != 0:
                    continue
                parts[i] = data_axis
                return P(*parts)
        return spec  # fully sharded already (or nothing divisible)

    if params is None:
        return jax.tree.map(shard, param_pspecs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, l: shard(s, l), param_pspecs, params,
        is_leaf=lambda x: isinstance(x, P),
    )
