"""TuningCache persistence: JSON round-trips, corrupt/partial cache files
falling back to seeded defaults (never raising), and key-collision behavior
across the ``mode`` (interpret vs hw) and format/scheme axes."""

import json
import warnings

import jax.numpy as jnp
import pytest

from repro.kernels import ops as kops
from repro.kernels.ops import TuneEntry, TuningCache


@pytest.fixture
def fresh_cache():
    cache = kops.tuning_cache()
    prev_enabled, prev_entries, prev_sweeps = (
        cache.enabled, dict(cache.entries), cache.sweeps,
    )
    cache.clear()
    yield cache
    cache.enabled = prev_enabled
    cache.entries = prev_entries
    cache.sweeps = prev_sweeps


# --------------------------------------------------------------------------- #
# round-trip                                                                   #
# --------------------------------------------------------------------------- #


def test_roundtrip_preserves_blocks_ms_and_marks_loaded(tmp_path):
    c = TuningCache(enabled=False)
    k1 = TuningCache.key("matmul", 64, 128, 256, jnp.float32, "dense", False)
    k2 = TuningCache.key("qmatmul", 64, 128, 256, jnp.int8, "dense+w8a8", False)
    c.entries[k1] = TuneEntry((256, 128, 128), "swept", 0.42)
    c.entries[k2] = TuneEntry((128, 128, 512), "swept", 0.17)
    p = str(tmp_path / "tune.json")
    c.save(p)
    c2 = TuningCache(enabled=False).load(p)
    assert c2.entries[k1].blocks == (256, 128, 128)
    assert c2.entries[k1].ms == pytest.approx(0.42)
    assert c2.entries[k2].blocks == (128, 128, 512)
    assert all(e.source == "loaded" for e in c2.entries.values())


def test_roundtrip_drops_default_placeholders(tmp_path):
    """Seeded defaults were never measured: persisting them would block
    future sweeps of those shapes in other processes."""
    c = TuningCache(enabled=False)
    c.resolve("matmul", 8, 8, 8, jnp.float32, "dense", True)  # records a default
    c.entries[TuningCache.key("matmul", 16, 16, 16, jnp.float32, "dense", True)] = (
        TuneEntry((64, 128, 128), "swept", 1.0)
    )
    p = str(tmp_path / "tune.json")
    c.save(p)
    entries = json.loads(open(p).read())["entries"]
    assert len(entries) == 1
    assert next(iter(entries.values()))["source"] == "swept"


def test_save_without_path_raises():
    c = TuningCache(enabled=False, path=None)
    with pytest.raises(ValueError, match="no cache path"):
        c.save()


# --------------------------------------------------------------------------- #
# corrupt / partial cache files fall back to seeded defaults                   #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize(
    "payload",
    [
        "{ not json at all",                                  # syntactically broken
        json.dumps({"version": 1}),                           # missing entries
        json.dumps({"version": 1, "entries": {"k": {}}}),     # entry missing blocks
        json.dumps({"version": 1, "entries": {"k": None}}),   # entry wrong type
    ],
)
def test_corrupt_cache_file_warns_and_uses_defaults(tmp_path, payload):
    p = tmp_path / "tune.json"
    p.write_text(payload)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        c = TuningCache(enabled=False, path=str(p))
    assert any("ignoring unreadable tuning cache" in str(x.message) for x in w)
    # the cache still works: unknown keys resolve to the seeded defaults
    assert c.resolve("matmul", 8, 8, 8, jnp.float32, "dense", True) == (128, 128, 128)
    assert c.resolve("qmatmul", 8, 8, 8, jnp.int8, "dense+w8a8", True) == (128, 128, 128)


def test_missing_cache_file_is_silently_fresh(tmp_path):
    c = TuningCache(enabled=False, path=str(tmp_path / "nope.json"))
    assert c.entries == {}


# --------------------------------------------------------------------------- #
# key collisions                                                               #
# --------------------------------------------------------------------------- #


def test_interpret_and_hw_modes_never_share_a_winner(fresh_cache):
    """Interpret-mode sweeps time Python, not silicon: an interpret winner
    must never shadow (or be returned for) a real-hardware lookup."""
    shape = ("matmul", 64, 128, 256, jnp.float32, "dense")
    k_int = TuningCache.key(*shape, True)
    k_hw = TuningCache.key(*shape, False)
    assert k_int != k_hw
    fresh_cache.entries[k_int] = TuneEntry((64, 128, 128), "swept", 9.9)
    assert fresh_cache.lookup(*shape, False) is None
    # hw resolve falls back to the seeded default, not the interpret winner
    assert fresh_cache.resolve(*shape, False) == TuningCache.DEFAULTS["matmul"]
    # and the interpret entry is untouched
    assert fresh_cache.entries[k_int].blocks == (64, 128, 128)


def test_format_and_scheme_axes_key_separately():
    keys = {
        TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense", True),
        TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense+e2s1", True),
        TuningCache.key("matmul", 8, 8, 8, jnp.float32, "colcompact", True),
        TuningCache.key("qmatmul", 8, 8, 8, jnp.float32, "dense+w8", True),
        TuningCache.key("qmatmul", 8, 8, 8, jnp.int8, "dense+w8a8", True),
        TuningCache.key("bsr_matmul", 8, 8, 8, jnp.float32, "pbcsr", True),
        TuningCache.key("bsr_matmul", 8, 8, 8, jnp.float32, "pbcsr+e1s1", True),
    }
    assert len(keys) == 7  # no two collapse


def test_loaded_entries_survive_resolve_and_block_sweeps(fresh_cache):
    """A loaded winner is authoritative: resolve returns it without
    sweeping even when tuning is enabled."""
    shape = ("matmul", 64, 128, 256, jnp.float32, "dense")
    key = TuningCache.key(*shape, True)
    fresh_cache.entries[key] = TuneEntry((256, 128, 128), "loaded", 0.5)
    fresh_cache.enabled = True
    called = []

    def runner(*blocks):
        called.append(blocks)

    assert fresh_cache.resolve(*shape, True, runner=runner) == (256, 128, 128)
    assert not called and fresh_cache.sweeps == 0
