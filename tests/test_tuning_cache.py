"""TuningCache persistence: JSON round-trips, corrupt/partial cache files
falling back to seeded defaults (never raising), and key-collision behavior
across the ``mode`` (interpret vs hw) and format/scheme axes."""

import json
import warnings

import jax.numpy as jnp
import pytest

from repro.kernels import ops as kops
from repro.kernels.ops import TuneEntry, TuningCache


@pytest.fixture
def fresh_cache():
    cache = kops.tuning_cache()
    prev_enabled, prev_entries, prev_sweeps = (
        cache.enabled, dict(cache.entries), cache.sweeps,
    )
    cache.clear()
    yield cache
    cache.enabled = prev_enabled
    cache.entries = prev_entries
    cache.sweeps = prev_sweeps


# --------------------------------------------------------------------------- #
# round-trip                                                                   #
# --------------------------------------------------------------------------- #


def test_roundtrip_preserves_blocks_ms_and_marks_loaded(tmp_path):
    c = TuningCache(enabled=False)
    k1 = TuningCache.key("matmul", 64, 128, 256, jnp.float32, "dense", False)
    k2 = TuningCache.key("qmatmul", 64, 128, 256, jnp.int8, "dense+w8a8", False)
    c.entries[k1] = TuneEntry((256, 128, 128), "swept", 0.42)
    c.entries[k2] = TuneEntry((128, 128, 512), "swept", 0.17)
    p = str(tmp_path / "tune.json")
    c.save(p)
    c2 = TuningCache(enabled=False).load(p)
    assert c2.entries[k1].blocks == (256, 128, 128)
    assert c2.entries[k1].ms == pytest.approx(0.42)
    assert c2.entries[k2].blocks == (128, 128, 512)
    assert all(e.source == "loaded" for e in c2.entries.values())


def test_roundtrip_drops_default_placeholders(tmp_path):
    """Seeded defaults were never measured: persisting them would block
    future sweeps of those shapes in other processes."""
    c = TuningCache(enabled=False)
    c.resolve("matmul", 8, 8, 8, jnp.float32, "dense", True)  # records a default
    c.entries[TuningCache.key("matmul", 16, 16, 16, jnp.float32, "dense", True)] = (
        TuneEntry((64, 128, 128), "swept", 1.0)
    )
    p = str(tmp_path / "tune.json")
    c.save(p)
    entries = json.loads(open(p).read())["entries"]
    assert len(entries) == 1
    assert next(iter(entries.values()))["source"] == "swept"


def test_save_without_path_raises():
    c = TuningCache(enabled=False, path=None)
    with pytest.raises(ValueError, match="no cache path"):
        c.save()


# --------------------------------------------------------------------------- #
# corrupt / partial cache files fall back to seeded defaults                   #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize(
    "payload",
    [
        "{ not json at all",                                  # syntactically broken
        json.dumps({"version": 1}),                           # missing entries
        json.dumps({"version": 1, "entries": {"k": {}}}),     # entry missing blocks
        json.dumps({"version": 1, "entries": {"k": None}}),   # entry wrong type
    ],
)
def test_corrupt_cache_file_warns_and_uses_defaults(tmp_path, payload):
    p = tmp_path / "tune.json"
    p.write_text(payload)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        c = TuningCache(enabled=False, path=str(p))
    assert any("ignoring unreadable tuning cache" in str(x.message) for x in w)
    # the cache still works: unknown keys resolve to the seeded defaults
    assert c.resolve("matmul", 8, 8, 8, jnp.float32, "dense", True) == (128, 128, 128, 1)
    assert c.resolve("qmatmul", 8, 8, 8, jnp.int8, "dense+w8a8", True) == (128, 128, 128, 1)


def test_missing_cache_file_is_silently_fresh(tmp_path):
    c = TuningCache(enabled=False, path=str(tmp_path / "nope.json"))
    assert c.entries == {}


# --------------------------------------------------------------------------- #
# key collisions                                                               #
# --------------------------------------------------------------------------- #


def test_interpret_and_hw_modes_never_share_a_winner(fresh_cache):
    """Interpret-mode sweeps time Python, not silicon: an interpret winner
    must never shadow (or be returned for) a real-hardware lookup."""
    shape = ("matmul", 64, 128, 256, jnp.float32, "dense")
    k_int = TuningCache.key(*shape, True)
    k_hw = TuningCache.key(*shape, False)
    assert k_int != k_hw
    fresh_cache.entries[k_int] = TuneEntry((64, 128, 128), "swept", 9.9)
    assert fresh_cache.lookup(*shape, False) is None
    # hw resolve falls back to the seeded default, not the interpret winner
    assert fresh_cache.resolve(*shape, False) == TuningCache.DEFAULTS["matmul"]
    # and the interpret entry is untouched
    assert fresh_cache.entries[k_int].blocks == (64, 128, 128)


def test_format_and_scheme_axes_key_separately():
    keys = {
        TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense", True),
        TuningCache.key("matmul", 8, 8, 8, jnp.float32, "dense+e2s1", True),
        TuningCache.key("matmul", 8, 8, 8, jnp.float32, "colcompact", True),
        TuningCache.key("qmatmul", 8, 8, 8, jnp.float32, "dense+w8", True),
        TuningCache.key("qmatmul", 8, 8, 8, jnp.int8, "dense+w8a8", True),
        TuningCache.key("bsr_matmul", 8, 8, 8, jnp.float32, "pbcsr", True),
        TuningCache.key("bsr_matmul", 8, 8, 8, jnp.float32, "pbcsr+e1s1", True),
    }
    assert len(keys) == 7  # no two collapse


def test_loaded_entries_survive_resolve_and_block_sweeps(fresh_cache):
    """A loaded winner is authoritative: resolve returns it without
    sweeping even when tuning is enabled."""
    shape = ("matmul", 64, 128, 256, jnp.float32, "dense")
    key = TuningCache.key(*shape, True)
    fresh_cache.entries[key] = TuneEntry((256, 128, 128), "loaded", 0.5)
    fresh_cache.enabled = True
    called = []

    def runner(*blocks):
        called.append(blocks)

    assert fresh_cache.resolve(*shape, True, runner=runner) == (256, 128, 128)
    assert not called and fresh_cache.sweeps == 0


# --------------------------------------------------------------------------- #
# PR 6: pipeline-depth / block_c key-family extension                          #
# --------------------------------------------------------------------------- #


def test_matmul_defaults_carry_pipeline_depth_and_conv_block_c():
    """The matmul/qmatmul block tuple grew a 4th pipeline-depth field and
    conv2d a 3rd block_c field; defaults pin the legacy behavior (depth 1 =
    compiler-scheduled grid-K, block_c 0 = resident full-K)."""
    assert TuningCache.DEFAULTS["matmul"] == (128, 128, 128, 1)
    assert TuningCache.DEFAULTS["qmatmul"] == (128, 128, 128, 1)
    assert TuningCache.DEFAULTS["conv2d"] == (8, 128, 0)
    # candidate grids include pipelined / tiled-K entries
    assert any(c[3] >= 2 for c in TuningCache.CANDIDATES["matmul"])
    assert any(c[3] >= 2 for c in TuningCache.CANDIDATES["qmatmul"])
    assert any(c[2] > 0 for c in TuningCache.CANDIDATES["conv2d"])


def test_legacy_block_tuples_normalize_without_colliding():
    """Entries cached before the field extension (3-tuple matmul, 2-tuple
    conv) still resolve: the normalizers extend them with the legacy-pinned
    values instead of keying them separately."""
    assert kops._blocks4((256, 128, 128)) == (256, 128, 128, 1)
    assert kops._blocks4((128, 128, 128, 2)) == (128, 128, 128, 2)
    assert kops._conv_blocks3((8, 128)) == (8, 128, 0)
    assert kops._conv_blocks3((8, 128, 64)) == (8, 128, 64)


def test_extended_block_tuples_json_round_trip(tmp_path):
    """4-field matmul winners and 3-field conv winners survive save/load
    bit-exactly (depth/block_c are part of the value, not the key, so no
    old-format key can collide with them)."""
    c = TuningCache(enabled=False)
    km = TuningCache.key("matmul", 64, 128, 512, jnp.float32, "dense", False)
    kc = TuningCache.key_nd(
        "conv2d", (1, 256, 16, 16, 64, 3, 3, 1), jnp.float32, "dense+f32", False
    )
    c.entries[km] = TuneEntry((128, 128, 256, 2), "swept", 0.3)
    c.entries[kc] = TuneEntry((8, 128, 64), "swept", 0.7)
    p = str(tmp_path / "tune.json")
    c.save(p)
    c2 = TuningCache(enabled=False).load(p)
    assert c2.entries[km].blocks == (128, 128, 256, 2)
    assert c2.entries[kc].blocks == (8, 128, 64)
    assert all(e.source == "loaded" for e in c2.entries.values())


def test_loaded_pipelined_winner_blocks_sweeps(fresh_cache):
    """A loaded depth-2 winner is authoritative exactly like a legacy one:
    resolve returns it verbatim, no sweep, and the stats ledger records a
    hit rather than a miss."""
    shape = ("matmul", 64, 128, 512, jnp.float32, "dense")
    key = TuningCache.key(*shape, True)
    fresh_cache.entries[key] = TuneEntry((128, 128, 256, 2), "loaded", 0.4)
    fresh_cache.enabled = True
    called = []
    got = fresh_cache.resolve(*shape, True, runner=lambda *b: called.append(b))
    assert got == (128, 128, 256, 2)
    assert not called and fresh_cache.sweeps == 0
    assert fresh_cache.stats["matmul"] == {"hits": 1, "misses": 0, "sweeps": 0}


def test_ops_filter_restricts_sweeps_but_not_lookups(fresh_cache):
    """The tune CLI's --ops filter: excluded families never sweep (they
    resolve to defaults) while included families sweep normally; cached
    winners still serve everyone."""
    fresh_cache.enabled = True
    fresh_cache.ops_filter = frozenset({"conv2d"})
    swept = []

    def runner(*blocks):
        swept.append(blocks)
        return jnp.zeros(())

    shape = ("matmul", 64, 128, 128, jnp.float32, "dense")
    got = fresh_cache.resolve(*shape, True, runner=runner)
    assert got == TuningCache.DEFAULTS["matmul"] and not swept
    assert fresh_cache.stats["matmul"]["sweeps"] == 0
    conv_shape = (1, 8, 8, 8, 4, 3, 3, 1)
    fresh_cache.resolve_nd(
        "conv2d", conv_shape, jnp.float32, "dense+f32", True, runner=runner
    )
    assert swept  # the included family swept its candidate grid
    assert fresh_cache.stats["conv2d"]["sweeps"] == 1
    # a cached winner is returned regardless of the filter
    key = TuningCache.key(*shape, True)
    fresh_cache.entries[key] = TuneEntry((64, 128, 128, 1), "swept", 0.2)
    assert fresh_cache.resolve(*shape, True) == (64, 128, 128, 1)


def test_stats_report_csv_counts_per_family(fresh_cache):
    fresh_cache.resolve("matmul", 8, 8, 8, jnp.float32, "dense", True)   # miss
    fresh_cache.resolve("matmul", 8, 8, 8, jnp.float32, "dense", True)   # hit
    fresh_cache.resolve("qmatmul", 8, 8, 8, jnp.int8, "dense+w8a8", True)
    report = fresh_cache.stats_report()
    lines = report.splitlines()
    assert lines[0] == "family,hits,misses,sweeps"
    assert "matmul,1,1,0" in lines
    assert "qmatmul,0,1,0" in lines
    # clear() wipes the ledger with the entries
    fresh_cache.clear()
    assert fresh_cache.stats_report() == "family,hits,misses,sweeps"


# --------------------------------------------------------------------------- #
# crash-safe (atomic) save                                                     #
# --------------------------------------------------------------------------- #


def _cache_with_entry(key_dims=(64, 128, 256), blocks=(256, 128, 128)):
    c = TuningCache(enabled=False)
    k = TuningCache.key("matmul", *key_dims, jnp.float32, "dense", False)
    c.entries[k] = TuneEntry(blocks, "swept", 0.5)
    return c, k


def test_interrupted_save_leaves_previous_file_intact(tmp_path, monkeypatch):
    """A save that dies mid-write (simulated dump failure) must leave the
    previously saved JSON byte-identical and valid -- the write lands in a
    temp file that never replaces the destination."""
    c, k = _cache_with_entry()
    p = str(tmp_path / "tune.json")
    c.save(p)
    before = open(p).read()
    json.loads(before)  # valid baseline

    def boom(obj, f, **kw):
        f.write('{"version": 1, "entr')  # truncated garbage, then die
        raise RuntimeError("disk full")

    monkeypatch.setattr(json, "dump", boom)
    with pytest.raises(RuntimeError, match="disk full"):
        c.save(p)
    assert open(p).read() == before  # destination untouched
    assert json.loads(open(p).read())["entries"]  # still parseable
    leftovers = [f for f in tmp_path.iterdir() if f.name != "tune.json"]
    assert leftovers == []  # temp file cleaned up on failure


def test_concurrent_saves_never_expose_truncated_json(tmp_path):
    """Hammer save() from two threads while a reader loads in a loop: the
    atomic rename means every observed file state parses as complete JSON
    (the pre-fix plain open(path, 'w') interleaves and truncates)."""
    import threading

    c1, _ = _cache_with_entry((64, 128, 256), (256, 128, 128))
    c2, _ = _cache_with_entry((32, 64, 512), (128, 128, 512))
    # make the payloads different sizes so torn writes would be visible
    for i in range(50):
        k = TuningCache.key("conv2d", 8 + i, 8, 8, jnp.float32, "dense", True)
        c2.entries[k] = TuneEntry((1, 8, 64, 64, 1), "swept", float(i))
    p = str(tmp_path / "tune.json")
    c1.save(p)
    stop = threading.Event()
    errors = []

    def writer(c):
        while not stop.is_set():
            try:
                c.save(p)
            except Exception as e:  # pragma: no cover - fails the test below
                errors.append(e)
                return

    threads = [threading.Thread(target=writer, args=(c,)) for c in (c1, c2)]
    for t in threads:
        t.start()
    try:
        for _ in range(200):
            payload = json.loads(open(p).read())  # must never raise
            assert payload["version"] == 1
            assert len(payload["entries"]) in (1, 51)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert errors == []


def test_save_still_returns_path_and_roundtrips(tmp_path):
    """The atomic rewrite keeps the external contract: returns the path,
    and an immediate load sees exactly what was saved."""
    c, k = _cache_with_entry()
    p = str(tmp_path / "sub")
    import os

    os.makedirs(p)
    target = os.path.join(p, "tune.json")
    assert c.save(target) == target
    c2 = TuningCache(enabled=False).load(target)
    assert c2.entries[k].blocks == (256, 128, 128)
