"""Chaos suite: guarded execution, fault injection, breakers, serving
hardening.

The headline test is the chaos gate from the PR's acceptance criteria:
with a seeded 5% injected kernel-failure rate across all three demo apps
served through ``AsyncPlanServer``, 100% of submitted requests complete
(reference fallback), the scheduler thread survives, and under a *total*
failure rate the results are bit-identical to the pure reference plan.
Everything here is deterministic -- fault decisions come from seeded RNGs,
breaker cooldowns from injected clocks, retry backoff from injected sleep.
"""

import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import restore_global_state, snapshot_global_state

from repro.core.graph import (
    GraphBuilder,
    compile_plan,
    guard_fallback_counts,
)
from repro.core.graph.executor import EXEC_BACKENDS
from repro.kernels import ops as kops
from repro.models.cnn import APPS
from repro.robustness import (
    BreakerOpen,
    CircuitBreaker,
    FaultPlan,
    FaultRule,
    GuardConfig,
    InjectedFault,
    active_fault_plan,
    uninstall_all,
)
from repro.serving import (
    AsyncPlanServer,
    QueueFullError,
    SwapError,
    WatchdogTimeout,
    submit_with_retry,
)
from repro.utils.retry import retry_call

KEY = jax.random.PRNGKey(0)


class Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tiny(backend="guarded", guard=None, n=8):
    """One-linear-layer graph: the smallest demotable plan."""
    b = GraphBuilder(["x"])
    w = jax.random.normal(KEY, (n, n), jnp.float32)
    y = b.add("linear", "x", params={"w": w})
    g = b.build(y)
    return g, compile_plan(g, backend=backend, guard=guard)


# --------------------------------------------------------------------------- #
# circuit breaker state machine                                                #
# --------------------------------------------------------------------------- #


def test_breaker_trips_after_threshold_within_window():
    clk = Clock()
    br = CircuitBreaker(threshold=3, window=10.0, cooldown=5.0, clock=clk)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    br.record_failure()
    assert br.state == "closed" and br.allow()  # under threshold
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    assert not br.allow()  # cooldown not elapsed
    with pytest.raises(BreakerOpen):
        br.raise_if_open()


def test_breaker_window_prunes_stale_failures():
    clk = Clock()
    br = CircuitBreaker(threshold=3, window=10.0, clock=clk)
    br.record_failure()
    br.record_failure()
    clk.advance(11.0)  # both failures age out of the window
    br.record_failure()
    assert br.state == "closed"


def test_breaker_half_open_probe_recovers_or_reopens():
    clk = Clock()
    br = CircuitBreaker(threshold=1, cooldown=5.0, clock=clk)
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clk.advance(5.0)
    assert br.allow() and br.state == "half_open"  # one probe allowed
    br.record_failure()  # probe failed: reopen, cooldown restarts
    assert br.state == "open" and br.trips == 2 and not br.allow()
    clk.advance(5.0)
    assert br.allow() and br.state == "half_open"
    br.record_success()  # probe succeeded: full recovery
    assert br.state == "closed" and br.allow()
    assert br.snapshot() == {"state": "closed", "trips": 2, "recent_failures": 0}


def test_breaker_rejects_bad_threshold():
    with pytest.raises(ValueError, match="threshold"):
        CircuitBreaker(threshold=0)


# --------------------------------------------------------------------------- #
# fault plans                                                                  #
# --------------------------------------------------------------------------- #


def test_fault_rule_validates_kind_and_rate():
    with pytest.raises(ValueError, match="kind"):
        FaultRule("matmul", "explode")
    with pytest.raises(ValueError, match="rate"):
        FaultRule("matmul", "raise", rate=1.5)


def test_install_patches_and_uninstall_restores_entry_points():
    orig = kops.matmul
    x = jnp.ones((4, 4), jnp.float32)
    with FaultPlan([FaultRule("matmul", "raise", rate=1.0)], seed=0) as fp:
        assert kops.matmul is not orig
        with pytest.raises(InjectedFault):
            kops.matmul(x, x, interpret=True)
        assert fp.injection_count("matmul") == 1
        assert active_fault_plan() is fp
    assert kops.matmul is orig
    assert active_fault_plan() is None
    # and the restored entry point works
    y = kops.matmul(x, x, interpret=True)
    assert np.allclose(np.asarray(y), 4.0)


def test_seeded_injection_sequence_is_deterministic():
    def pattern(seed):
        fp = FaultPlan([FaultRule("matmul", "raise", rate=0.3)], seed=seed)
        fn = fp.wrap("matmul", lambda: "ok")
        seq = []
        for _ in range(200):
            try:
                fn()
                seq.append(0)
            except InjectedFault:
                seq.append(1)
        return seq

    a, b, c = pattern(7), pattern(7), pattern(8)
    assert a == b  # same seed, same call order -> identical faults
    assert a != c
    assert 30 <= sum(a) <= 90  # ~0.3 rate over 200 calls
    assert len(a) == 200


def test_nan_and_inf_poisoning():
    x = jnp.ones((4, 4), jnp.float32)
    with FaultPlan([FaultRule("matmul", "nan", rate=1.0)], seed=0):
        y = kops.matmul(x, x, interpret=True)
        assert bool(jnp.all(jnp.isnan(y)))
    with FaultPlan([FaultRule("matmul", "inf", rate=1.0)], seed=0):
        y = kops.matmul(x, x, interpret=True)
        assert bool(jnp.all(jnp.isinf(y)))


def test_latency_injection_uses_injectable_sleep():
    slept = []
    fp = FaultPlan(
        [FaultRule("matmul", "latency", rate=1.0, delay=0.25)],
        seed=0, sleep=slept.append,
    )
    x = jnp.ones((4, 4), jnp.float32)
    with fp:
        y = kops.matmul(x, x, interpret=True)
    assert slept == [0.25]
    assert np.allclose(np.asarray(y), 4.0)  # latency never corrupts output


def test_cache_corrupt_rule_zeroes_existing_entries():
    cache = kops.tuning_cache()
    k = kops.TuningCache.key("matmul", 64, 64, 64, jnp.float32, "dense", True)
    cache.entries[k] = kops.TuneEntry((64, 128, 128), "swept", 0.3)
    with FaultPlan([FaultRule("*", "cache_corrupt", rate=1.0)], seed=0) as fp:
        assert k in fp.corrupted_keys
        assert cache.entries[k].blocks == (0, 0, 0)
        assert fp.injection_count("tuning_cache") >= 1
    # conftest's autouse fixture restores the cache; nothing to clean here


def test_double_install_raises_and_uninstall_all_sweeps():
    fp1 = FaultPlan([FaultRule("matmul", "raise")]).install()
    fp2 = FaultPlan([FaultRule("conv2d", "raise")]).install()
    with pytest.raises(RuntimeError, match="already installed"):
        fp1.install()
    assert active_fault_plan() is fp2
    assert uninstall_all() == 2
    assert active_fault_plan() is None


# --------------------------------------------------------------------------- #
# guarded executor                                                             #
# --------------------------------------------------------------------------- #


def test_guarded_backend_is_listed_and_validated():
    assert "guarded" in EXEC_BACKENDS
    g, _ = _tiny(backend="reference")
    with pytest.raises(ValueError, match="guarded"):
        compile_plan(g, backend="bogus")


def test_guard_config_requires_guarded_backend():
    b = GraphBuilder(["x"])
    y = b.add("linear", "x", params={"w": jnp.eye(4)})
    g = b.build(y)
    with pytest.raises(ValueError, match="guard"):
        compile_plan(g, backend="reference", guard=GuardConfig())


def test_guarded_matches_reference_without_faults():
    g, plan = _tiny()
    ref = compile_plan(g, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    err = float(jnp.max(jnp.abs(plan(g.params, x) - ref(g.params, x))))
    assert err <= 1e-5
    stats = plan.guard_stats()
    assert stats["counters"]["primary_ok"] == 1
    assert stats["counters"]["fallbacks"] == 0


def test_total_faults_demote_bitexact_with_exact_counters():
    g, plan = _tiny()
    ref = compile_plan(g, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    y_ref = ref(g.params, x)
    base = guard_fallback_counts().get("linear/f32/exception", 0)
    with FaultPlan([FaultRule("linear", "raise", rate=1.0)], seed=0):
        y = plan(g.params, x)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))  # bit-correct
    c = plan.guard_stats()["counters"]
    assert c["fallbacks"] == 1 and c["primary_ok"] == 0
    assert c["by_key"] == {"linear/f32/exception": 1}
    # process-wide accounting extends (not duplicates) the ops-style counters
    assert guard_fallback_counts()["linear/f32/exception"] == base + 1


def test_numeric_guard_demotes_poisoned_output():
    g, plan = _tiny()
    ref = compile_plan(g, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    with FaultPlan([FaultRule("linear", "nan", rate=1.0)], seed=0):
        y = plan(g.params, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert np.array_equal(np.asarray(y), np.asarray(ref(g.params, x)))
    c = plan.guard_stats()["counters"]
    assert c["numeric_guard_trips"] == 1
    assert c["by_key"] == {"linear/f32/numeric": 1}


def test_numeric_guard_can_be_disabled():
    g, plan = _tiny(guard=GuardConfig(numeric_guards=False))
    x = jnp.ones((2, 8), jnp.float32)
    with FaultPlan([FaultRule("linear", "nan", rate=1.0)], seed=0):
        y = plan(g.params, x)
    assert bool(jnp.all(jnp.isnan(y)))  # poison flows through, no demotion
    assert plan.guard_stats()["counters"]["fallbacks"] == 0


def test_breaker_pins_to_reference_then_recovers_after_cooldown():
    clk = Clock()
    cfg = GuardConfig(breaker_threshold=2, breaker_cooldown=5.0, clock=clk)
    g, plan = _tiny(guard=cfg)
    ref = compile_plan(g, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    y_ref = np.asarray(ref(g.params, x))
    with FaultPlan([FaultRule("linear", "raise", rate=1.0)], seed=0):
        plan(g.params, x)  # failure 1
        plan(g.params, x)  # failure 2 -> breaker opens
        assert plan.guard_stats()["breakers"]["linear/f32"]["state"] == "open"
        plan(g.params, x)  # short-circuits: no primary attempt, no new trip
    c = plan.guard_stats()["counters"]
    assert c["breaker_short_circuits"] == 1
    assert c["by_key"]["linear/f32/breaker_open"] == 1
    # faults gone, but the breaker is still open: stays pinned to reference
    assert np.array_equal(np.asarray(plan(g.params, x)), y_ref)
    assert plan.guard_stats()["counters"]["breaker_short_circuits"] == 2
    # cooldown elapses -> half-open probe runs the (healthy) kernel -> closed
    clk.advance(5.0)
    plan(g.params, x)
    br = plan.guard_stats()["breakers"]["linear/f32"]
    assert br == {"state": "closed", "trips": 1, "recent_failures": 0}
    assert plan.guard_stats()["counters"]["primary_ok"] >= 1


def test_qlinear_scheme_keys_breakers_separately():
    """A quantized node's breaker key carries its scheme, so a broken INT8
    kernel never opens the f32 family's breaker."""
    b = GraphBuilder(["x"])
    wq = jnp.ones((8, 8), jnp.int8)
    y = b.add(
        "qlinear", "x",
        params={"values": wq, "w_scale": jnp.ones((8,), jnp.float32)},
        format="dense", scheme="w8",
    )
    g = b.build(y)
    plan = compile_plan(g, backend="guarded")
    x = jnp.ones((2, 8), jnp.float32)
    with FaultPlan([FaultRule("qlinear", "raise", rate=1.0)], seed=0):
        plan(g.params, x)
    assert plan.guard_stats()["counters"]["by_key"] == {
        "qlinear/w8/exception": 1
    }


def test_corrupted_tuning_cache_recovers_through_guarded_plan():
    """cache_corrupt chaos: degenerate tuned blocks crash the kernel path;
    the guarded plan absorbs it per-step and still returns correct output."""
    g, plan = _tiny(n=16)
    ref = compile_plan(g, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    # seed a (bogus) swept winner for this shape, then corrupt every entry
    cache = kops.tuning_cache()
    k = kops.TuningCache.key("matmul", 4, 16, 16, jnp.float32, "dense", True)
    cache.entries[k] = kops.TuneEntry((8, 128, 128), "swept", 0.1)
    with FaultPlan([FaultRule("*", "cache_corrupt", rate=1.0)], seed=0):
        y = plan(g.params, x)
    assert np.array_equal(np.asarray(y), np.asarray(ref(g.params, x)))
    assert plan.guard_stats()["counters"]["fallbacks"] >= 1


def test_batched_guarded_plan_is_eager_and_rejects_vmap():
    g, plan = _tiny()
    with pytest.raises(ValueError, match="eager"):
        plan.batched(2, via_vmap=True)
    bp = plan.batched(2)
    x = jnp.ones((3, 8), jnp.float32)  # padded tail chunk
    with FaultPlan([FaultRule("linear", "raise", rate=1.0)], seed=0):
        y = bp(g.params, x)
    assert y.shape == (3, 8)
    assert plan.guard_stats()["counters"]["fallbacks"] == 2  # two chunks


def test_guard_counters_restore_via_conftest_snapshot():
    """The state-isolation machinery covers guard counters and installed
    fault plans exactly like the conv/tuning state."""
    baseline = snapshot_global_state()
    g, plan = _tiny()
    snap = snapshot_global_state()
    FaultPlan([FaultRule("linear", "raise", rate=1.0)], seed=0).install()
    plan(g.params, jnp.ones((2, 8), jnp.float32))
    assert guard_fallback_counts()["linear/f32/exception"] >= 1
    assert active_fault_plan() is not None
    restore_global_state(snap)
    assert snapshot_global_state() == baseline
    assert active_fault_plan() is None  # leaked install force-removed


# --------------------------------------------------------------------------- #
# retry helper                                                                 #
# --------------------------------------------------------------------------- #


def test_retry_call_backoff_schedule_with_jitter():
    delays, attempts = [], []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise OSError("transient")
        return "ok"

    out = retry_call(
        flaky, retries=5, backoff=1.0, backoff_factor=2.0, jitter=0.5,
        sleep=delays.append, rng=random.Random(0),
        on_retry=lambda i, e: attempts.append(i),
    )
    assert out == "ok" and calls["n"] == 4
    assert attempts == [0, 1, 2]
    assert len(delays) == 3
    # full-jitter bounds: delay_i in [base_i, base_i * 1.5)
    for d, base in zip(delays, [1.0, 2.0, 4.0]):
        assert base <= d < base * 1.5


def test_retry_call_exhaustion_reraises_and_validates():
    with pytest.raises(OSError):
        retry_call(
            lambda: (_ for _ in ()).throw(OSError("nope")),
            retries=2, sleep=lambda _: None,
        )
    with pytest.raises(ValueError, match="retries"):
        retry_call(lambda: 1, retries=-1)
    with pytest.raises(ValueError, match="jitter"):
        retry_call(lambda: 1, jitter=-0.1)


def test_training_retry_backcompat_delegates():
    from repro.training.fault_tolerance import retry

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("once")
        return 42

    assert retry(flaky, retries=1, backoff=0.0) == 42


# --------------------------------------------------------------------------- #
# serving hardening: watchdog, health, submit retry                            #
# --------------------------------------------------------------------------- #


def _tiny_server(**kw):
    g, plan = _tiny()
    server = AsyncPlanServer(**kw)
    server.add_plan("tiny", plan, g.params, batch_size=2)
    return g, plan, server


def test_watchdog_fails_hung_batch_scheduler_survives():
    g, plan, server = _tiny_server(watchdog=0.1, clock=time.monotonic)
    x = jnp.ones((8,), jnp.float32)
    h0 = server.submit("tiny", x)  # warm (compile) outside the fault window
    server.step(force=True)
    assert h0.result(5).shape == (8,)
    release = threading.Event()
    fp = FaultPlan(
        [FaultRule("linear", "latency", rate=1.0, delay=0.0)],
        seed=0, sleep=lambda _: release.wait(10),
    ).install()
    try:
        h = server.submit("tiny", x)
        server.step(force=True)  # worker hangs; watchdog deadline fires
        assert h.done()
        assert isinstance(h.exception(), WatchdogTimeout)
        assert server.stats["per_plan"]["tiny"]["watchdog_timeouts"] == 1
    finally:
        release.set()  # unblock the abandoned worker thread
        fp.uninstall()
    # the abandoned worker finishing late must not overwrite the verdict
    time.sleep(0.05)
    assert isinstance(h.exception(), WatchdogTimeout)
    # and the scheduler keeps serving
    h2 = server.submit("tiny", x)
    server.step(force=True)
    assert h2.exception() is None and h2.result(1).shape == (8,)
    server.close()


def test_scheduler_thread_survives_tick_errors():
    _, _, server = _tiny_server(
        clock=time.monotonic, tick_interval=0.001, flush_after=0.005
    )
    boom = {"n": 0}
    real_step = server.step

    def bad_step(**kw):
        if boom["n"] < 3:
            boom["n"] += 1
            raise RuntimeError("injected tick failure")
        return real_step(**kw)

    server.step = bad_step
    server.start()
    deadline = time.monotonic() + 5
    while boom["n"] < 3 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert boom["n"] == 3
    assert server.running  # thread survived every bad tick
    assert server.health()["tick_errors"] == 3
    del server.step  # restore the real method for the drain in close()
    h = server.submit("tiny", jnp.ones((8,), jnp.float32))
    assert h.result(5).shape == (8,)
    server.close()
    assert not server.running


def test_health_snapshot_shape():
    g, plan, server = _tiny_server(clock=lambda: 0.0)
    with FaultPlan([FaultRule("linear", "raise", rate=1.0)], seed=0):
        h = server.submit("tiny", jnp.ones((8,), jnp.float32))
        server.step(force=True)
    assert h.exception() is None  # guarded plan absorbed the fault
    health = server.health()
    assert health["running"] is False and health["closed"] is False
    assert health["pending"] == 0 and health["tick_errors"] == 0
    tiny = health["plans"]["tiny"]
    assert tiny["queue_depth"] == 0
    assert tiny["stats"]["completed"] == 1
    guard = tiny["guard"]
    assert guard["counters"]["fallbacks"] >= 1
    assert "linear/f32" in guard["breakers"]
    server.close()


def test_submit_with_retry_rides_out_backpressure():
    _, _, server = _tiny_server(clock=lambda: 0.0, max_queue=1)
    h1 = server.submit("tiny", jnp.ones((8,), jnp.float32))
    # queue is now full; the retry helper drains it between attempts
    h2 = submit_with_retry(
        server, "tiny", jnp.ones((8,), jnp.float32),
        retries=3, backoff=0.001,
        sleep=lambda _: server.step(force=True),
    )
    server.step(force=True)
    assert h1.result(1).shape == (8,) and h2.result(1).shape == (8,)
    # a queue that stays full exhausts the retries and still raises
    server.submit("tiny", jnp.ones((8,), jnp.float32))
    with pytest.raises(QueueFullError):
        submit_with_retry(
            server, "tiny", jnp.ones((8,), jnp.float32),
            retries=2, backoff=0.001, sleep=lambda _: None,
        )
    server.close()


# --------------------------------------------------------------------------- #
# the chaos gate (acceptance criteria)                                         #
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_chaos_gate_all_apps_zero_loss_and_bitexact_fallback():
    """Acceptance gate: all three demo apps served by one AsyncPlanServer
    under a seeded 5% kernel-failure rate -- every request completes, close
    to reference; under a 100% rate every step demotes and the results are
    bit-identical to the pure reference plans; the scheduler thread never
    dies; breakers trip under sustained failure and recover after cooldown."""
    clk = Clock()
    size, frames_per_app = 12, 4
    server = AsyncPlanServer(flush_after=0.005, clock=time.monotonic)
    plans, refs, shapes = {}, {}, {}
    for app in APPS:
        g = APPS[app](jax.random.PRNGKey(0), base=8)
        cfg = GuardConfig(breaker_threshold=3, breaker_cooldown=5.0, clock=clk)
        plans[app] = (compile_plan(g, backend="guarded", guard=cfg), g.params)
        refs[app] = compile_plan(g, backend="reference")
        c_in = 1 if app == "coloring" else 3
        shapes[app] = (c_in, size, size)
        server.add_plan(
            app, plans[app][0], g.params, batch_size=2,
            input_spec=[(shapes[app], jnp.float32)],
        )
    rng = np.random.default_rng(0)
    frames = {
        app: [
            jnp.asarray(rng.standard_normal(shapes[app]), jnp.float32)
            for _ in range(frames_per_app)
        ]
        for app in APPS
    }
    with server:
        server.start()
        for app in APPS:  # warm each app's path outside the chaos window
            server.submit(app, frames[app][0]).result(60)

        def serve_all():
            handles = [
                (app, f, submit_with_retry(server, app, f, backoff=0.001))
                for app in APPS
                for f in frames[app]
            ]
            results = [(app, f, h.result(120)) for app, f, h in handles]
            assert all(h.exception() is None for _, _, h in handles)
            return results

        # scenario 1: 5% failure rate -- zero loss, close to reference
        with FaultPlan([FaultRule("*", "raise", rate=0.05)], seed=7) as fp:
            results = serve_all()
        assert len(results) == 3 * frames_per_app  # 100% completion
        for app, f, y in results:
            y_ref = refs[app](plans[app][1], f[None])
            err = float(jnp.max(jnp.abs(jnp.asarray(y) - jnp.asarray(y_ref)[0])))
            assert err <= 1e-4, (app, err)
        assert fp.injection_count() >= 1  # chaos actually happened

        # scenario 2: total failure -- every step demotes, bit-exact results
        with FaultPlan([FaultRule("*", "raise", rate=1.0)], seed=7):
            results = serve_all()
        for app, f, y in results:
            y_ref = refs[app](plans[app][1], f[None])
            assert np.array_equal(np.asarray(y), np.asarray(y_ref)[0]), app

        # the sustained failures tripped breakers on every app...
        tripped = {
            app
            for app in APPS
            for b in plans[app][0].guard_stats()["breakers"].values()
            if b["trips"] >= 1
        }
        assert tripped == set(APPS)
        # ...and with the faults gone + cooldown elapsed they close again
        clk.advance(5.0)
        for app in APPS:
            server.submit(app, frames[app][0]).result(60)
        for app in APPS:
            states = {
                b["state"]
                for b in plans[app][0].guard_stats()["breakers"].values()
            }
            assert states == {"closed"}, (app, states)
        assert server.running  # the scheduler thread survived all of it
        assert server.health()["tick_errors"] == 0
        total = server.stats
        assert total["completed"] == total["submitted"]  # zero request loss
        assert total["bad_frames"] == 0 and total["watchdog_timeouts"] == 0


@pytest.mark.slow
def test_chaos_gate_hot_swap_all_apps_zero_loss():
    """Acceptance gate (PR 9): swap all three demo-app plans mid-traffic
    under the seeded 5% chaos rate -- 100% of admitted requests complete at
    parity with the reference plan *of the version that served them*, every
    old version drains and retires, and the rollback path is exercised (a
    poisoned incoming version must never install)."""

    def scale(params, factor):
        return jax.tree_util.tree_map(
            lambda a: a * factor
            if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating) else a,
            params,
        )

    size = 12
    server = AsyncPlanServer(flush_after=0.005, clock=time.monotonic)
    plans, refs, shapes, frames, vparams = {}, {}, {}, {}, {}
    rng = np.random.default_rng(0)
    for app in APPS:
        g = APPS[app](jax.random.PRNGKey(0), base=8)
        cfg = GuardConfig(breaker_threshold=100)
        plans[app] = compile_plan(g, backend="guarded", guard=cfg)
        refs[app] = compile_plan(g, backend="reference")
        c_in = 1 if app == "coloring" else 3
        shapes[app] = (c_in, size, size)
        vparams[app] = {0: g.params, 1: scale(g.params, 0.5)}
        frames[app] = [
            jnp.asarray(rng.standard_normal(shapes[app]), jnp.float32)
            for _ in range(6)
        ]
        server.add_plan(
            app, plans[app], g.params, batch_size=2,
            input_spec=[(shapes[app], jnp.float32)],
        )
    with server:
        server.start()
        for app in APPS:  # warm each app's path outside the chaos window
            server.submit(app, frames[app][0]).result(60)

        def submit_all(lo, hi):
            return [
                (app, f, submit_with_retry(server, app, f, backoff=0.001))
                for app in APPS
                for f in frames[app][lo:hi]
            ]

        with FaultPlan([FaultRule("*", "raise", rate=0.05)], seed=7) as fp:
            handles = submit_all(0, 3)  # admitted on v0
            for app in APPS:  # swap every plan while that traffic is live
                assert server.swap_plan(
                    app, plans[app], vparams[app][1],
                    probe_frames=[frames[app][0]],
                ) == 1
            # rollback path: a poisoned version must fail its probe and
            # leave the freshly installed v1 serving
            with pytest.raises(SwapError, match="non-finite"):
                server.swap_plan(
                    "coloring", plans["coloring"],
                    scale(vparams["coloring"][0], np.nan),
                    probe_frames=[frames["coloring"][0]],
                )
            handles += submit_all(3, 6)  # admitted on v1
            versions = {id(h): h._runner.version for _, _, h in handles}
            results = [(app, f, h, h.result(120)) for app, f, h in handles]
        assert fp.injection_count() >= 1  # chaos actually happened
        assert len(results) == 3 * 6  # 100% completion: zero request loss
        for app, f, h, y in results:
            want = refs[app](vparams[app][versions[id(h)]], f[None])
            err = float(jnp.max(jnp.abs(jnp.asarray(y) - jnp.asarray(want)[0])))
            assert err <= 1e-4, (app, versions[id(h)], err)
        # both versions actually served traffic on every app
        assert all(
            {versions[id(h)] for a, _, h in handles if a == app} == {0, 1}
            for app in APPS
        )
        health = server.health()
        s = server.stats
        for app in APPS:
            assert health["plans"][app]["version"] == 1
            assert "draining" not in health["plans"][app]  # v0 retired
        assert s["swaps"] == 3 and s["versions_retired"] == 3
        assert s["swap_rollbacks"] == 1
        assert s["completed"] == s["submitted"]
        assert server.health()["tick_errors"] == 0


@pytest.mark.slow
def test_chaos_gate_decode_zero_sequence_loss():
    """Acceptance gate (PR 10): autoregressive decode through guarded
    prefill/decode plans under the seeded 5% kernel-failure rate -- every
    sequence completes (per-step demotion absorbs faults before they can
    fail a batch), the generated tokens match the naive jnp greedy loop,
    and no KV-cache page leaks; under a 100% rate every step demotes and
    the tokens are still golden (reference fallback is bit-correct)."""
    from repro.configs.registry import smoke_config
    from repro.core.graph.passes import optimize
    from repro.models.transformer import forward, init_lm
    from repro.models.transformer_graph import (
        build_decoder_graph,
        decoder_cache_spec,
    )
    from repro.serving import PagedKVCache

    cfg = smoke_config("qwen2.5-3b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    guard = GuardConfig(breaker_threshold=100)
    plans, graphs = {}, {}
    for phase in ("prefill", "decode"):
        graphs[phase] = optimize(build_decoder_graph(params, cfg, phase=phase))
        plans[phase] = compile_plan(
            graphs[phase], backend="guarded", guard=guard, interpret=True
        )
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 6, 3, 8)]

    def naive(prompt, steps):
        seq = [int(t) for t in prompt]
        for _ in range(steps):
            logits, _ = forward(params, cfg, jnp.asarray([seq], jnp.int32))
            seq.append(int(jnp.argmax(logits[0, -1])))
        return seq[len(prompt):]

    want = [naive(p, 3) for p in prompts]

    def serve_all():
        cache = PagedKVCache(num_pages=32, page_size=4,
                             **decoder_cache_spec(cfg))
        server = AsyncPlanServer()
        server.add_llm("lm", prefill=plans["prefill"],
                       decode=plans["decode"], cache=cache, max_batch=2)
        handles = [server.submit_llm("lm", p, max_new_tokens=3)
                   for p in prompts]
        while any(not h.done() for h in handles):
            server.step()
        st = server.stats["per_llm"]["lm"]
        server.close()
        cache.check_invariants()
        assert cache.used_pages == 0  # zero page leak
        return handles, st

    # scenario 1: 5% failure rate -- zero sequence loss, golden tokens
    with FaultPlan([FaultRule("*", "raise", rate=0.05)], seed=7) as fp:
        handles, st = serve_all()
    assert fp.injection_count() >= 1  # chaos actually happened
    assert st["failed"] == 0 and st["completed"] == len(prompts)
    for h, w in zip(handles, want):
        assert h.exception() is None
        assert [int(t) for t in h.result(0)] == w

    # scenario 2: total failure -- every step demotes, tokens still golden
    base = sum(
        plans[p].guard_stats()["counters"]["fallbacks"]
        for p in ("prefill", "decode")
    )
    with FaultPlan([FaultRule("*", "raise", rate=1.0)], seed=7):
        handles, st = serve_all()
    assert st["failed"] == 0
    for h, w in zip(handles, want):
        assert [int(t) for t in h.result(0)] == w
    demoted = sum(
        plans[p].guard_stats()["counters"]["fallbacks"]
        for p in ("prefill", "decode")
    )
    assert demoted > base  # the fallback path genuinely carried the traffic


def test_demotions_surface_in_registry_and_trace():
    """Chaos observability contract (make chaos-smoke): a guarded run under
    fault injection reports every demotion BOTH ways -- as registry counters
    (guard_demotions_total, the guard_fallback_counts view) and as trace
    annotations (a ``demoted`` arg on the step span plus a cat="guard"
    instant), and the two accounts agree event-for-event."""
    from repro.obs import metrics, trace

    g, plan = _tiny()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8))
    with FaultPlan([FaultRule("linear", "raise", rate=1.0)], seed=0):
        with trace.tracing() as buf:
            plan(g.params, x)
    # registry side
    assert guard_fallback_counts()["linear/f32/exception"] == 1
    series = metrics.registry().counter(
        "guard_demotions_total", op="linear", scheme="f32", reason="exception"
    )
    assert series.value == 1
    # trace side: the step span is annotated and a guard instant fired
    (step,) = [s for s in buf.spans() if s["cat"] == "step"]
    assert step["args"]["demoted"] == "exception"
    (inst,) = buf.instants("guard")
    assert inst["name"] == "demote:linear"
    assert inst["args"] == {"scheme": "f32", "reason": "exception"}
    # the instant fired inside the step's time window
    assert step["ts"] <= inst["ts"] <= step["ts"] + step["dur"]
