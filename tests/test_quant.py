"""Quantization subsystem: QTensor round-trips, INT8 qmatmul kernel parity,
calibration, the ``quantize`` pass, the ``quant`` executor backend, and the
end-to-end acceptance gates (demo apps at <= 5e-2 vs fp32 with >= 3x weight
compression)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    DEFAULT_PIPELINE,
    Graph,
    Node,
    PassContext,
    PassManager,
    compile_plan,
    optimize,
    registered_ops,
)
from repro.core.graph.passes import fuse_epilogue, quantize
from repro.kernels import ops as kops
from repro.kernels import qmatmul, ref
from repro.models.cnn import APP_ACT_SKIP, APP_QUANT_SKIP, APPS, app_masks
from repro.quant import CalibrationTable, QTensor, calibrate_plan, fake_quant

KEY = jax.random.PRNGKey(0)

APP_INPUTS = {
    "style_transfer": (1, 3, 16, 16),
    "coloring": (1, 1, 16, 16),
    "super_resolution": (1, 3, 8, 8),
}


# --------------------------------------------------------------------------- #
# QTensor                                                                      #
# --------------------------------------------------------------------------- #


def test_qtensor_per_tensor_roundtrip():
    x = jax.random.normal(KEY, (33, 47)) * 3.0
    qt = QTensor.from_float(x)
    assert qt.values.dtype == jnp.int8
    assert qt.axis is None and jnp.ndim(qt.scale) == 0
    # symmetric absmax: reconstruction error bounded by half a step
    assert qt.max_abs_error(x) <= float(qt.scale) * 0.5 + 1e-6
    # -128 never appears (negation-safe symmetric range)
    assert int(jnp.min(qt.values)) >= -127


def test_qtensor_per_channel_beats_per_tensor():
    # channels at wildly different magnitudes: one shared scale wrecks the
    # small channel, per-channel scales track it
    w = jnp.concatenate(
        [jax.random.normal(KEY, (64, 8)) * 10.0, jax.random.normal(KEY, (64, 8)) * 0.01],
        axis=1,
    )
    per_t = QTensor.from_float(w)
    per_c = QTensor.from_float(w, axis=1)
    assert per_c.scale.shape == (16,)
    small = w[:, 8:]
    err_t = float(jnp.abs(per_t.dequantize()[:, 8:] - small).max())
    err_c = float(jnp.abs(per_c.dequantize()[:, 8:] - small).max())
    assert err_c < err_t / 10


def test_qtensor_bytes_and_zero_channel():
    w = jnp.zeros((16, 4)).at[:, :2].set(1.0)
    qt = QTensor.from_float(w, axis=1)
    # all-zero channels dequantize to zeros, never NaN
    assert not bool(jnp.isnan(qt.dequantize()).any())
    assert qt.nbytes == 16 * 4 + 4 * 4  # int8 payload + f32 scales
    assert qt.compression_ratio() > 3.0


def test_fake_quant_matches_dequantized_quantize():
    x = jax.random.normal(KEY, (8, 8))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    qt_vals = jnp.clip(jnp.round(x / scale), -127, 127) * scale
    np.testing.assert_allclose(np.asarray(fake_quant(x, jnp.float32(scale))),
                               np.asarray(qt_vals), rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------- #
# qmatmul kernel vs oracle                                                     #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("shape", [(16, 64, 32), (37, 70, 50), (5, 130, 129)])
@pytest.mark.parametrize("scheme", ["w8", "w8a8"])
def test_qmatmul_kernel_matches_ref(shape, scheme):
    m, k, n = shape
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(3), (n,))
    qt = QTensor.from_float(w, axis=1)
    x_scale = float(jnp.max(jnp.abs(x))) / 127.0 if scheme == "w8a8" else None
    got = qmatmul(x, qt.values, qt.scale, b, x_scale=x_scale, activation="relu")
    want = ref.qmatmul_ref(x, qt.values, qt.scale, b, x_scale=x_scale, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
    # and the whole scheme stays close to fp32
    f32 = ref.matmul_ref(x, w, b, activation="relu")
    assert float(jnp.abs(got - f32).max()) <= 5e-2


def test_qmatmul_leading_batch_dims():
    x = jax.random.normal(KEY, (2, 3, 40))
    w = jax.random.normal(jax.random.PRNGKey(2), (40, 24)) * 0.1
    qt = QTensor.from_float(w, axis=1)
    got = qmatmul(x, qt.values, qt.scale)
    assert got.shape == (2, 3, 24)
    want = ref.qmatmul_ref(x.reshape(6, 40), qt.values, qt.scale).reshape(2, 3, 24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("scheme", ["w8", "w8a8"])
def test_qmatmul_epilogue_program(scheme):
    m, k, n = 20, 48, 40
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.1
    side = jax.random.normal(jax.random.PRNGKey(3), (m, n))
    qt = QTensor.from_float(w, axis=1)
    x_scale = float(jnp.max(jnp.abs(x))) / 127.0 if scheme == "w8a8" else None
    steps = (("add", 0), ("activation", "gelu"), ("mul", 0))
    got = qmatmul(
        x, qt.values, qt.scale, x_scale=x_scale,
        epilogue=steps, epilogue_sides=(side,),
    )
    want = ref.apply_steps_ref(
        ref.qmatmul_ref(x, qt.values, qt.scale, x_scale=x_scale), steps, [side]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_qmatmul_tunes_under_its_own_key_family():
    cache = kops.tuning_cache()
    prev = dict(cache.entries)
    try:
        x = jax.random.normal(KEY, (16, 64))
        w = jax.random.normal(jax.random.PRNGKey(2), (64, 32)) * 0.1
        qt = QTensor.from_float(w, axis=1)
        qmatmul(x, qt.values, qt.scale)
        qmatmul(x, qt.values, qt.scale, x_scale=0.01)
        k_w8 = kops.TuningCache.key("qmatmul", 16, 32, 64, jnp.float32, "dense+w8", True)
        k_a8 = kops.TuningCache.key("qmatmul", 16, 32, 64, jnp.int8, "dense+w8a8", True)
        assert k_w8 in cache.entries and k_a8 in cache.entries
        # never aliases the fp32 matmul family
        assert kops.TuningCache.key("matmul", 16, 32, 64, jnp.float32, "dense", True) not in (
            k_w8, k_a8,
        )
    finally:
        cache.entries = prev


# --------------------------------------------------------------------------- #
# calibration                                                                  #
# --------------------------------------------------------------------------- #


def _mlp_graph(key, k=48, h=64, n_out=32):
    k1, k2 = jax.random.split(key)
    nodes = [
        Node("linear", "fc1", ("x",)),
        Node("activation", "act1", ("fc1",), {"fn": "relu"}),
        Node("linear", "fc2", ("act1",)),
    ]
    params = {
        "fc1": {"w": jax.random.normal(k1, (k, h)) * 0.1, "b": jnp.zeros((h,))},
        "fc2": {"w": jax.random.normal(k2, (h, n_out)) * 0.1, "b": jnp.zeros((n_out,))},
    }
    return Graph(nodes=nodes, inputs=("x",), outputs=("fc2",), params=params)


def test_calibration_table_running_max_and_json(tmp_path):
    t = CalibrationTable()
    t.observe("x", jnp.asarray([1.0, -3.0]))
    t.observe("x", jnp.asarray([2.0]))
    assert t.ranges["x"] == 3.0
    assert "x" in t and "y" not in t
    assert t.scale("x") == pytest.approx(3.0 / 127.0)
    assert t.get_scale("y") is None
    p = tmp_path / "calib.json"
    t.batches = 2
    t.save(str(p))
    t2 = CalibrationTable.load(str(p))
    assert t2.ranges == t.ranges and t2.batches == 2
    assert json.loads(p.read_text())["version"] == 1


def test_calibrate_plan_records_inputs_and_every_node():
    g = _mlp_graph(KEY)
    plan = compile_plan(g, backend="reference")
    xs = [jax.random.normal(jax.random.PRNGKey(i), (4, 48)) for i in range(3)]
    table = calibrate_plan(plan, g.params, xs)
    assert set(table.ranges) == {"x", "fc1", "act1", "fc2"}
    assert table.batches == 3
    want = max(float(jnp.max(jnp.abs(x))) for x in xs)
    assert table.ranges["x"] == pytest.approx(want)


# --------------------------------------------------------------------------- #
# the quantize pass                                                            #
# --------------------------------------------------------------------------- #


def test_quantize_pass_linear_w8a8_and_w8():
    g = _mlp_graph(KEY)
    plan = compile_plan(g, backend="reference")
    x = jax.random.normal(KEY, (4, 48))
    table = calibrate_plan(plan, g.params, [x])
    gq = quantize(g, table)
    fc1 = gq.node("fc1")
    assert fc1.op == "qlinear" and fc1.attrs["scheme"] == "w8a8"
    assert fc1.attrs["x_scale"] == pytest.approx(table.scale("x"))
    assert fc1.attrs["bytes_saved"] > 0
    assert gq.params["fc1"]["values"].dtype == jnp.int8
    assert gq.params["fc1"]["w_scale"].shape == (64,)
    assert "b" in gq.params["fc1"]  # bias survives f32
    # empty table -> weight-only: no activation ranges, scheme w8
    gw = quantize(g, CalibrationTable())
    assert gw.node("fc1").attrs["scheme"] == "w8"
    assert "x_scale" not in gw.node("fc1").attrs


def test_quantize_pass_skip_and_pbcsr_untouched():
    g = _mlp_graph(KEY)
    gq = quantize(g, CalibrationTable(), skip=("fc1",))
    assert gq.node("fc1").op == "linear"
    assert gq.node("fc2").op == "qlinear"
    # pbcsr sparse_linear stays f32 (blocked payload)
    n = Node("sparse_linear", "sp", ("x",), {"format": "pbcsr"})
    g2 = Graph(
        nodes=[n], inputs=("x",), outputs=("sp",),
        params={"sp": {"values": jnp.zeros((2, 1, 8, 8)), "block_rows": jnp.zeros((2, 1), jnp.int32)}},
    )
    assert quantize(g2, CalibrationTable()).node("sp").op == "sparse_linear"


def test_quantize_preserves_epilogue_and_its_params():
    # linear -> layer-norm follower: fuse_epilogue folds the norm (moving
    # scale/bias into e0_* params), quantize must carry both through
    k1, _ = jax.random.split(KEY)
    nodes = [
        Node("linear", "fc", ("x",)),
        Node("norm", "ln", ("fc",), {"kind": "layer"}),
    ]
    params = {
        "fc": {"w": jax.random.normal(k1, (32, 24)) * 0.1},
        "ln": {"scale": jnp.ones((24,)) * 1.1, "bias": jnp.zeros((24,)) + 0.1},
    }
    g = Graph(nodes=nodes, inputs=("x",), outputs=("ln",), params=params)
    gf = fuse_epilogue(g)
    gq = quantize(gf, CalibrationTable())
    node = gq.node("ln")
    assert node.op == "qlinear" and node.attrs["epilogue"]
    assert "e0_scale" in gq.params["ln"] and "e0_bias" in gq.params["ln"]
    x = jax.random.normal(KEY, (6, 32))
    got = compile_plan(gq, backend="quant")(gq.params, x)
    want = compile_plan(gf, backend="reference")(gf.params, x)
    assert float(jnp.abs(got - want).max()) <= 5e-2


def test_quantize_in_default_pipeline_after_fuse_epilogue_and_gated():
    i_epi = DEFAULT_PIPELINE.index("fuse_epilogue")
    i_q = DEFAULT_PIPELINE.index("quantize")
    assert i_q == i_epi + 1
    # no calibration in the context -> the pass is skipped entirely
    g = _mlp_graph(KEY)
    ctx = PassContext()
    go = PassManager().run(g, ctx)
    assert all(n.op != "qlinear" for n in go.nodes)
    assert not ctx.stats["quantize"].changed


# --------------------------------------------------------------------------- #
# the quant executor backend                                                   #
# --------------------------------------------------------------------------- #


def test_quant_backend_parity_and_kernel_backend_rejects_qlinear():
    g = _mlp_graph(KEY)
    x = jax.random.normal(KEY, (8, 48))
    table = calibrate_plan(compile_plan(g, backend="reference"), g.params, [x])
    gq = quantize(g, table)
    got = compile_plan(gq, backend="quant")(gq.params, x)
    oracle = compile_plan(gq, backend="reference")(gq.params, x)
    # Pallas int8 kernels vs the jnp dequant oracle: near-exact
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle), rtol=1e-4, atol=1e-5)
    # vs the full-precision plan: bounded quantization noise
    f32 = compile_plan(g, backend="reference")(g.params, x)
    assert float(jnp.abs(got - f32).max()) <= 5e-2
    # qlinear is a quant-backend op; plain kernel plans refuse it
    assert "qlinear" in registered_ops("quant")
    with pytest.raises(NotImplementedError, match="qlinear"):
        compile_plan(gq, backend="kernel")


def test_quant_backend_inherits_kernel_handlers():
    ops = registered_ops("quant")
    for op in ("linear", "sparse_linear", "conv2d", "fused_elementwise", "qlinear", "qconv2d"):
        assert op in ops, op


def test_colcompact_qlinear_roundtrip():
    # sparse_linear(colcompact) -> qlinear keeps the gather indices
    w = jax.random.normal(KEY, (64, 24)) * 0.1
    kept = jnp.asarray(np.arange(0, 64, 2), jnp.int32)
    n = Node("sparse_linear", "sp", ("x",), {"format": "colcompact", "k_full": 64})
    g = Graph(
        nodes=[n], inputs=("x",), outputs=("sp",),
        params={"sp": {"values": w[::2], "kept": kept}},
    )
    gq = quantize(g, CalibrationTable())
    assert gq.node("sp").op == "qlinear"
    assert gq.node("sp").attrs["format"] == "colcompact"
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    got = compile_plan(gq, backend="quant")(gq.params, x)
    oracle = compile_plan(gq, backend="reference")(gq.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle), rtol=1e-4, atol=1e-5)
    f32 = ref.matmul_ref(jnp.take(x, kept, axis=-1), w[::2])
    assert float(jnp.abs(got - f32).max()) <= 5e-2


# --------------------------------------------------------------------------- #
# end-to-end acceptance: the three demo apps                                   #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("app", list(APPS))
def test_app_quant_backend_parity_and_compression(app):
    g = APPS[app](KEY, base=8)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    plan_f32 = compile_plan(go, backend="reference")
    shape = APP_INPUTS[app]
    batches = [
        jax.random.normal(jax.random.fold_in(KEY, i), shape) for i in range(2)
    ]
    table = calibrate_plan(plan_f32, go.params, batches)
    gq = optimize(
        g, masks, structures, calibration=table, quant_skip=APP_QUANT_SKIP[app],
        act_quant_skip=APP_ACT_SKIP[app],
    )
    assert any(n.op in ("qlinear", "qconv2d") for n in gq.nodes)
    if app == "coloring":
        # the BN-normalized stack holds the parity contract with every conv
        # at W8A8 -- int8 x int8 contractions end to end
        assert all(
            n.attrs.get("scheme") == "w8a8"
            for n in gq.nodes if n.op == "qconv2d"
        )
    plan_q = compile_plan(gq, backend="quant")
    x = jax.random.normal(jax.random.fold_in(KEY, 99), shape)
    err = float(jnp.abs(plan_q(gq.params, x) - plan_f32(go.params, x)).max())
    assert err <= 5e-2, (app, err)
    mem_f = plan_f32.memory_estimate(x)
    mem_q = plan_q.memory_estimate(x)
    ratio = mem_f["param_bytes"] / mem_q["param_bytes"]
    assert ratio >= 3.0, (app, ratio)
    # int8 payloads dominate the quantized plan's storage
    assert mem_q["param_bytes_by_dtype"]["int8"] > mem_q["param_bytes_by_dtype"]["float32"]
    assert mem_q["weight_bytes_saved"] == mem_f["param_bytes"] - mem_q["param_bytes"]


def test_batched_plan_serves_quantized_graph():
    g = _mlp_graph(KEY)
    gq = quantize(g, CalibrationTable())
    plan = compile_plan(gq, backend="quant")
    bp = plan.batched(4)
    x = jax.random.normal(KEY, (6, 48))
    out = bp(gq.params, x)
    assert out.shape == (6, 32)
    want = plan(gq.params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
