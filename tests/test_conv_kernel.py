"""Implicit-GEMM Pallas conv2d: kernel parity across shapes/strides/padding,
the three lowering schemes (dense f32, channel-pruned, INT8 W8/W8A8),
in-tile epilogue programs, the lax.conv fallback matrix, the conv tuning-key
family, and the executor/app acceptance gates (every demo-app conv lowers
through the kernel, zero fallbacks, plan steps at or below the PR 2
baseline).  Everything runs in interpret mode (CPU container)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    GraphBuilder,
    compile_plan,
    optimize,
    registered_ops,
)
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models.cnn import APPS, app_masks
from repro.quant import QTensor

KEY = jax.random.PRNGKey(0)

APP_INPUTS = {
    "style_transfer": (1, 3, 16, 16),
    "coloring": (1, 1, 16, 16),
    "super_resolution": (1, 3, 8, 8),
}

#: PR 2's plan-step acceptance baseline (33/30/37); folding the channel
#: compaction into the conv nodes cut these further
STEP_CAPS = {"style_transfer": 33, "coloring": 30, "super_resolution": 37}


def _conv_case(n, c, h, w, o, k, key=KEY):
    x = jax.random.normal(key, (n, c, h, w))
    wt = jax.random.normal(jax.random.PRNGKey(1), (o, c, k, k)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (o,)) * 0.1
    return x, wt, b


# --------------------------------------------------------------------------- #
# dense f32 parity                                                             #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize(
    "shape",
    [
        (1, 3, 7, 9, 5, 3),    # odd spatial dims, 3x3
        (2, 5, 11, 13, 7, 3),  # odd everything, batch 2
        (1, 4, 8, 8, 6, 1),    # 1x1 filter
        (1, 2, 16, 10, 3, 3),
    ],
)
def test_conv_kernel_parity(shape, stride, padding):
    n, c, h, w, o, k = shape
    x, wt, b = _conv_case(n, c, h, w, o, k)
    got = kops.conv2d(x, wt, b, stride=stride, padding=padding, activation="relu")
    want = ref.conv2d_ref(x, wt, b, stride=stride, padding=padding, activation="relu")
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_kernel_no_bias_no_activation():
    x, wt, _ = _conv_case(1, 3, 9, 9, 4, 3)
    got = kops.conv2d(x, wt)
    want = ref.conv2d_ref(x, wt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_kernel_explicit_pad_pairs():
    """lax-style ((ph_lo, ph_hi), (pw_lo, pw_hi)) padding lowers through the
    kernel (asymmetric pads included); negative (cropping) pads fall back."""
    x, wt, b = _conv_case(1, 3, 8, 9, 4, 3)
    kops.reset_conv_fallbacks()
    pads = ((1, 0), (2, 1))
    got = kops.conv2d(x, wt, b, stride=2, padding=pads)
    assert kops.conv_fallback_counts() == {}
    want = ref.conv2d_ref(x, wt, b, stride=2, padding=pads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
    neg = ((-1, 0), (0, 0))
    got_n = kops.conv2d(x, wt, b, padding=neg)
    assert kops.conv_fallback_counts() == {"padding": 1}
    want_n = ref.conv2d_ref(x, wt, b, padding=neg)
    np.testing.assert_allclose(np.asarray(got_n), np.asarray(want_n), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------- #
# channel-pruned scheme                                                        #
# --------------------------------------------------------------------------- #


def test_conv_kernel_channel_pruned_contracts_kept_only():
    x = jax.random.normal(KEY, (2, 10, 9, 9))
    kept = jnp.asarray([0, 3, 4, 7, 9], jnp.int32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (8, 5, 3, 3)) * 0.1
    got = kops.conv2d(x, wt, None, kept=kept, stride=2)
    want = ref.conv2d_ref(jnp.take(x, kept, axis=1), wt, None, stride=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_kernel_empty_pruned_channel_set_is_pure_epilogue():
    """All input channels pruned: the empty contraction contributes zeros,
    so the output is bias + activation + epilogue only."""
    x = jax.random.normal(KEY, (2, 6, 8, 8))
    wt = jnp.zeros((4, 0, 3, 3))
    kept = jnp.zeros((0,), jnp.int32)
    b = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    got = kops.conv2d(x, wt, b, kept=kept, activation="relu")
    want = jnp.broadcast_to(jax.nn.relu(b)[None, :, None, None], (2, 4, 8, 8))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------- #
# INT8 schemes                                                                 #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("scheme", ["w8", "w8a8"])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv_kernel_int8_matches_oracle(scheme, stride):
    x, wt, b = _conv_case(1, 6, 12, 12, 8, 3)
    qt = QTensor.from_float(wt, axis=0)
    xs = float(jnp.max(jnp.abs(x))) / 127.0 if scheme == "w8a8" else None
    got = kops.conv2d(
        x, qt.values, b, w_scale=qt.scale, x_scale=xs, stride=stride,
        activation="relu",
    )
    want = ref.qconv2d_ref(
        x, qt.values, qt.scale, b, x_scale=xs, stride=stride, activation="relu"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
    # and the whole scheme stays close to fp32
    f32 = ref.conv2d_ref(x, wt, b, stride=stride, activation="relu", out_dtype=jnp.float32)
    assert float(jnp.abs(got - f32).max()) <= 5e-2


def test_conv_kernel_int8_requires_scale():
    x, wt, _ = _conv_case(1, 4, 8, 8, 4, 3)
    qt = QTensor.from_float(wt, axis=0)
    with pytest.raises(ValueError, match="w_scale"):
        kops.conv2d(x, qt.values)
    with pytest.raises(ValueError, match="int8"):
        kops.conv2d(x, wt, x_scale=0.1)


# --------------------------------------------------------------------------- #
# in-tile epilogue programs                                                    #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("scheme", ["f32", "w8"])
def test_conv_kernel_epilogue_program_in_tile(scheme):
    x, wt, b = _conv_case(2, 4, 9, 9, 6, 3)
    side = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 9, 9))
    steps = (("add", 0), ("activation", "gelu"), ("mul", 0))
    if scheme == "w8":
        qt = QTensor.from_float(wt, axis=0)
        got = kops.conv2d(
            x, qt.values, b, w_scale=qt.scale,
            epilogue=steps, epilogue_sides=(side,),
        )
        base = ref.qconv2d_ref(x, qt.values, qt.scale, b)
    else:
        got = kops.conv2d(x, wt, b, epilogue=steps, epilogue_sides=(side,))
        base = ref.conv2d_ref(x, wt, b, out_dtype=jnp.float32)
    want = ref.apply_steps_ref(base, steps, [side])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_kernel_epilogue_validation():
    x, wt, _ = _conv_case(1, 3, 8, 8, 4, 3)
    with pytest.raises(ValueError, match="slot"):
        kops.conv2d(x, wt, epilogue=(("add", 0),), epilogue_sides=())


# --------------------------------------------------------------------------- #
# fallback matrix                                                              #
# --------------------------------------------------------------------------- #


def test_conv_fallback_groups_and_dilation_counted_and_exact():
    x = jax.random.normal(KEY, (1, 4, 8, 8))
    wg = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 3, 3)) * 0.1
    wd = jax.random.normal(jax.random.PRNGKey(2), (4, 4, 3, 3)) * 0.1
    kops.reset_conv_fallbacks()
    got_g = kops.conv2d(x, wg, None, groups=2)
    got_d = kops.conv2d(x, wd, None, dilation=2)
    assert kops.conv_fallback_counts() == {"groups": 1, "dilation": 1}
    np.testing.assert_allclose(
        np.asarray(got_g), np.asarray(ref.conv2d_ref(x, wg, None, groups=2)),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(ref.conv2d_ref(x, wd, None, dilation=2)),
        rtol=1e-4, atol=1e-5,
    )


def test_conv_fallback_preserves_epilogue_and_int8():
    """A fallback must be an engine change, never a semantics change: the
    int8 + epilogue math matches the oracle exactly."""
    x, wt, b = _conv_case(1, 4, 8, 8, 4, 3)
    qt = QTensor.from_float(wt, axis=0)
    side = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 8, 8))
    steps = (("add", 0), ("activation", "tanh"))
    kops.reset_conv_fallbacks()
    got = kops.conv2d(
        x, qt.values, b, w_scale=qt.scale, dilation=2,
        epilogue=steps, epilogue_sides=(side,),
    )
    assert kops.conv_fallback_counts() == {"dilation": 1}
    want = ref.apply_steps_ref(
        ref.qconv2d_ref(x, qt.values, qt.scale, b, dilation=2), steps, [side]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------- #
# tuning-key family                                                            #
# --------------------------------------------------------------------------- #


def test_conv_tuning_key_family_never_collides():
    cache = kops.tuning_cache()
    prev = dict(cache.entries)
    try:
        x, wt, _ = _conv_case(1, 6, 8, 8, 4, 3)
        qt = QTensor.from_float(wt, axis=0)
        kept = jnp.asarray([0, 2, 3], jnp.int32)
        kops.conv2d(x, wt)
        kops.conv2d(x, wt[:, :3], kept=kept)
        kops.conv2d(x, qt.values, w_scale=qt.scale)
        kops.conv2d(x, qt.values, w_scale=qt.scale, x_scale=0.02)
        shape8 = (1, 6, 8, 8, 4, 3, 3, 1)
        k_f32 = kops.TuningCache.key_nd("conv2d", shape8, jnp.float32, "dense+f32", True)
        k_chan = kops.TuningCache.key_nd(
            "conv2d", (1, 3, 8, 8, 4, 3, 3, 1), jnp.float32, "channelcompact+f32", True
        )
        k_w8 = kops.TuningCache.key_nd("conv2d", shape8, jnp.float32, "dense+w8", True)
        k_a8 = kops.TuningCache.key_nd("conv2d", shape8, jnp.int8, "dense+w8a8", True)
        # same dims, different output geometry: VALID suffixes the fmt so it
        # never shares a winner with SAME
        kops.conv2d(x, wt, padding="VALID")
        k_valid = kops.TuningCache.key_nd(
            "conv2d", shape8, jnp.float32, "dense+f32+valid", True
        )
        keys = {k_f32, k_chan, k_w8, k_a8, k_valid}
        assert len(keys) == 5  # schemes/formats/paddings never alias
        for k in keys:
            assert k in cache.entries, k
        # the conv shape signature carries all eight dims
        assert k_f32.split("|")[1] == "1x6x8x8x4x3x3x1"
    finally:
        cache.entries = prev


def test_conv_epilogue_keys_separately():
    cache = kops.tuning_cache()
    prev = dict(cache.entries)
    try:
        x, wt, _ = _conv_case(1, 4, 8, 8, 4, 3)
        side = jnp.zeros((1, 4, 8, 8))
        kops.conv2d(x, wt, epilogue=(("add", 0),), epilogue_sides=(side,))
        k = kops.TuningCache.key_nd(
            "conv2d", (1, 4, 8, 8, 4, 3, 3, 1), jnp.float32, "dense+f32+e1s1", True
        )
        assert k in cache.entries
    finally:
        cache.entries = prev


# --------------------------------------------------------------------------- #
# executor integration                                                         #
# --------------------------------------------------------------------------- #


def _conv_graph(c=6, o=8, k=3, with_norm=False):
    b = GraphBuilder(["x"])
    wt = jax.random.normal(KEY, (o, c, k, k)) * 0.1
    h = b.add("conv2d", "x", name="c1",
              params={"w": wt, "b": jnp.zeros((o,))}, stride=1, padding="SAME")
    if with_norm:
        h = b.add("norm", h, name="in1",
                  params={"scale": jnp.ones((o,)), "bias": jnp.zeros((o,))},
                  kind="instance")
    h = b.add("activation", h, name="a1", fn="relu")
    return b.build(h)


def test_kernel_backend_conv_epilogue_runs_in_tile():
    g = optimize(_conv_graph())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 11, 11))
    got = compile_plan(g, backend="kernel")(g.params, x)
    want = compile_plan(g, backend="reference")(g.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_kernel_backend_conv_norm_epilogue_falls_back_to_jnp_tail():
    """Instance-norm steps need whole spatial planes: the kernel runs the
    GEMM, the norm runs as a jnp tail -- still one plan step, exact parity."""
    g = optimize(_conv_graph(with_norm=True))
    (node,) = [n for n in g.nodes if n.op == "conv2d"]
    assert any(s[0] == "norm_instance" for s in node.attrs["epilogue"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 9, 9))
    got = compile_plan(g, backend="kernel")(g.params, x)
    want = compile_plan(g, backend="reference")(g.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_substitute_sparse_folds_channel_compaction_into_conv():
    """Dead input channels fold into the conv node (format=channelcompact +
    kept param) -- no gather glue node, one fewer plan step."""
    from repro.core.pruning import Column

    g = _conv_graph(c=8)
    w = g.params["c1"]["w"]
    mask = jnp.ones_like(w).at[:, ::2].set(0.0)  # kill half the input channels
    go = optimize(g, {"c1": mask}, {"c1": Column(0.5)})
    (conv,) = [n for n in go.nodes if n.op == "conv2d"]
    assert conv.attrs["format"] == "channelcompact"
    assert go.params[conv.name]["w"].shape[1] == 4
    assert go.params[conv.name]["kept"].shape == (4,)
    assert not any(n.op == "gather_channels" for n in go.nodes)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 9, 9))
    for backend in ("kernel", "reference"):
        got = compile_plan(go, backend=backend)(go.params, x)
        want = compile_plan(g, backend="reference")(
            {**g.params, "c1": {**g.params["c1"], "w": w * mask}}, x
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_qconv2d_is_quant_backend_only():
    assert "qconv2d" in registered_ops("quant")
    assert "qconv2d" not in registered_ops("kernel")


def test_memory_estimate_reports_conv_vmem_workspace():
    g = optimize(_conv_graph())
    plan = compile_plan(g, backend="reference")
    mem = plan.memory_estimate(jax.ShapeDtypeStruct((1, 6, 16, 16), jnp.float32))
    assert mem["peak_vmem_workspace_bytes"] > 0
    (conv_name,) = [s.node.name for s in plan.steps if s.node.op == "conv2d"]
    ws = mem["vmem_workspace_by_step"][conv_name]
    # at least the resident image + one im2col patch tile
    assert ws >= 16 * 16 * 6 * 4


# --------------------------------------------------------------------------- #
# the launch.tune pre-warm CLI                                                 #
# --------------------------------------------------------------------------- #


def test_launch_tune_smoke_prewarms_and_saves_cache(tmp_path, monkeypatch):
    """--smoke sweeps every key reachable from a demo app's plan on CPU and
    persists a loadable cache JSON (the CI-sized slice of the ROADMAP's
    hardware tuning sweeps)."""
    from repro.launch import tune

    cache = kops.tuning_cache()
    prev_enabled, prev_entries = cache.enabled, dict(cache.entries)
    out = tmp_path / "tuned.json"
    monkeypatch.setattr(
        "sys.argv",
        ["tune", "--graph-app", "coloring", "--smoke", "--size", "8",
         "--out", str(out)],
    )
    try:
        tune.main()
        assert out.exists()
        fresh = kops.TuningCache(enabled=False)
        fresh.load(str(out))
        swept_ops = {k.split("|")[0] for k in fresh.entries}
        assert "conv2d" in swept_ops and "matmul" in swept_ops
        assert all(e.source == "loaded" for e in fresh.entries.values())
    finally:
        cache.enabled, cache.entries = prev_enabled, prev_entries


# --------------------------------------------------------------------------- #
# app acceptance: every demo-app conv lowers through the Pallas kernel         #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("app", list(APPS))
def test_app_kernel_plans_lower_all_convs_through_pallas(app):
    g = APPS[app](KEY, base=8)
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    plan_k = compile_plan(go, backend="kernel")
    assert len(plan_k.steps) <= STEP_CAPS[app], (len(plan_k.steps), STEP_CAPS[app])
    x = jax.random.normal(jax.random.PRNGKey(1), APP_INPUTS[app])
    kops.reset_conv_fallbacks()
    got = plan_k(go.params, x)  # eager: the fallback counter sees every call
    assert kops.conv_fallback_counts() == {}, kops.conv_fallback_counts()
    want = compile_plan(go, backend="reference")(go.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# PR 6: tiled-K contraction + 1x1 direct-GEMM fast path                        #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("block_c", [0, 2, 4])
def test_conv_tiled_k_matches_resident_and_oracle(block_c):
    """Pinning block_c > 0 streams K in channel slabs through the cross-step
    accumulator; the result is at tolerance with both the resident full-K
    path (block_c=0) and the lax oracle."""
    x, wt, b = _conv_case(2, 6, 11, 13, 8, 3)
    got = kops.conv2d(x, wt, b, activation="relu",
                      block_h=8, block_o=128, block_c=block_c)
    want = ref.conv2d_ref(x, wt, b, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("scheme", ["w8", "w8a8"])
def test_conv_tiled_k_int8_schemes_match_oracle(scheme):
    """block_c slabs accumulate in int32 for w8a8 (channel zero-padding
    contributes exact zeros) and f32 for w8-dequant."""
    x, wt, b = _conv_case(1, 6, 10, 10, 8, 3)
    qt = QTensor.from_float(wt, axis=0)
    xs = float(jnp.max(jnp.abs(x))) / 127.0 if scheme == "w8a8" else None
    got = kops.conv2d(x, qt.values, b, w_scale=qt.scale, x_scale=xs,
                      block_h=8, block_o=128, block_c=2)
    want = ref.qconv2d_ref(x, qt.values, qt.scale, b, x_scale=xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_tiled_k_epilogue_runs_on_last_step_only():
    """The epilogue (bias/activation/steps) must fire exactly once, on the
    final K step, over the accumulated sum -- not per slab."""
    x, wt, b = _conv_case(1, 4, 9, 9, 6, 3)
    side = jax.random.normal(jax.random.PRNGKey(3), (1, 6, 9, 9))
    steps = (("add", 0), ("activation", "gelu"))
    got = kops.conv2d(x, wt, b, epilogue=steps, epilogue_sides=(side,),
                      block_h=8, block_o=128, block_c=2)
    want = ref.apply_steps_ref(
        ref.conv2d_ref(x, wt, b, out_dtype=jnp.float32), steps, [side]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_wide_channel_conv_no_longer_vmem_fallback():
    """PR 4's guard rejected any shape whose resident full-K workspace
    overflowed VMEM; with tiled-K the guard passes whenever SOME block_c
    candidate fits, so the wide-channel config lowers through Pallas."""
    c, h, w, kh = 2048, 32, 32, 3
    # the resident workspace genuinely overflows (the old fallback trigger)
    resident = kops.conv_vmem_workspace(c, h, w, kh, kh, 1, "SAME", 8, 128)
    assert resident["total"] > kops._CONV_VMEM_LIMIT
    # ... but a tiled block_c candidate fits, so the hw guard passes now
    assert kops.conv_fallback_reason(c, h, w, kh, kh, 1, "SAME", interpret=False) is None
    # and the hw default resolution elects a tiled block_c for this shape
    dh, do_, bc = kops._conv_default_blocks(c, h, w, kh, kh, 1, "SAME", 4, 4, False)
    assert bc > 0
    tiled = kops.conv_vmem_workspace(c, h, w, kh, kh, 1, "SAME", dh, do_, bc)
    assert tiled["total"] <= kops._CONV_VMEM_LIMIT
    # pinning a still-too-big block_c is honored verbatim -> fallback
    assert kops.conv_fallback_reason(
        c, h, w, kh, kh, 1, "SAME", interpret=False, block_c=0
    ) == "vmem"


def test_wide_channel_conv_runs_through_pallas_at_parity():
    """A (scaled-down) wide-channel config executes the tiled-K kernel path
    end to end: zero fallbacks, oracle parity."""
    x, wt, b = _conv_case(1, 64, 8, 8, 8, 3)
    kops.reset_conv_fallbacks()
    got = kops.conv2d(x, wt, b, block_h=8, block_o=128, block_c=16)
    assert kops.conv_fallback_counts() == {}
    want = ref.conv2d_ref(x, wt, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_1x1_fast_path_elected_counted_and_parity():
    """Unit-tap convs bypass im2col and lower to the dense/quant GEMM
    kernels; elections are counted per scheme like fallbacks."""
    x = jax.random.normal(KEY, (2, 6, 12, 12))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (8, 6, 1, 1)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (8,)) * 0.1
    kops.reset_conv_fastpaths()
    kops.reset_conv_fallbacks()
    got = kops.conv2d(x, w1, b, activation="relu")
    assert kops.conv_fastpath_counts() == {"f32": 1}
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ref.conv2d_ref(x, w1, b, activation="relu")),
        rtol=1e-4, atol=1e-5,
    )
    # stride subsamples spatially before the GEMM
    got_s = kops.conv2d(x, w1, b, stride=2)
    np.testing.assert_allclose(
        np.asarray(got_s), np.asarray(ref.conv2d_ref(x, w1, b, stride=2)),
        rtol=1e-4, atol=1e-5,
    )
    # int8 schemes route to qmatmul and count under their scheme
    qt = QTensor.from_float(w1, axis=0)
    got_q = kops.conv2d(x, qt.values, b, w_scale=qt.scale, x_scale=0.05)
    assert kops.conv_fastpath_counts()["w8a8"] == 1
    np.testing.assert_allclose(
        np.asarray(got_q),
        np.asarray(ref.qconv2d_ref(x, qt.values, qt.scale, b, x_scale=0.05)),
        rtol=1e-4, atol=1e-5,
    )
    # channel compaction gathers kept channels before the reshape
    kept = jnp.asarray([0, 2, 5], jnp.int32)
    got_k = kops.conv2d(x, w1[:, :3], b, kept=kept)
    assert kops.conv_fastpath_counts()["f32"] >= 3
    np.testing.assert_allclose(
        np.asarray(got_k),
        np.asarray(ref.conv2d_ref(jnp.take(x, kept, axis=1), w1[:, :3], b)),
        rtol=1e-4, atol=1e-5,
    )
    assert kops.conv_fallback_counts() == {}  # elections are not fallbacks


def test_conv_1x1_election_rules():
    """Election requires unit taps, groups=1, no effective padding, live
    input channels; pinned block sizes or gemm_1x1=False bypass it so the
    im2col kernel stays testable on 1x1 shapes."""
    assert kops.conv_gemm1x1_elected(1, 1, 1, "SAME", 6)
    assert kops.conv_gemm1x1_elected(1, 1, 1, "VALID", 6)
    assert kops.conv_gemm1x1_elected(1, 1, 1, ((0, 0), (0, 0)), 6)
    assert not kops.conv_gemm1x1_elected(3, 3, 1, "SAME", 6)   # taps
    assert not kops.conv_gemm1x1_elected(1, 1, 2, "SAME", 6)   # groups
    assert not kops.conv_gemm1x1_elected(1, 1, 1, ((1, 0), (0, 0)), 6)  # pad
    assert not kops.conv_gemm1x1_elected(1, 1, 1, "SAME", 0)   # no live K
    x = jax.random.normal(KEY, (1, 4, 8, 8))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (6, 4, 1, 1)) * 0.1
    kops.reset_conv_fastpaths()
    kops.conv2d(x, w1, block_h=8, block_o=128)  # pinned -> im2col kernel
    kops.conv2d(x, w1, gemm_1x1=False)
    assert kops.conv_fastpath_counts() == {}


@pytest.mark.parametrize("app", list(APPS))
def test_app_1x1_convs_lower_through_fast_path(app):
    """Every demo app carries at least one 1x1 conv (style/SR residual
    blocks are bottleneck/WDSR-B style; coloring's fusion conv): each app's
    kernel plan elects the direct-GEMM fast path with zero fallbacks."""
    g = APPS[app](KEY, base=8)
    n_1x1 = sum(
        1 for n in g.nodes
        if n.op == "conv2d" and g.params[n.name]["w"].shape[2] == 1
    )
    assert n_1x1 >= 1, app
    masks, structures = app_masks(g, app, sparsity=0.5)
    go = optimize(g, masks, structures)
    plan_k = compile_plan(go, backend="kernel")
    x = jax.random.normal(jax.random.PRNGKey(1), APP_INPUTS[app])
    kops.reset_conv_fastpaths()
    kops.reset_conv_fallbacks()
    got = plan_k(go.params, x)  # eager: counters see every call
    fastpaths = kops.conv_fastpath_counts()
    assert sum(fastpaths.values()) >= n_1x1, (app, fastpaths)
    assert kops.conv_fallback_counts() == {}, kops.conv_fallback_counts()
    want = compile_plan(go, backend="reference")(go.params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
