"""The paper's three demo applications (style transfer, coloring, super
resolution) as LR graphs: shape correctness, pruning+compiler exactness,
and the Table-1 contract (pruned+compiler strictly cheaper than dense)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import lower, optimize
from repro.core.pruning import PatternKernel, project
from repro.models.cnn import APPS, PAPER_RECIPE, build_coloring, build_style_transfer, build_super_resolution
from benchmarks.table1_apps import app_masks, count_graph_flops, graph_param_bytes

KEY = jax.random.PRNGKey(0)

INPUTS = {
    "style_transfer": (1, 3, 32, 32),
    "coloring": (1, 1, 32, 32),
    "super_resolution": (1, 3, 16, 16),
}
OUT_SHAPES = {
    "style_transfer": (1, 3, 32, 32),
    "coloring": (1, 2, 32, 32),
    "super_resolution": (1, 3, 32, 32),
}


@pytest.mark.parametrize("app", list(APPS))
def test_app_builds_and_runs(app):
    g = APPS[app](KEY, base=16)
    x = jax.random.normal(jax.random.PRNGKey(1), INPUTS[app])
    y = lower(g, use_kernels=False)(g.params, x)
    assert y.shape == OUT_SHAPES[app]
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("app", list(APPS))
def test_app_pruned_compiler_exactness(app):
    """optimize(graph, masks) must equal the masked-dense reference."""
    g = APPS[app](KEY, base=16)
    masks, structures = app_masks(g, app, sparsity=0.5)
    assert masks, "the paper's recipe must hit conv/linear layers"
    # masked reference
    pm = {}
    for name, p in g.params.items():
        if name in masks:
            pm[name] = {**p, "w": p["w"] * masks[name]}
        else:
            pm[name] = p
    x = jax.random.normal(jax.random.PRNGKey(1), INPUTS[app])
    y_ref = lower(g, use_kernels=False)(pm, x)
    go = optimize(g, masks, structures)
    y = lower(go, use_kernels=False)(go.params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("app", list(APPS))
def test_app_compiler_reduces_cost(app):
    """Table-1 direction: pruned+compiler has fewer FLOPs + smaller params."""
    g = APPS[app](KEY, base=16)
    masks, structures = app_masks(g, app, sparsity=0.6)
    go = optimize(g, masks, structures)
    x_shape = INPUTS[app]
    f_dense = count_graph_flops(g, x_shape)
    f_sparse = count_graph_flops(go, x_shape)
    assert f_sparse < f_dense, (f_sparse, f_dense)
    assert graph_param_bytes(go) < graph_param_bytes(g)


def test_paper_recipe_mapping():
    assert PAPER_RECIPE == {
        "style_transfer": "column",
        "coloring": "pattern",
        "super_resolution": "pattern",
    }


def test_pattern_pruning_preserves_kernel_count_semantics():
    g = build_super_resolution(KEY, base=16, n_res=2)
    masks, structures = app_masks(g, "super_resolution", sparsity=0.5)
    name, st_ = next(iter(structures.items()))
    assert isinstance(st_, PatternKernel)
    m = np.asarray(masks[name])
    assert set(np.unique(m.sum(axis=(2, 3)))).issubset({0.0, 4.0})
